#!/usr/bin/env bash
# CI for the slay crate: build, tests, formatting, lints.
#
# Build and tests are hard gates (the tier-1 bar from ROADMAP.md).
# Formatting and clippy run in report mode by default — the codebase
# predates rustfmt adoption — and become hard gates with STRICT=1:
#
#   ./ci.sh            # build + test gate, fmt/clippy report
#   STRICT=1 ./ci.sh   # everything gates
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

soft() {
    local label="$1"
    shift
    echo "== $* =="
    if "$@"; then
        echo "[ok] $label"
    elif [ "${STRICT:-0}" = "1" ]; then
        echo "[fail] $label (STRICT=1)"
        exit 1
    else
        echo "[warn] $label reported findings (non-gating; run STRICT=1 to enforce)"
    fi
}

soft "rustfmt" cargo fmt --check
soft "clippy" cargo clippy --all-targets -- -D warnings

echo "ci.sh done"
