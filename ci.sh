#!/usr/bin/env bash
# CI for the slay crate: build, tests, lints, formatting.
#
# Hard gates:
#   * cargo build --release            (tier-1 bar from ROADMAP.md)
#   * cargo build --release --benches  (the harness=false bench mains —
#                                       keeps the paper-figure programs
#                                       from bit-rotting outside `cargo
#                                       test`'s reach)
#   * cargo test -q                    (tier-1 bar)
#   * cargo clippy --all-targets -- -D warnings
#
# Formatting still runs in report mode by default — the codebase predates
# rustfmt adoption — and becomes a hard gate with STRICT=1:
#
#   ./ci.sh            # build + bench-build + test + clippy gate, fmt report
#   STRICT=1 ./ci.sh   # everything gates
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --benches =="
cargo build --release --benches

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

soft() {
    local label="$1"
    shift
    echo "== $* =="
    if "$@"; then
        echo "[ok] $label"
    elif [ "${STRICT:-0}" = "1" ]; then
        echo "[fail] $label (STRICT=1)"
        exit 1
    else
        echo "[warn] $label reported findings (non-gating; run STRICT=1 to enforce)"
    fi
}

soft "rustfmt" cargo fmt --check

echo "ci.sh done"
