#!/usr/bin/env bash
# CI for the slay crate: build, tests, lints, formatting.
#
# Hard gates:
#   * cargo build --release            (tier-1 bar from ROADMAP.md)
#   * cargo build --release --benches  (the harness=false bench mains —
#                                       keeps the paper-figure programs
#                                       from bit-rotting outside `cargo
#                                       test`'s reach)
#   * cargo test -q                    (tier-1 bar; includes the
#                                       counting-allocator guard in
#                                       rust/tests/alloc_discipline.rs and
#                                       the SIMD-vs-scalar microkernel
#                                       properties in
#                                       rust/tests/simd_kernels.rs)
#   * SLAY_SIMD=scalar cargo test -q   (x86_64 only: the whole suite again
#                                       with dispatch forced to the scalar
#                                       backend — every bit-identity /
#                                       chaos / alloc invariant must hold
#                                       under both resolved tables,
#                                       ADR-010)
#   * cargo clippy --all-targets -- -D warnings
#   * cargo fmt --check                (hard gate since ADR-004)
#   * SLAY_BENCH_SMOKE=1 fig2_scaling  (smoke-runs the scaling bench at
#                                       small L and checks that the
#                                       machine-readable
#                                       results/BENCH_scaling.json lands)
#   * SLAY_BENCH_SMOKE=1 persist       (snapshot → restore → serve smoke
#                                       of the ADR-004 persistence
#                                       subsystem; asserts
#                                       results/BENCH_persist.json lands)
#   * SLAY_BENCH_SMOKE=1 serve_decode  (fused vs per-item cross-session
#                                       decode smoke of ADR-005; asserts
#                                       results/BENCH_decode.json lands)
#   * SLAY_BENCH_SMOKE=1 serve_fork    (COW fork + shared-prefix cache
#                                       smoke of ADR-006; asserts the
#                                       warm/cold ≤ 0.25 acceptance gate
#                                       and results/BENCH_fork.json)
#   * SLAY_BENCH_SMOKE=1 serve_wire    (wire protocol + front-end smoke of
#                                       ADR-007: JSON vs binary plane over
#                                       threads and epoll; asserts the
#                                       binary-beats-JSON p50 gate at 4096
#                                       floats and results/BENCH_wire.json)
#   * SLAY_BENCH_SMOKE=1 serve_obs     (observability overhead smoke:
#                                       decode throughput with per-stage
#                                       tracing on must stay within 3% of
#                                       recording off; asserts
#                                       results/BENCH_obs.json lands)
#   * SLAY_BENCH_SMOKE=1 microkernel   (SIMD dispatch speedup smoke,
#                                       ADR-010: dispatched GEMMs must be
#                                       >= 4x forced-scalar with AVX2
#                                       resolved, no-regression elsewhere;
#                                       asserts results/BENCH_simd.json
#                                       lands)
#   * chaos (armed)                    (ADR-008 fault-injection smoke: the
#                                       fixed-seed SLAY_FAULTS plan below
#                                       drives mixed traffic through worker
#                                       kills / compute panics / frame
#                                       corruption / spill-write failures
#                                       and gates on the no-hang,
#                                       bit-identity and
#                                       every-fault-counted invariants)
#   * chaos (disarmed)                 (same traffic with the fault layer
#                                       off — zero fault counters, zero
#                                       errored sessions: the
#                                       fault-layer-is-a-no-op gate)
#   * trajectory                       (rolls the smokes' BENCH_*.json
#                                       into the tracked
#                                       BENCH_TRAJECTORY.json and fails
#                                       on a > SLAY_BENCH_TOLERANCE drop
#                                       vs the previous entry)
#
# The benches run with SLAY_FAULTS scrubbed from the environment so the
# tracked perf trajectory always measures the fault-free serving path.
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --benches =="
cargo build --release --benches

echo "== cargo test -q =="
env -u SLAY_FAULTS cargo test -q

# ADR-010: on x86_64 the auto-resolved backend is AVX2 wherever the CPU
# has it, so forcing scalar re-proves every invariant against the other
# table. (aarch64 runs NEON above; scalar coverage there comes from the
# in-process cross-backend property tests.)
if [ "$(uname -m)" = "x86_64" ]; then
  echo "== cargo test -q (SLAY_SIMD=scalar) =="
  SLAY_SIMD=scalar env -u SLAY_FAULTS cargo test -q
fi

# The fixed-seed chaos plan. Keep in lockstep with DEFAULT_PLAN in
# rust/tests/chaos.rs (the harness self-arms with the same string when
# the variable is unset, so this is belt-and-braces reproducibility).
CHAOS_PLAN="spill_write:io@0.03;decode:panic@0.01;frame_rx:corrupt@0.02;worker_loop:panic@0.004;seed=7"

echo "== chaos smoke, armed (SLAY_FAULTS=$CHAOS_PLAN) =="
SLAY_FAULTS="$CHAOS_PLAN" cargo test -q --test chaos

echo "== chaos control, disarmed (fault layer must be a no-op) =="
SLAY_FAULTS=off cargo test -q --test chaos

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== cargo fmt --check =="
cargo fmt --check

RESULTS_DIR="${SLAY_RESULTS:-results}"

echo "== fig2_scaling smoke (emits BENCH_scaling.json) =="
rm -f "$RESULTS_DIR/BENCH_scaling.json"
SLAY_BENCH_SMOKE=1 env -u SLAY_FAULTS cargo bench --bench fig2_scaling
test -f "$RESULTS_DIR/BENCH_scaling.json" || { echo "BENCH_scaling.json missing"; exit 1; }

echo "== persist smoke (snapshot -> restore -> serve; emits BENCH_persist.json) =="
rm -f "$RESULTS_DIR/BENCH_persist.json"
SLAY_BENCH_SMOKE=1 env -u SLAY_FAULTS cargo bench --bench persist
test -f "$RESULTS_DIR/BENCH_persist.json" || { echo "BENCH_persist.json missing"; exit 1; }

echo "== serve_decode smoke (fused vs per-item decode; emits BENCH_decode.json) =="
rm -f "$RESULTS_DIR/BENCH_decode.json"
SLAY_BENCH_SMOKE=1 env -u SLAY_FAULTS cargo bench --bench serve_decode
test -f "$RESULTS_DIR/BENCH_decode.json" || { echo "BENCH_decode.json missing"; exit 1; }

echo "== serve_fork smoke (COW fork + prefix cache; emits BENCH_fork.json) =="
rm -f "$RESULTS_DIR/BENCH_fork.json"
SLAY_BENCH_SMOKE=1 env -u SLAY_FAULTS cargo bench --bench serve_fork
test -f "$RESULTS_DIR/BENCH_fork.json" || { echo "BENCH_fork.json missing"; exit 1; }

echo "== serve_wire smoke (JSON vs binary, threads vs epoll; emits BENCH_wire.json) =="
rm -f "$RESULTS_DIR/BENCH_wire.json"
SLAY_BENCH_SMOKE=1 env -u SLAY_FAULTS cargo bench --bench serve_wire
test -f "$RESULTS_DIR/BENCH_wire.json" || { echo "BENCH_wire.json missing"; exit 1; }

echo "== serve_obs smoke (tracing overhead <= 3% gate; emits BENCH_obs.json) =="
rm -f "$RESULTS_DIR/BENCH_obs.json"
SLAY_BENCH_SMOKE=1 env -u SLAY_FAULTS cargo bench --bench serve_obs
test -f "$RESULTS_DIR/BENCH_obs.json" || { echo "BENCH_obs.json missing"; exit 1; }

echo "== microkernel smoke (SIMD >= 4x scalar gate on AVX2; emits BENCH_simd.json) =="
rm -f "$RESULTS_DIR/BENCH_simd.json"
SLAY_BENCH_SMOKE=1 env -u SLAY_FAULTS cargo bench --bench microkernel
test -f "$RESULTS_DIR/BENCH_simd.json" || { echo "BENCH_simd.json missing"; exit 1; }

echo "== perf trajectory (appends BENCH_TRAJECTORY.json, diffs vs previous entry) =="
env -u SLAY_FAULTS cargo bench --bench trajectory
test -f "${SLAY_TRAJECTORY:-BENCH_TRAJECTORY.json}" || { echo "BENCH_TRAJECTORY.json missing"; exit 1; }

echo "ci.sh done"
