#!/usr/bin/env bash
# CI for the slay crate: build, tests, lints, formatting.
#
# Hard gates:
#   * cargo build --release            (tier-1 bar from ROADMAP.md)
#   * cargo build --release --benches  (the harness=false bench mains —
#                                       keeps the paper-figure programs
#                                       from bit-rotting outside `cargo
#                                       test`'s reach)
#   * cargo test -q                    (tier-1 bar; includes the
#                                       counting-allocator guard in
#                                       rust/tests/alloc_discipline.rs)
#   * cargo clippy --all-targets -- -D warnings
#   * SLAY_BENCH_SMOKE=1 fig2_scaling  (smoke-runs the scaling bench at
#                                       small L and checks that the
#                                       machine-readable
#                                       results/BENCH_scaling.json lands)
#
# Formatting still runs in report mode by default — the codebase predates
# rustfmt adoption — and becomes a hard gate with STRICT=1:
#
#   ./ci.sh            # build + bench-build + test + clippy gate, fmt report
#   STRICT=1 ./ci.sh   # everything gates
set -euo pipefail
cd "$(dirname "$0")"

echo "== cargo build --release =="
cargo build --release

echo "== cargo build --release --benches =="
cargo build --release --benches

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy --all-targets -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== fig2_scaling smoke (emits BENCH_scaling.json) =="
RESULTS_DIR="${SLAY_RESULTS:-results}"
rm -f "$RESULTS_DIR/BENCH_scaling.json"
SLAY_BENCH_SMOKE=1 cargo bench --bench fig2_scaling
test -f "$RESULTS_DIR/BENCH_scaling.json" || { echo "BENCH_scaling.json missing"; exit 1; }

soft() {
    local label="$1"
    shift
    echo "== $* =="
    if "$@"; then
        echo "[ok] $label"
    elif [ "${STRICT:-0}" = "1" ]; then
        echo "[fail] $label (STRICT=1)"
        exit 1
    else
        echo "[warn] $label reported findings (non-gating; run STRICT=1 to enforce)"
    fi
}

soft "rustfmt" cargo fmt --check

echo "ci.sh done"
