//! Full synthetic-task evaluation (Tables 3 + 8 at paper protocol):
//! every task × mechanism × seed combination requested on the command
//! line, writing per-task accuracies and category means to results/.
//!
//! Run (subset): `cargo run --release --example synthetic_tasks --
//!                 --tasks copy,retrieval,majority --mechanisms slay,standard
//!                 --seeds 1 --steps 400`
//! Run (full Table 8, CPU-hours):
//!               `… --tasks all --mechanisms standard,yat_spherical,favor,elu_linear,slay --seeds 3 --steps 800`

use slay::cli_app::train_eval_task;
use slay::data::tasks::{Task, ALL_TASKS};
use slay::runtime::Registry;
use slay::util::benchkit::{write_csv, Table};

fn main() -> anyhow::Result<()> {
    let args = slay::util::cli::Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())?;
    let task_arg = args.get_or("tasks", "copy,retrieval,first_token,majority");
    let mech_arg = args.get_or("mechanisms", "slay,standard");
    let seeds = args.u64_or("seeds", 1)?;
    let steps = args.usize_or("steps", 400)?;

    let tasks: Vec<Task> = if task_arg == "all" {
        ALL_TASKS.to_vec()
    } else {
        task_arg
            .split(',')
            .map(|n| Task::from_name(n).ok_or_else(|| anyhow::anyhow!("unknown task '{n}'")))
            .collect::<anyhow::Result<_>>()?
    };
    let mechanisms: Vec<&str> = mech_arg.split(',').collect();

    let reg = Registry::open_default()?;
    let mut header = vec!["task".to_string(), "category".to_string()];
    header.extend(mechanisms.iter().map(|m| m.to_string()));
    let mut table = Table::new(
        "Synthetic tasks — answer accuracy (mean±std over seeds)",
        &header.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut csv_rows = Vec::new();

    for task in &tasks {
        let mut row = vec![task.name().to_string(), task.category().name().to_string()];
        for mech in &mechanisms {
            let mut accs = Vec::new();
            for seed in 0..seeds {
                let t0 = std::time::Instant::now();
                let (_, acc) = train_eval_task(&reg, *task, mech, steps, seed)?;
                eprintln!(
                    "[{}/{mech}] seed {seed}: acc {acc:.3} ({:.0}s)",
                    task.name(),
                    t0.elapsed().as_secs_f64()
                );
                accs.push(acc);
            }
            let mean = slay::math::stats::mean(&accs);
            let sd = slay::math::stats::std_dev(&accs);
            row.push(format!("{mean:.2}±{sd:.2}"));
            csv_rows.push(vec![
                task.name().to_string(),
                mech.to_string(),
                format!("{mean:.4}"),
                format!("{sd:.4}"),
            ]);
        }
        table.row(row);
    }
    table.print();
    write_csv(
        "synthetic_tasks_full.csv",
        &["task", "mechanism", "acc_mean", "acc_std"],
        &csv_rows,
    )?;
    Ok(())
}
