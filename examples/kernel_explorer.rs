//! Kernel explorer — interactive-grade sweep over the SLAY estimator's
//! design space: polynomial method × feature budget × quadrature depth,
//! reporting attention-output fidelity vs exact spherical Yat attention.
//! This is the ablation playground behind DESIGN.md's estimator choices.
//!
//! Run: `cargo run --release --example kernel_explorer -- [--l 64] [--d 16]`

use slay::kernels::config::{Mechanism, PolyMethod, SlayConfig};
use slay::kernels::build;
use slay::math::linalg::Mat;
use slay::math::rng::Rng;
use slay::util::benchkit::Table;

fn main() -> anyhow::Result<()> {
    let args = slay::util::cli::Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())?;
    let l = args.usize_or("l", 64)?;
    let d = args.usize_or("d", 16)?;

    // clustered geometry (alignments spread over [-1, 1])
    let mut rng = Rng::new(93);
    let centers = Mat::randn(4, d, &mut rng).normalized_rows();
    let mut gen =
        |rng: &mut Rng| Mat::from_fn(l, d, |r, c| centers.row(r % 4)[c] + 0.3 * rng.normal_f32());
    let q = gen(&mut rng);
    let k = gen(&mut rng);
    let v = Mat::randn(l, d, &mut rng);
    let exact = build(&Mechanism::YatSpherical { eps: 1e-3 }, d, l)?
        .forward(q.view(), k.view(), v.view(), false, 0);

    let mut table = Table::new(
        "SLAY estimator design space — rel-l2 vs exact spherical Yat (seed-avg of 4)",
        &["poly", "R", "P", "D", "m", "rel_l2"],
    );
    for poly in [PolyMethod::Anchor, PolyMethod::Exact] {
        for r_nodes in [2usize, 3, 6] {
            for (n_poly, d_prf) in [(8usize, 16usize), (16, 32), (32, 64)] {
                let mut errs = Vec::new();
                let mut m = 0;
                for seed in 0..4 {
                    let cfg =
                        SlayConfig { poly, r_nodes, n_poly, d_prf, seed, ..Default::default() };
                    let op = build(&Mechanism::Slay(cfg.clone()), d, l)?;
                    m = op.feature_dim().unwrap();
                    let y = op.forward(q.view(), k.view(), v.view(), false, 0);
                    errs.push(slay::math::stats::rel_l2(&y.data, &exact.data));
                }
                table.row(vec![
                    poly.name().to_string(),
                    r_nodes.to_string(),
                    n_poly.to_string(),
                    d_prf.to_string(),
                    m.to_string(),
                    format!("{:.3}", slay::math::stats::mean(&errs)),
                ]);
            }
        }
    }
    table.print();
    table.to_csv("kernel_explorer.csv")?;
    println!(
        "\nreading: exact-poly dominates anchors at equal budget; R>3 buys little \
         (first nodes carry the integral — Fig. 11); errors track the paper's 0.49-0.66 band."
    );
    Ok(())
}
