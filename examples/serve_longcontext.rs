//! Long-context serving demo — the workload the paper's intro motivates:
//! many concurrent sequences with deep contexts, mixed prefill/decode,
//! served by the SLAY coordinator in constant memory per sequence.
//!
//! Reports sustained throughput, decode latency percentiles, batching
//! effectiveness and state-memory footprint; compares against what a
//! quadratic KV-cache would need at the same depth.
//!
//! With `--spill-dir` the store pages idle session states to disk instead
//! of destroying them (ADR-004), and `--snapshot` writes a durable
//! snapshot of every live session at the end of the run — the directory
//! can be resumed with `slay serve --restore <dir>`, including on a
//! different worker count.
//!
//! Run: `cargo run --release --example serve_longcontext -- [--seqs 32]
//!       [--context 4096] [--decodes 64] [--workers 4]
//!       [--spill-dir /tmp/slay-spill] [--snapshot /tmp/slay-snap]`

use slay::coordinator::request::AttendChunk;
use slay::coordinator::{Coordinator, CoordinatorConfig};
use slay::kernels::engine::workspace_bytes;
use slay::math::linalg::Mat;
use slay::math::rng::Rng;
use slay::util::cli::Args;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())?;
    let n_seqs = args.usize_or("seqs", 32)?;
    let context = args.usize_or("context", 4096)?;
    let decodes = args.usize_or("decodes", 64)?;
    let workers = args.usize_or("workers", 4)?;
    let d = 32usize;
    let prefill_chunk = 512usize;

    let mut cfg = CoordinatorConfig {
        d_head: d,
        d_v: d,
        workers,
        max_batch: 16,
        ..CoordinatorConfig::default()
    };
    if let Some(dir) = args.get("spill-dir") {
        cfg.store.spill_dir = Some(std::path::PathBuf::from(dir));
    }
    let coord = Arc::new(Coordinator::start(cfg)?);

    println!(
        "serving {n_seqs} sequences to context {context} (+{decodes} decode steps each), \
         {workers} workers"
    );

    // with --snapshot, sessions stay live so the final snapshot has
    // something to persist
    let keep_sessions = args.get("snapshot").is_some();
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for s in 0..n_seqs {
        let c = coord.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut rng = Rng::new(s as u64 + 1);
            let seq = c.create_sequence()?;
            // prefill in chunks
            let mut done = 0;
            while done < context {
                let n = prefill_chunk.min(context - done);
                let chunk = AttendChunk {
                    seq,
                    q: Mat::randn(n, d, &mut rng),
                    k: Mat::randn(n, d, &mut rng),
                    v: Mat::randn(n, d, &mut rng),
                };
                loop {
                    match c.attend(AttendChunk {
                        seq,
                        q: chunk.q.clone(),
                        k: chunk.k.clone(),
                        v: chunk.v.clone(),
                    }) {
                        Ok(_) => break,
                        Err(e) if e.to_string().contains("backpressure") => {
                            std::thread::sleep(std::time::Duration::from_micros(300));
                        }
                        Err(e) => return Err(e),
                    }
                }
                done += n;
            }
            // decode steps, recording latency
            let mut lat = Vec::new();
            for _ in 0..decodes {
                let r = c.attend(AttendChunk {
                    seq,
                    q: Mat::randn(1, d, &mut rng),
                    k: Mat::randn(1, d, &mut rng),
                    v: Mat::randn(1, d, &mut rng),
                })?;
                lat.push(r.latency.as_secs_f64() * 1e3);
            }
            if !keep_sessions {
                c.release_sequence(seq)?;
            }
            Ok(lat)
        }));
    }
    let mut decode_lat = Vec::new();
    for h in handles {
        decode_lat.extend(h.join().expect("client thread")?);
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.metrics();

    let total_tokens = n_seqs * (context + decodes);
    println!("\n== results ==");
    println!("wall time            {wall:.2}s");
    println!("total tokens         {total_tokens}");
    println!("throughput           {:.0} tok/s", total_tokens as f64 / wall);
    println!(
        "decode latency       p50 {:.2}ms  p95 {:.2}ms",
        slay::math::stats::percentile(&decode_lat, 50.0),
        slay::math::stats::percentile(&decode_lat, 95.0)
    );
    println!("mean batch size      {:.1}", m.mean_batch_size());
    println!("rejected (backpressure) {}", m.rejected);
    println!(
        "spill tier           {} spilled ({:.1} MiB), {} faulted back",
        m.spilled,
        m.bytes_spilled as f64 / (1024.0 * 1024.0),
        m.restored_from_spill
    );

    // durable snapshot of whatever is still live (ADR-004)
    if let Some(dir) = args.get("snapshot") {
        let report = coord.snapshot(std::path::Path::new(dir))?;
        println!(
            "snapshot             {} sequences, {:.1} MiB -> {dir}",
            report.sequences,
            report.bytes as f64 / (1024.0 * 1024.0)
        );
        println!("                     (resume: slay serve --restore {dir})");
    }

    // memory story (Fig. 2's point, serving edition)
    let mcfg = coord.config();
    let op = slay::kernels::build(&mcfg.mechanism, d, context)?;
    let state_bytes = op.new_state(d).capacity_bytes();
    let kv_bytes = context * 2 * d * 4; // quadratic KV-cache at same depth
    println!(
        "\nper-sequence memory: SLAY state {:.1} KiB (constant) vs KV-cache {:.1} KiB \
         (grows with context; x{:.1} at {context} tokens)",
        state_bytes as f64 / 1024.0,
        kv_bytes as f64 / 1024.0,
        kv_bytes as f64 / state_bytes as f64
    );
    let _ = workspace_bytes(None, context, d, d);
    coord
        .metrics()
        .to_json()
        .to_pretty()
        .lines()
        .for_each(|l| println!("  {l}"));
    Arc::try_unwrap(coord)
        .map_err(|_| anyhow::anyhow!("coordinator still referenced"))?
        .shutdown()?;
    Ok(())
}
