//! End-to-end training driver (the repository's e2e validation): train a
//! transformer LM through the full three-layer stack — synthetic corpus
//! generated in Rust, gradients computed by the AOT-compiled JAX
//! `train_step` (which embeds the SLAY attention), executed over PJRT —
//! and log the loss curve + validation perplexity to results/.
//!
//! Run: `cargo run --release --example train_lm -- [--preset tiny]
//!       [--mechanism slay] [--steps 300] [--seed 0]`
//!
//! Requires `make artifacts`. The run is recorded in EXPERIMENTS.md §E2E.

use slay::data::corpus::{Corpus, CorpusConfig};
use slay::math::rng::Rng;
use slay::runtime::executor::TensorData;
use slay::runtime::Registry;
use slay::train::Trainer;
use slay::util::benchkit::write_csv;

fn main() -> anyhow::Result<()> {
    let args = slay::util::cli::Args::parse(&std::env::args().skip(1).collect::<Vec<_>>())?;
    let preset = args.get_or("preset", "tiny");
    let mech = args.get_or("mechanism", "slay");
    let steps = args.usize_or("steps", 300)?;
    let seed = args.u64_or("seed", 0)? as u32;

    let reg = Registry::open_default()?;
    let mut tr = Trainer::new(
        &reg,
        &format!("train_step_{preset}_{mech}"),
        &format!("init_{preset}"),
        seed,
    )?;
    let n_params: usize = reg
        .manifest
        .get(&format!("init_{preset}"))?
        .outputs
        .iter()
        .map(|s| s.elements())
        .sum();
    println!(
        "e2e train: {mech}/{preset} — {} parameters, batch {}, seq {}, vocab {}",
        n_params, tr.shapes.batch, tr.shapes.seq_len, tr.shapes.vocab
    );

    let corpus = Corpus::new(CorpusConfig { vocab: tr.shapes.vocab, ..Default::default() }, 42);
    let mut rng = Rng::new(seed as u64 + 1);

    // fixed validation set
    let mut vrng = Rng::new(9999);
    let val: Vec<(Vec<i32>, Vec<i32>)> = (0..4)
        .map(|_| corpus.lm_batch(tr.shapes.batch, tr.shapes.seq_len, &mut vrng))
        .collect();
    let loss_exe = reg.get(&format!("loss_{preset}_{mech}"))?;
    let eval = |tr: &Trainer| -> anyhow::Result<f32> {
        let mut acc = 0.0;
        for (t, y) in &val {
            acc += tr
                .run_with_params(
                    &loss_exe,
                    &[TensorData::I32(t.clone()), TensorData::I32(y.clone())],
                )?[0]
                .scalar_f32()?;
        }
        Ok(acc / val.len() as f32)
    };

    let mut curve: Vec<Vec<String>> = Vec::new();
    let t0 = std::time::Instant::now();
    let v0 = eval(&tr)?;
    println!("step {:>5}  train -       val {v0:.4}  ppl {:.1}", 0, (v0 as f64).exp());
    curve.push(vec!["0".into(), "".into(), format!("{v0:.5}")]);
    for step in 1..=steps {
        let (tokens, targets) = corpus.lm_batch(tr.shapes.batch, tr.shapes.seq_len, &mut rng);
        let loss = tr.step(&tokens, &targets)?;
        if step % 25 == 0 || step == steps {
            let vl = eval(&tr)?;
            let tok_s = (step * tr.shapes.batch * tr.shapes.seq_len) as f64
                / t0.elapsed().as_secs_f64();
            println!(
                "step {step:>5}  train {loss:.4}  val {vl:.4}  ppl {:.1}  ({tok_s:.0} tok/s)",
                (vl as f64).exp()
            );
            curve.push(vec![step.to_string(), format!("{loss:.5}"), format!("{vl:.5}")]);
        }
    }
    let final_val = eval(&tr)?;
    println!(
        "\nfinal: val loss {final_val:.4}, ppl {:.2}, {:.1}s wall",
        (final_val as f64).exp(),
        t0.elapsed().as_secs_f64()
    );
    anyhow::ensure!(final_val < v0, "training failed to reduce validation loss");

    write_csv(
        &format!("e2e_train_{mech}_{preset}.csv"),
        &["step", "train_loss", "val_loss"],
        &curve,
    )?;
    let ckpt = std::path::PathBuf::from(format!("results/e2e_{mech}_{preset}.slayckpt"));
    tr.save(&ckpt)?;
    println!("checkpoint: {}", ckpt.display());
    Ok(())
}
