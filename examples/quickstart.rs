//! Quickstart — the public API in five minutes:
//! 1. evaluate the spherical Yat-kernel and its SLAY linearization,
//! 2. run SLAY attention over a sequence (batch + streaming),
//! 3. stand up the serving coordinator and push a few chunks through it.
//!
//! Run: `cargo run --release --example quickstart`

use slay::coordinator::request::AttendChunk;
use slay::coordinator::{Coordinator, CoordinatorConfig};
use slay::kernels::config::{Mechanism, SlayConfig};
use slay::kernels::slay::{QKFeatures, SlayFeatures};
use slay::kernels::{build, engine, yat};
use slay::math::linalg::Mat;
use slay::math::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- 1. the kernel ----------------------------------------------------
    let eps = 1e-3f32;
    println!("spherical Yat-kernel E_sph(x) = x^2 / (2+eps - 2x):");
    for x in [-0.5f32, 0.0, 0.5, 0.9] {
        println!("  E_sph({x:+.1}) = {:.4}", yat::e_sph(x, eps));
    }
    println!("  bound 1/eps = {:.0} attained at x = 1 (Prop. 3)\n", 1.0 / eps);

    // --- 2. SLAY linearized attention --------------------------------------
    let d = 32;
    let l = 256;
    let mut rng = Rng::new(0);
    let (q, k, v) = (
        Mat::randn(l, d, &mut rng),
        Mat::randn(l, d, &mut rng),
        Mat::randn(l, d, &mut rng),
    );

    let slay_op = build(&Mechanism::Slay(SlayConfig::default()), d, l)?;
    let y = slay_op.forward(q.view(), k.view(), v.view(), /*causal=*/ true, 0);
    println!(
        "SLAY causal attention over L={l}: output {}x{}, feature dim m={}",
        y.rows,
        y.cols,
        slay_op.feature_dim().unwrap()
    );

    // exact quadratic counterpart for comparison
    let exact_op = build(&Mechanism::YatSpherical { eps: 1e-3 }, d, l)?;
    let y_exact = exact_op.forward(q.view(), k.view(), v.view(), true, 0);
    println!(
        "rel-l2 vs exact spherical Yat attention: {:.3} (linear time vs O(L^2))\n",
        slay::math::stats::rel_l2(&y.data, &y_exact.data)
    );

    // --- 3. streaming sessions (the KV-cache analog) ------------------------
    // The AttentionBackend session API: prefill a context chunk, then decode
    // token by token against an opaque constant-size state.
    let mut state = slay_op.new_state(d);
    slay_op.prefill(&mut state, q.view(), k.view(), v.view())?;
    let mut y_last = vec![0.0f32; d];
    let (qd, kd, vd) = (
        Mat::randn(1, d, &mut rng),
        Mat::randn(1, d, &mut rng),
        Mat::randn(1, d, &mut rng),
    );
    slay_op.decode(&mut state, qd.row(0), kd.row(0), vd.row(0), &mut y_last)?;
    println!(
        "streaming state after {} tokens: {} bytes (constant in L); last-token output[0..4] = {:?}",
        state.len(),
        state.bytes(),
        &y_last[..4]
    );
    // the same raw machinery is still available one level down
    let feats = SlayFeatures::new(SlayConfig::default(), d)?;
    let mut raw = engine::StreamingState::new(feats.dim(), d);
    raw.append(feats.map_k(k.view(), 0).row(0), v.row(0));
    println!("raw StreamingState bytes: {}", raw.bytes());

    // --- 4. the serving coordinator -----------------------------------------
    let coord = Coordinator::start(CoordinatorConfig {
        d_head: d,
        d_v: d,
        workers: 2,
        ..CoordinatorConfig::default()
    })?;
    let seq = coord.create_sequence()?;
    // prefill then three decode steps
    coord.attend(AttendChunk {
        seq,
        q: Mat::randn(64, d, &mut rng),
        k: Mat::randn(64, d, &mut rng),
        v: Mat::randn(64, d, &mut rng),
    })?;
    for _ in 0..3 {
        let r = coord.attend(AttendChunk {
            seq,
            q: Mat::randn(1, d, &mut rng),
            k: Mat::randn(1, d, &mut rng),
            v: Mat::randn(1, d, &mut rng),
        })?;
        println!(
            "decode step: seq_len={} latency={:?}",
            r.seq_len, r.latency
        );
    }
    println!("\ncoordinator metrics: {}", coord.metrics().to_json().to_string());
    coord.shutdown()?;
    println!("quickstart OK");
    Ok(())
}
