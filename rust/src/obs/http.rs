//! Minimal `GET /metrics` HTTP listener for off-the-shelf Prometheus
//! scrapers (`--metrics-addr`). One polling thread, blocking per-request
//! I/O with short timeouts — a scrape endpoint, not a web server. The
//! serving planes (JSON lines / SLAYWIRE) are untouched; this is a side
//! door onto the same `Metrics`.

use crate::coordinator::Metrics;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Handle to a running metrics listener; dropping it stops the thread.
pub struct MetricsHttp {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsHttp {
    /// Bind `addr` (e.g. `127.0.0.1:9464`, port 0 for ephemeral) and serve
    /// `GET /metrics` as Prometheus text exposition.
    pub fn start(addr: &str, metrics: Arc<Metrics>) -> anyhow::Result<MetricsHttp> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("slay-metrics-http".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // Errors on a scrape socket are the scraper's
                            // problem; never take the listener down.
                            let _ = serve_one(stream, &metrics);
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(25));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(25)),
                    }
                }
            })?;
        crate::log_info!("metrics listener on http://{local}/metrics");
        Ok(MetricsHttp {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for MetricsHttp {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn serve_one(mut stream: TcpStream, metrics: &Metrics) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_millis(500)))?;
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    // Read enough for the request line; drain headers best-effort.
    let mut buf = [0u8; 2048];
    let mut used = 0usize;
    loop {
        if used == buf.len() || buf[..used].windows(2).any(|w| w == b"\r\n") {
            break;
        }
        match stream.read(&mut buf[used..]) {
            Ok(0) => break,
            Ok(n) => used += n,
            Err(_) => break,
        }
    }
    let req = String::from_utf8_lossy(&buf[..used]);
    let line = req.lines().next().unwrap_or("");
    let mut parts = line.split_whitespace();
    let (method, path) = (parts.next().unwrap_or(""), parts.next().unwrap_or(""));
    let (status, ctype, body) = if method == "GET" && (path == "/metrics" || path == "/") {
        (
            "200 OK",
            "text/plain; version=0.0.4; charset=utf-8",
            crate::obs::prom::render(metrics),
        )
    } else {
        ("404 Not Found", "text/plain", "not found\n".to_string())
    };
    let resp = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(resp.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn serves_prometheus_on_get_metrics() {
        let m = Arc::new(Metrics::new());
        m.submitted
            .fetch_add(7, std::sync::atomic::Ordering::Relaxed);
        let http = MetricsHttp::start("127.0.0.1:0", Arc::clone(&m)).unwrap();
        let resp = get(http.addr(), "/metrics");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
        assert!(resp.contains("text/plain; version=0.0.4"));
        assert!(resp.contains("slay_submitted_total 7"));
    }

    #[test]
    fn unknown_path_is_404_and_listener_survives() {
        let m = Arc::new(Metrics::new());
        let http = MetricsHttp::start("127.0.0.1:0", Arc::clone(&m)).unwrap();
        let resp = get(http.addr(), "/nope");
        assert!(resp.starts_with("HTTP/1.1 404"), "{resp}");
        // still serving after a bad request
        let resp = get(http.addr(), "/metrics");
        assert!(resp.starts_with("HTTP/1.1 200 OK"), "{resp}");
    }
}
