//! Lock-free log-linear latency histogram.
//!
//! Replaces the coordinator's mutex-guarded latency reservoir (whose
//! replacement index raced on the `completed` counter) with a fixed array
//! of `AtomicU64` buckets: `record` is two relaxed `fetch_add`s and an
//! integer log — zero allocation, zero locks, safe on every hot path.
//!
//! Layout: integer log-linear over **microseconds** with 4 sub-buckets per
//! octave (`SUB_BITS = 2`), so every bucket's width is ≤ 25% of its lower
//! bound (≈ 2 significant figures, per the paper-serving issue). 128
//! buckets cover 1 µs up to ~2 hours before the final clamp bucket —
//! double the issue's "~64 buckets" sketch, because 64 log-linear buckets
//! at 25% resolution only span ~4 decades and decode latencies here range
//! from single-digit µs (prefix-cache hits) to multi-second chaos-test
//! stalls. The deviation is deliberate: 1 KiB per histogram is still
//! nothing, and resolution is kept.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of buckets (see module docs for why 128, not 64).
pub const BUCKETS: usize = 128;
/// log2 of the sub-buckets per octave (4 ⇒ ≤ 25% relative bucket width).
const SUB_BITS: u32 = 2;
const SUB: u64 = 1 << SUB_BITS;

/// Bucket index for a latency of `us` microseconds.
///
/// Values `< 4` get exact unit buckets; above that, bucket `i` covers
/// `[2^k + s·2^(k-2), 2^k + (s+1)·2^(k-2))` for octave `k` and sub-bucket
/// `s ∈ {0..3}`. Everything past the table clamps into the last bucket.
#[inline]
pub fn bucket_index(us: u64) -> usize {
    let idx = if us < SUB {
        us as usize
    } else {
        let k = 63 - us.leading_zeros(); // floor(log2), ≥ SUB_BITS
        let sub = ((us >> (k - SUB_BITS)) & (SUB - 1)) as usize;
        SUB as usize + (k - SUB_BITS) as usize * SUB as usize + sub
    };
    idx.min(BUCKETS - 1)
}

/// `[lo, hi)` bounds in microseconds of bucket `idx` (inverse of
/// [`bucket_index`]). The final clamp bucket's `hi` is `u64::MAX`.
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    assert!(idx < BUCKETS);
    let i = idx as u64;
    if i < SUB {
        return (i, i + 1);
    }
    let k = (i - SUB) / SUB + SUB_BITS as u64;
    let sub = (i - SUB) % SUB;
    let step = 1u64 << (k - SUB_BITS as u64);
    let lo = (1u64 << k) + sub * step;
    if idx == BUCKETS - 1 {
        (lo, u64::MAX)
    } else {
        (lo, lo + step)
    }
}

/// Fixed-bucket lock-free histogram. All fields are relaxed atomics; a
/// snapshot read concurrent with writers may be off by in-flight records,
/// which is fine for monitoring.
pub struct Histo {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for Histo {
    fn default() -> Self {
        Histo::new()
    }
}

impl Histo {
    pub fn new() -> Self {
        Histo {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }

    /// Record a duration. Two relaxed `fetch_add`s — no locks, no allocs.
    #[inline]
    pub fn record(&self, d: Duration) {
        self.record_us(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Record a raw microsecond value.
    #[inline]
    pub fn record_us(&self, us: u64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    pub fn mean_ms(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us() as f64 / n as f64 / 1e3
        }
    }

    /// Quantile estimate in milliseconds, `q ∈ [0, 100]`.
    ///
    /// Walks the cumulative counts to the target rank `⌈q/100·n⌉` and
    /// returns the midpoint of the bucket holding that rank — within one
    /// bucket's relative error (≤ 25%, usually ≤ 12.5%) of the exact
    /// order statistic.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q / 100.0) * total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                let (lo, hi) = bucket_bounds(i);
                // The clamp bucket has no meaningful upper bound; report
                // its lower edge instead of a bogus midpoint.
                let hi = if i == BUCKETS - 1 { lo } else { hi };
                return (lo + hi) as f64 / 2.0 / 1e3;
            }
        }
        0.0 // unreachable while writers are quiescent
    }

    /// Per-bucket counts (for Prometheus cumulative-bucket rendering).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    #[test]
    fn bounds_invert_index_across_the_whole_table() {
        for idx in 0..BUCKETS {
            let (lo, hi) = bucket_bounds(idx);
            assert_eq!(bucket_index(lo), idx, "lo of bucket {idx}");
            if idx < BUCKETS - 1 {
                assert_eq!(bucket_index(hi - 1), idx, "hi-1 of bucket {idx}");
                assert_eq!(bucket_index(hi), idx + 1, "hi of bucket {idx}");
            }
        }
    }

    /// Property: every recorded value lands in a bucket whose bounds
    /// contain it.
    #[test]
    fn recorded_value_falls_inside_its_bucket_bounds() {
        let mut rng = Rng::new(41);
        for _ in 0..10_000 {
            // log-uniform over the full span, plus the small-integer edge
            let us = match rng.below(4) {
                0 => rng.below(8) as u64,
                1 => rng.below(4096) as u64,
                2 => rng.next_u64() % 10_000_000,        // ≤ 10 s
                _ => rng.next_u64() % (1u64 << 40),      // into the clamp
            };
            let idx = bucket_index(us);
            let (lo, hi) = bucket_bounds(idx);
            assert!(
                lo <= us && us < hi,
                "us={us} idx={idx} bounds=[{lo},{hi})"
            );
        }
    }

    /// Property: bucket widths stay ≤ 25% of their lower bound (the
    /// "~2 significant figures" promise), for all non-degenerate buckets.
    #[test]
    fn relative_bucket_width_is_bounded() {
        for idx in SUB as usize..BUCKETS - 1 {
            let (lo, hi) = bucket_bounds(idx);
            assert!(
                (hi - lo) as f64 <= 0.25 * lo as f64 + 1.0,
                "bucket {idx}: [{lo},{hi})"
            );
        }
    }

    /// Property: the quantile estimate's bucket contains the exact target
    /// order statistic, and on a smooth distribution the estimate is
    /// within one bucket's relative error of `math::stats::percentile`.
    #[test]
    fn quantiles_track_exact_percentiles_within_bucket_error() {
        let mut rng = Rng::new(42);
        let h = Histo::new();
        let n = 4096usize;
        let mut vals: Vec<u64> = (0..n)
            .map(|_| {
                // log-uniform in [16 µs, ~1 s): smooth, spans many octaves
                let e = rng.range(4.0, 20.0);
                2f64.powf(e) as u64
            })
            .collect();
        for &v in &vals {
            h.record_us(v);
        }
        vals.sort_unstable();
        let ms: Vec<f64> = vals.iter().map(|&v| v as f64 / 1e3).collect();
        for q in [50.0, 90.0, 99.0, 99.9] {
            let est = h.quantile_ms(q);
            // (a) exact-by-construction: the estimate's bucket holds the
            // target-rank sample.
            let target = ((q / 100.0) * n as f64).ceil().max(1.0) as usize;
            let rank_val = vals[target - 1];
            let (lo, hi) = bucket_bounds(bucket_index((est * 1e3) as u64));
            assert!(
                lo <= rank_val && rank_val < hi,
                "q={q}: rank val {rank_val} outside est bucket [{lo},{hi})"
            );
            // (b) cross-check against the interpolating exact percentile:
            // within one bucket's relative width (25%) plus interpolation
            // slack on a 4096-sample smooth distribution.
            let exact = crate::math::stats::percentile(&ms, q);
            let rel = (est - exact).abs() / exact.max(1e-9);
            assert!(rel <= 0.25, "q={q}: est={est}ms exact={exact}ms rel={rel}");
        }
    }

    /// Property: concurrent recording is lossless — total count and the
    /// bucket-sum both equal the number of records issued.
    #[test]
    fn concurrent_records_are_all_counted() {
        use std::sync::Arc;
        let h = Arc::new(Histo::new());
        let threads = 8;
        let per = 5_000u64;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    let mut rng = Rng::new(100 + t);
                    for _ in 0..per {
                        h.record_us(rng.next_u64() % 1_000_000);
                    }
                })
            })
            .collect();
        for jh in handles {
            jh.join().unwrap();
        }
        let expect = threads * per;
        assert_eq!(h.count(), expect);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), expect);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histo::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile_ms(50.0), 0.0);
        assert_eq!(h.mean_ms(), 0.0);
    }

    #[test]
    fn mean_matches_recorded_sum() {
        let h = Histo::new();
        h.record(Duration::from_millis(10));
        h.record(Duration::from_millis(30));
        assert!((h.mean_ms() - 20.0).abs() < 0.01, "mean={}", h.mean_ms());
    }
}
