//! Prometheus text exposition (format 0.0.4), hand-rolled like the rest of
//! the stack — zero dependencies.
//!
//! Rendering pulls counters and gauges from the *same*
//! `Snapshot::counter_fields()` / `Snapshot::gauge_fields()` lists that
//! feed the JSON output, so a field added to `Snapshot` appears in both
//! formats or in neither — the exposition-completeness test in
//! `coordinator::metrics` pins that invariant.
//!
//! Metric naming: counters are `slay_<field>_total`, gauges `slay_<field>`,
//! stage latencies one histogram family
//! `slay_stage_latency_seconds{class=…,stage=…}`, per-shard stats gauges/
//! counters labelled `{shard=…}`.

use crate::coordinator::Metrics;
use crate::obs::{bucket_bounds, Class, Stage};
use std::fmt::Write as _;

/// Render the full metrics state as Prometheus text exposition.
pub fn render(m: &Metrics) -> String {
    let snap = m.snapshot();
    let mut out = String::with_capacity(8192);

    for (name, v) in snap.counter_fields() {
        let _ = writeln!(out, "# TYPE slay_{name}_total counter");
        let _ = writeln!(out, "slay_{name}_total {v}");
    }
    for (name, v) in snap.gauge_fields() {
        let _ = writeln!(out, "# TYPE slay_{name} gauge");
        let _ = writeln!(out, "slay_{name} {}", fmt_f64(v));
    }

    // Info-style metric: the constant 1 carries the resolved SIMD backend
    // as a label (ADR-010), the conventional way to expose a string.
    let _ = writeln!(out, "# TYPE slay_simd_backend_info gauge");
    let _ = writeln!(
        out,
        "slay_simd_backend_info{{backend=\"{}\"}} 1",
        snap.simd_backend
    );

    // Stage latency histograms: one family, labelled by class and stage.
    // Only non-empty series are emitted; within a series only buckets that
    // advance the cumulative count appear (plus the mandatory +Inf).
    let mut wrote_type = false;
    for c in Class::ALL {
        for s in Stage::ALL {
            let h = m.obs.stage(c, s);
            let total = h.count();
            if total == 0 {
                continue;
            }
            if !wrote_type {
                let _ = writeln!(out, "# TYPE slay_stage_latency_seconds histogram");
                wrote_type = true;
            }
            let labels = format!("class=\"{}\",stage=\"{}\"", c.name(), s.name());
            let mut cum = 0u64;
            for (i, n) in h.bucket_counts().into_iter().enumerate() {
                if n == 0 {
                    continue;
                }
                cum += n;
                let (_, hi) = bucket_bounds(i);
                let _ = writeln!(
                    out,
                    "slay_stage_latency_seconds_bucket{{{labels},le=\"{}\"}} {cum}",
                    fmt_f64(hi as f64 / 1e6)
                );
            }
            let _ = writeln!(
                out,
                "slay_stage_latency_seconds_bucket{{{labels},le=\"+Inf\"}} {total}"
            );
            let _ = writeln!(
                out,
                "slay_stage_latency_seconds_sum{{{labels}}} {}",
                fmt_f64(h.sum_us() as f64 / 1e6)
            );
            let _ = writeln!(out, "slay_stage_latency_seconds_count{{{labels}}} {total}");
        }
    }

    // Per-shard stats (absent until the coordinator installs them).
    let shards = m.obs.shards();
    if !shards.is_empty() {
        use std::sync::atomic::Ordering;
        let gauges: [(&str, fn(&crate::obs::ShardStats) -> u64); 4] = [
            ("shard_queue_depth", |s| s.queue_depth.load(Ordering::Relaxed)),
            ("shard_resident_seqs", |s| s.resident_seqs.load(Ordering::Relaxed)),
            ("shard_resident_bytes", |s| s.resident_bytes.load(Ordering::Relaxed)),
            ("shard_spilled_seqs", |s| s.spilled_seqs.load(Ordering::Relaxed)),
        ];
        for (name, get) in gauges {
            let _ = writeln!(out, "# TYPE slay_{name} gauge");
            for (i, s) in shards.iter().enumerate() {
                let _ = writeln!(out, "slay_{name}{{shard=\"{i}\"}} {}", get(s));
            }
        }
        let counters: [(&str, fn(&crate::obs::ShardStats) -> u64); 2] = [
            ("shard_items", |s| s.items.load(Ordering::Relaxed)),
            ("shard_batches", |s| s.batches.load(Ordering::Relaxed)),
        ];
        for (name, get) in counters {
            let _ = writeln!(out, "# TYPE slay_{name}_total counter");
            for (i, s) in shards.iter().enumerate() {
                let _ = writeln!(out, "slay_{name}_total{{shard=\"{i}\"}} {}", get(s));
            }
        }
    }

    // Event-ring depth: retained vs ever-pushed (gap = evicted).
    let _ = writeln!(out, "# TYPE slay_events_retained gauge");
    let _ = writeln!(out, "slay_events_retained {}", m.obs.events.len());
    let _ = writeln!(out, "# TYPE slay_events_total counter");
    let _ = writeln!(out, "slay_events_total {}", m.obs.events.total());

    out
}

/// Prometheus float formatting: plain decimal, no exponent surprises for
/// the magnitudes we emit; integers render without a trailing `.0`.
fn fmt_f64(v: f64) -> String {
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn populated_metrics() -> Metrics {
        use std::sync::atomic::Ordering;
        use std::time::Duration;
        let m = Metrics::new();
        m.submitted.fetch_add(5, Ordering::Relaxed);
        m.completed.fetch_add(4, Ordering::Relaxed);
        m.active_connections.store(2, Ordering::Relaxed);
        m.record_latency(Duration::from_millis(3));
        m.obs.init_shards(2);
        m.obs
            .shard(0)
            .unwrap()
            .queue_depth
            .store(1, Ordering::Relaxed);
        for (c, s, us) in [
            (Class::Decode, Stage::Queue, 120),
            (Class::Decode, Stage::Compute, 900),
            (Class::Decode, Stage::Compute, 90_000),
            (Class::Prefill, Stage::Total, 2_500),
        ] {
            m.obs.stage(c, s).record_us(us);
        }
        m.obs.events.push("snapshot", "test".into());
        m
    }

    /// Structural validity: every sample line parses, every sample name
    /// has a preceding `# TYPE`, histogram buckets are cumulative
    /// monotone and every histogram series carries `+Inf`, `_sum`,
    /// `_count` with `+Inf == _count`.
    #[test]
    fn output_is_valid_text_exposition() {
        let m = populated_metrics();
        let text = render(&m);
        let mut typed: HashMap<String, String> = HashMap::new();
        // per-series histogram bookkeeping
        let mut last_bucket: HashMap<String, (f64, u64)> = HashMap::new();
        let mut inf: HashMap<String, u64> = HashMap::new();
        let mut count: HashMap<String, u64> = HashMap::new();
        for line in text.lines() {
            assert!(!line.trim().is_empty(), "blank line in exposition");
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut it = rest.split_whitespace();
                let name = it.next().unwrap().to_string();
                let kind = it.next().unwrap().to_string();
                assert!(
                    ["counter", "gauge", "histogram"].contains(&kind.as_str()),
                    "bad TYPE kind: {line}"
                );
                typed.insert(name, kind);
                continue;
            }
            assert!(!line.starts_with('#'), "unexpected comment: {line}");
            // sample line: name{labels}? value
            let (name_labels, value) =
                line.rsplit_once(' ').unwrap_or_else(|| panic!("no value: {line}"));
            value
                .parse::<f64>()
                .unwrap_or_else(|_| panic!("bad value in: {line}"));
            let (name, labels) = match name_labels.split_once('{') {
                Some((n, l)) => {
                    assert!(l.ends_with('}'), "unclosed labels: {line}");
                    (n.to_string(), l.trim_end_matches('}').to_string())
                }
                None => (name_labels.to_string(), String::new()),
            };
            // the sample's family must have been TYPEd (histograms via
            // their base name)
            let base = name
                .strip_suffix("_bucket")
                .or_else(|| name.strip_suffix("_sum"))
                .or_else(|| name.strip_suffix("_count"))
                .filter(|b| typed.get(*b).map(String::as_str) == Some("histogram"))
                .unwrap_or(&name);
            assert!(typed.contains_key(base), "sample before TYPE: {line}");
            if typed.get(base).map(String::as_str) == Some("histogram") {
                let series: String = labels
                    .split(',')
                    .filter(|kv| !kv.starts_with("le="))
                    .collect::<Vec<_>>()
                    .join(",");
                let key = format!("{base}{{{series}}}");
                if name.ends_with("_bucket") {
                    let le = labels
                        .split(',')
                        .find_map(|kv| kv.strip_prefix("le="))
                        .unwrap_or_else(|| panic!("bucket without le: {line}"))
                        .trim_matches('"');
                    let v: u64 = value.parse().unwrap();
                    if le == "+Inf" {
                        inf.insert(key, v);
                    } else {
                        let le: f64 = le.parse().unwrap();
                        if let Some((ple, pv)) = last_bucket.get(&key) {
                            assert!(le > *ple, "le not increasing: {line}");
                            assert!(v >= *pv, "bucket not cumulative: {line}");
                        }
                        last_bucket.insert(key, (le, v));
                    }
                } else if name.ends_with("_count") {
                    count.insert(key, value.parse().unwrap());
                }
            }
        }
        assert!(!typed.is_empty() && !inf.is_empty());
        for (k, c) in &count {
            assert_eq!(inf.get(k), Some(c), "+Inf != _count for {k}");
        }
        for (k, (_, v)) in &last_bucket {
            assert!(inf[k] >= *v, "+Inf below last bucket for {k}");
        }
    }

    #[test]
    fn stage_series_and_shard_series_present() {
        let m = populated_metrics();
        let text = render(&m);
        assert!(text.contains(
            "slay_stage_latency_seconds_count{class=\"decode\",stage=\"compute\"} 2"
        ));
        assert!(text.contains("slay_shard_queue_depth{shard=\"0\"} 1"));
        assert!(text.contains("slay_shard_queue_depth{shard=\"1\"} 0"));
        assert!(text.contains("slay_events_retained 1"));
        assert!(text.contains("slay_submitted_total 5"));
        assert!(text.contains("slay_active_connections 2"));
    }
}
