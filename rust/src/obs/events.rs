//! Bounded in-memory ring of structured serving events.
//!
//! The fault layer (ADR-008) surfaces incidents only as counters; the ring
//! keeps the *last K* incidents with timestamps and context so "what just
//! happened" is answerable post-hoc over `{"op":"events"}` without log
//! scraping. Pushes are rare (restarts, poisons, sheds, protocol errors —
//! never the per-chunk path), so a short mutexed `VecDeque` is fine; the
//! hot path never touches it.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Default ring capacity (events retained).
pub const RING_CAP: usize = 512;

/// One structured event. `seq` is a monotonically increasing id that keeps
/// counting after old events are evicted, so a consumer can detect gaps.
#[derive(Clone, Debug)]
pub struct Event {
    pub seq: u64,
    /// Milliseconds since the ring (i.e. the coordinator) was created.
    pub t_ms: f64,
    /// Wall-clock milliseconds since the Unix epoch (scraper-friendly).
    pub unix_ms: u64,
    /// Stable machine-readable kind, e.g. `worker_restart`.
    pub kind: &'static str,
    /// Human-readable context (shard id, seq id, error text …).
    pub detail: String,
}

impl Event {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("seq", Json::Num(self.seq as f64)),
            ("t_ms", Json::Num(self.t_ms)),
            ("unix_ms", Json::Num(self.unix_ms as f64)),
            ("kind", Json::Str(self.kind.to_string())),
            ("detail", Json::Str(self.detail.clone())),
        ])
    }
}

/// Fixed-capacity event ring; oldest events are dropped on overflow.
pub struct EventRing {
    cap: usize,
    next_seq: AtomicU64,
    start: Instant,
    inner: Mutex<VecDeque<Event>>,
}

impl Default for EventRing {
    fn default() -> Self {
        EventRing::new(RING_CAP)
    }
}

impl EventRing {
    pub fn new(cap: usize) -> Self {
        EventRing {
            cap: cap.max(1),
            next_seq: AtomicU64::new(0),
            start: Instant::now(),
            inner: Mutex::new(VecDeque::with_capacity(cap.max(1).min(64))),
        }
    }

    /// Append an event, evicting the oldest if the ring is full.
    pub fn push(&self, kind: &'static str, detail: String) {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let ev = Event {
            seq,
            t_ms: self.start.elapsed().as_secs_f64() * 1e3,
            unix_ms: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_millis() as u64)
                .unwrap_or(0),
            kind,
            detail,
        };
        let mut q = self.inner.lock().unwrap();
        if q.len() == self.cap {
            q.pop_front();
        }
        q.push_back(ev);
    }

    /// Last `n` events, oldest first.
    pub fn tail(&self, n: usize) -> Vec<Event> {
        let q = self.inner.lock().unwrap();
        let skip = q.len().saturating_sub(n);
        q.iter().skip(skip).cloned().collect()
    }

    /// Events currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total events ever pushed (retained + evicted).
    pub fn total(&self) -> u64 {
        self.next_seq.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_stays_bounded_and_keeps_newest() {
        let r = EventRing::new(8);
        for i in 0..80 {
            r.push("test", format!("ev{i}"));
        }
        assert_eq!(r.len(), 8);
        assert_eq!(r.total(), 80);
        let tail = r.tail(100);
        assert_eq!(tail.len(), 8);
        // newest retained, seq ids contiguous and increasing
        assert_eq!(tail.first().unwrap().seq, 72);
        assert_eq!(tail.last().unwrap().seq, 79);
        assert_eq!(tail.last().unwrap().detail, "ev79");
    }

    #[test]
    fn tail_respects_n() {
        let r = EventRing::new(16);
        for i in 0..10 {
            r.push("k", format!("{i}"));
        }
        let t = r.tail(3);
        assert_eq!(t.len(), 3);
        assert_eq!(t[0].detail, "7");
        assert_eq!(t[2].detail, "9");
    }

    #[test]
    fn event_serializes() {
        let r = EventRing::new(4);
        r.push("worker_restart", "shard 3".to_string());
        let j = r.tail(1)[0].to_json();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("worker_restart"));
        assert_eq!(j.get("detail").unwrap().as_str(), Some("shard 3"));
        assert!(j.get("t_ms").is_some() && j.get("unix_ms").is_some());
    }
}
