//! Serving observability layer: lock-free stage histograms, per-shard
//! gauges, a structured event ring, Prometheus text exposition and an
//! optional scrape listener.
//!
//! The request lifecycle is stamped at six monotonic ticks:
//!
//! ```text
//! submit ── shard-enqueue ── batch-formed ── compute-start ── compute-end ── reply-flushed
//!    └── queue_wait ──┘└─ batch_form ─┘└── compute ──┘└── reply_flush ──┘
//!    └───────────────────────────── total ─────────────────────────────┘
//! ```
//!
//! (queue_wait spans enqueue→batch-formed; the submit→enqueue gap is
//! sub-microsecond validation and is folded into `total` only.) Each gap
//! feeds one [`Histo`] per request [`Class`] × [`Stage`], so `{"op":
//! "metrics"}` can attribute a millisecond to queueing vs batching vs
//! compute vs socket flush, per traffic class, at p50/p90/p99/p99.9.
//!
//! Everything here is a pure side channel: replies are byte-identical with
//! the layer enabled or disabled (`set_enabled(false)` is the no-record
//! baseline the `serve_obs` bench gates against).

pub mod events;
pub mod hist;
pub mod http;
pub mod prom;

pub use events::{Event, EventRing, RING_CAP};
pub use hist::{bucket_bounds, bucket_index, Histo, BUCKETS};
pub use http::MetricsHttp;

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

/// Request class — which kind of traffic a sample belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    /// Multi-token prefill chunks (including prefix-cache hits).
    Prefill,
    /// Single-token decode chunks served individually.
    Decode,
    /// Decode rows advanced inside a fused cross-session wave (ADR-005).
    FusedWave,
    /// Session fork operations (ADR-006).
    Fork,
    /// Control-plane ops (create / release / metrics / snapshot / …).
    Control,
}

impl Class {
    pub const ALL: [Class; 5] = [
        Class::Prefill,
        Class::Decode,
        Class::FusedWave,
        Class::Fork,
        Class::Control,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Class::Prefill => "prefill",
            Class::Decode => "decode",
            Class::FusedWave => "fused_wave",
            Class::Fork => "fork",
            Class::Control => "control",
        }
    }
}

/// Lifecycle stage — which gap between ticks a sample measures.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// shard-enqueue → batch-formed (time parked in the shard queue).
    Queue,
    /// batch-formed → compute-start (scheduling/ordering inside a batch).
    Batch,
    /// compute-start → compute-end (backend kernel time).
    Compute,
    /// compute-end → reply-flushed (completion routing + socket write).
    Flush,
    /// submit → reply-flushed (end-to-end).
    Total,
}

impl Stage {
    pub const ALL: [Stage; 5] = [
        Stage::Queue,
        Stage::Batch,
        Stage::Compute,
        Stage::Flush,
        Stage::Total,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Stage::Queue => "queue_wait",
            Stage::Batch => "batch_form",
            Stage::Compute => "compute",
            Stage::Flush => "reply_flush",
            Stage::Total => "total",
        }
    }
}

/// In-memory trace ticks carried on a completed `AttendResult` from the
/// worker to the front end that flushes the reply. Never serialized — the
/// wire encoders don't read it, which is what keeps replies byte-identical
/// with observability on or off.
#[derive(Clone, Copy, Debug)]
pub struct ObsTicks {
    pub class: Class,
    /// Tick 0: request entered `submit_with`.
    pub submit: Instant,
    /// Tick 4: backend compute finished (= tick 3 for compute-skipped
    /// prefix-cache hits).
    pub compute_end: Instant,
}

/// Per-shard (per-worker) live gauges and counters. Gauges are `store`d by
/// their single writer (queue depth excepted — it is inc'd at submit and
/// dec'd at dequeue); counters accumulate.
#[derive(Default)]
pub struct ShardStats {
    /// Items currently sitting in the shard's bounded queue.
    pub queue_depth: AtomicU64,
    /// Sessions resident in the shard's store (gauge).
    pub resident_seqs: AtomicU64,
    /// Bytes held by resident session state (gauge).
    pub resident_bytes: AtomicU64,
    /// Sessions paged out to the spill tier (gauge).
    pub spilled_seqs: AtomicU64,
    /// Work items this shard has processed (counter).
    pub items: AtomicU64,
    /// Batches this shard has formed (counter).
    pub batches: AtomicU64,
}

impl ShardStats {
    pub fn to_json(&self, shard: usize) -> crate::util::json::Json {
        use crate::util::json::Json;
        let n = |a: &AtomicU64| Json::Num(a.load(Ordering::Relaxed) as f64);
        Json::obj(vec![
            ("shard", Json::Num(shard as f64)),
            ("queue_depth", n(&self.queue_depth)),
            ("resident_seqs", n(&self.resident_seqs)),
            ("resident_bytes", n(&self.resident_bytes)),
            ("spilled_seqs", n(&self.spilled_seqs)),
            ("items", n(&self.items)),
            ("batches", n(&self.batches)),
        ])
    }
}

const N_CLASSES: usize = Class::ALL.len();
const N_STAGES: usize = Stage::ALL.len();

/// The observability state owned by `coordinator::Metrics`: one histogram
/// per class × stage, the legacy end-to-end request histogram, per-shard
/// stats and the event ring.
pub struct Obs {
    stages: [Histo; N_CLASSES * N_STAGES],
    /// End-to-end enqueue→reply histogram feeding the legacy
    /// `latency_p50_ms` / `latency_p95_ms` / `latency_mean_ms` keys.
    pub request: Histo,
    pub events: EventRing,
    shards: OnceLock<Vec<ShardStats>>,
    enabled: AtomicBool,
}

impl Default for Obs {
    fn default() -> Self {
        Obs::new()
    }
}

impl Obs {
    pub fn new() -> Self {
        Obs {
            stages: std::array::from_fn(|_| Histo::new()),
            request: Histo::new(),
            events: EventRing::default(),
            shards: OnceLock::new(),
            enabled: AtomicBool::new(true),
        }
    }

    /// Latency recording on/off. Events stay on (they are rare and carry
    /// incident context); only the per-chunk histogram path is gated, so
    /// the `serve_obs` bench can measure a true no-record baseline.
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn stage(&self, c: Class, s: Stage) -> &Histo {
        &self.stages[c as usize * N_STAGES + s as usize]
    }

    /// Record one stage sample (no-op when disabled).
    #[inline]
    pub fn record_stage(&self, c: Class, s: Stage, d: Duration) {
        if self.enabled() {
            self.stage(c, s).record(d);
        }
    }

    /// Record the legacy end-to-end request latency (no-op when disabled).
    #[inline]
    pub fn record_request(&self, d: Duration) {
        if self.enabled() {
            self.request.record(d);
        }
    }

    /// Ticks 5: the reply left the front end's socket. Records the
    /// `reply_flush` and `total` stages from the ticks a worker stamped on
    /// the result; a `None` trace (error replies) records nothing.
    #[inline]
    pub fn record_reply_flushed(&self, trace: Option<&ObsTicks>) {
        let Some(t) = trace else { return };
        if !self.enabled() {
            return;
        }
        let now = Instant::now();
        self.record_stage(t.class, Stage::Flush, now.saturating_duration_since(t.compute_end));
        self.record_stage(t.class, Stage::Total, now.saturating_duration_since(t.submit));
    }

    /// Install the per-shard stat blocks (called once by
    /// `Coordinator::start` with the worker count; later calls are no-ops).
    pub fn init_shards(&self, n: usize) {
        let _ = self
            .shards
            .set((0..n).map(|_| ShardStats::default()).collect());
    }

    pub fn shard(&self, i: usize) -> Option<&ShardStats> {
        self.shards.get().and_then(|v| v.get(i))
    }

    pub fn shards(&self) -> &[ShardStats] {
        self.shards.get().map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Nested `{class: {stage: {count,p50_ms,p90_ms,p99_ms,p999_ms,
    /// mean_ms}}}` JSON for `{"op":"metrics"}`; classes/stages with no
    /// samples are omitted.
    pub fn stages_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut classes = Vec::new();
        for c in Class::ALL {
            let mut stages = Vec::new();
            for s in Stage::ALL {
                let h = self.stage(c, s);
                if h.count() == 0 {
                    continue;
                }
                stages.push((
                    s.name(),
                    Json::obj(vec![
                        ("count", Json::Num(h.count() as f64)),
                        ("p50_ms", Json::Num(h.quantile_ms(50.0))),
                        ("p90_ms", Json::Num(h.quantile_ms(90.0))),
                        ("p99_ms", Json::Num(h.quantile_ms(99.0))),
                        ("p999_ms", Json::Num(h.quantile_ms(99.9))),
                        ("mean_ms", Json::Num(h.mean_ms())),
                    ]),
                ));
            }
            if !stages.is_empty() {
                classes.push((c.name(), Json::obj(stages)));
            }
        }
        Json::obj(classes)
    }

    /// `[{shard, queue_depth, …}, …]` JSON for `detail:"shards"`.
    pub fn shards_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::Arr(
            self.shards()
                .iter()
                .enumerate()
                .map(|(i, s)| s.to_json(i))
                .collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_indexing_is_bijective() {
        // every (class, stage) pair maps to a distinct histogram
        let o = Obs::new();
        for c in Class::ALL {
            for s in Stage::ALL {
                o.record_stage(c, s, Duration::from_micros(7));
            }
        }
        for c in Class::ALL {
            for s in Stage::ALL {
                assert_eq!(o.stage(c, s).count(), 1, "{}/{}", c.name(), s.name());
            }
        }
    }

    #[test]
    fn disabled_obs_records_nothing() {
        let o = Obs::new();
        o.set_enabled(false);
        o.record_stage(Class::Decode, Stage::Compute, Duration::from_millis(1));
        o.record_request(Duration::from_millis(1));
        assert_eq!(o.stage(Class::Decode, Stage::Compute).count(), 0);
        assert_eq!(o.request.count(), 0);
        o.set_enabled(true);
        o.record_request(Duration::from_millis(1));
        assert_eq!(o.request.count(), 1);
    }

    #[test]
    fn stages_json_omits_empty_cells() {
        let o = Obs::new();
        o.record_stage(Class::Decode, Stage::Compute, Duration::from_millis(2));
        let j = o.stages_json();
        assert!(j.get("decode").is_some());
        assert!(j.get("prefill").is_none());
        let d = j.get("decode").unwrap();
        assert!(d.get("compute").is_some());
        assert!(d.get("queue_wait").is_none());
        let c = d.get("compute").unwrap();
        for k in ["count", "p50_ms", "p90_ms", "p99_ms", "p999_ms", "mean_ms"] {
            assert!(c.get(k).is_some(), "missing {k}");
        }
    }

    #[test]
    fn shard_stats_init_and_serialize() {
        let o = Obs::new();
        assert!(o.shards().is_empty());
        o.init_shards(3);
        o.init_shards(9); // later init is a no-op
        assert_eq!(o.shards().len(), 3);
        o.shard(1).unwrap().queue_depth.store(4, Ordering::Relaxed);
        o.shard(1).unwrap().items.fetch_add(10, Ordering::Relaxed);
        let j = o.shards_json();
        if let crate::util::json::Json::Arr(a) = &j {
            assert_eq!(a.len(), 3);
            assert_eq!(a[1].get("queue_depth").unwrap().as_usize(), Some(4));
            assert_eq!(a[1].get("items").unwrap().as_usize(), Some(10));
            assert_eq!(a[1].get("shard").unwrap().as_usize(), Some(1));
        } else {
            panic!("shards_json not an array");
        }
    }
}
