//! The AOT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py` (build-time only) and executes them on the PJRT
//! CPU client from the Rust hot path. Python never runs at request time.
//!
//! * [`manifest`] — the artifact contract (shapes, dtypes, param order).
//! * [`executor`] — compile + execute with positional manifest checking.
//! * [`checkpoint`] — parameter snapshots crossing train → serve.
//! * [`Registry`] — process-wide compile cache.

pub mod checkpoint;
pub mod executor;
pub mod manifest;

use executor::Executable;
use manifest::Manifest;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

/// Compile-once cache over manifest artifacts.
///
/// `!Send` by design: PJRT objects are `Rc`-based, so the registry lives on
/// a single model-executor thread (see [`executor::with_client`]). The
/// coordinator communicates with it over channels.
pub struct Registry {
    pub manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<Executable>>>,
}

impl Registry {
    /// Open the artifacts directory (default `./artifacts`).
    pub fn open_default() -> anyhow::Result<Registry> {
        Self::open(&Manifest::default_dir())
    }

    pub fn open(dir: &std::path::Path) -> anyhow::Result<Registry> {
        Ok(Registry { manifest: Manifest::load(dir)?, cache: RefCell::new(BTreeMap::new()) })
    }

    /// Get (compiling on first use) an executable by artifact name.
    pub fn get(&self, name: &str) -> anyhow::Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let entry = self.manifest.get(name)?;
        let exe = Rc::new(Executable::load(entry)?);
        self.cache
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Artifact names currently compiled.
    pub fn compiled(&self) -> Vec<String> {
        self.cache.borrow().keys().cloned().collect()
    }
}
