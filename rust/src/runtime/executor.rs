//! PJRT execution of AOT artifacts: load HLO text, compile once on the CPU
//! client, execute with host tensors or device-resident buffers.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** is the
//! interchange format (jax ≥0.5 protos are rejected by xla_extension
//! 0.5.1), lowering used `return_tuple=True` so every artifact returns one
//! tuple we decompose positionally against the manifest.

use super::manifest::{ArtifactEntry, DType, TensorSpec};
use std::cell::OnceCell;

/// Host tensor values crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
            TensorData::U32(_) => DType::U32,
        }
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            TensorData::F32(v) => Ok(v),
            other => anyhow::bail!("expected f32 tensor, got {:?}", other.dtype()),
        }
    }

    /// Scalar f32 accessor (loss outputs).
    pub fn scalar_f32(&self) -> anyhow::Result<f32> {
        let v = self.as_f32()?;
        anyhow::ensure!(v.len() == 1, "expected scalar, got {} elements", v.len());
        Ok(v[0])
    }

    fn to_literal(&self, spec: &TensorSpec) -> anyhow::Result<xla::Literal> {
        anyhow::ensure!(
            self.len() == spec.elements(),
            "tensor '{}': {} elements but spec {:?} wants {}",
            spec.name,
            self.len(),
            spec.shape,
            spec.elements()
        );
        anyhow::ensure!(
            self.dtype() == spec.dtype,
            "tensor '{}': dtype mismatch",
            spec.name
        );
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match self {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
            TensorData::U32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal, spec: &TensorSpec) -> anyhow::Result<TensorData> {
        Ok(match spec.dtype {
            DType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            DType::I32 => TensorData::I32(lit.to_vec::<i32>()?),
            DType::U32 => TensorData::U32(lit.to_vec::<u32>()?),
        })
    }
}

/// Per-thread PJRT CPU client.
///
/// The `xla` crate's `PjRtClient` is `Rc`-based (non-atomic refcounts), so
/// PJRT objects are **thread-bound by construction**: `Executable` is
/// deliberately `!Send`, and the coordinator gives the whole runtime to one
/// dedicated model-executor thread (the vLLM engine-thread pattern) that
/// workers talk to over channels. XLA's own intra-op thread pool still
/// parallelizes the compute.
pub fn with_client<T>(
    f: impl FnOnce(&xla::PjRtClient) -> anyhow::Result<T>,
) -> anyhow::Result<T> {
    thread_local! {
        static CLIENT: OnceCell<xla::PjRtClient> = const { OnceCell::new() };
    }
    CLIENT.with(|cell| {
        if cell.get().is_none() {
            let c =
                xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("pjrt cpu client: {e}"))?;
            let _ = cell.set(c);
        }
        f(cell.get().unwrap())
    })
}

/// A compiled artifact bound to its manifest entry. `!Send`: lives on the
/// thread that compiled it.
pub struct Executable {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Load + compile an artifact (slow: run once, cache).
    pub fn load(entry: &ArtifactEntry) -> anyhow::Result<Executable> {
        let path = entry
            .path
            .to_str()
            .ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = with_client(|c| {
            c.compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {}: {e}", entry.name))
        })?;
        crate::log_debug!("compiled artifact {}", entry.name);
        Ok(Executable { entry: entry.clone(), exe })
    }

    /// Execute with host tensors, returning host tensors (manifest-checked).
    pub fn run(&self, inputs: &[TensorData]) -> anyhow::Result<Vec<TensorData>> {
        anyhow::ensure!(
            inputs.len() == self.entry.inputs.len(),
            "{}: {} inputs given, manifest declares {}",
            self.entry.name,
            inputs.len(),
            self.entry.inputs.len()
        );
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(self.entry.inputs.iter())
            .map(|(t, s)| t.to_literal(s))
            .collect::<anyhow::Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {}: {e}", self.entry.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching result: {e}"))?;
        self.decompose(tuple)
    }

    fn decompose(&self, tuple: xla::Literal) -> anyhow::Result<Vec<TensorData>> {
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {}: {e}", self.entry.name))?;
        anyhow::ensure!(
            parts.len() == self.entry.outputs.len(),
            "{}: got {} outputs, manifest declares {}",
            self.entry.name,
            parts.len(),
            self.entry.outputs.len()
        );
        parts
            .iter()
            .zip(self.entry.outputs.iter())
            .map(|(lit, spec)| TensorData::from_literal(lit, spec))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;

    fn spec(name: &str, shape: &[usize], dtype: DType) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec(), dtype }
    }

    #[test]
    fn tensor_roundtrip_via_literal() {
        let t = TensorData::F32(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let s = spec("x", &[2, 3], DType::F32);
        let lit = t.to_literal(&s).unwrap();
        let back = TensorData::from_literal(&lit, &s).unwrap();
        assert_eq!(back.as_f32().unwrap(), t.as_f32().unwrap());
    }

    #[test]
    fn int_tensor_roundtrip() {
        let t = TensorData::I32(vec![-1, 0, 7, 42]);
        let s = spec("tok", &[4], DType::I32);
        let lit = t.to_literal(&s).unwrap();
        match TensorData::from_literal(&lit, &s).unwrap() {
            TensorData::I32(v) => assert_eq!(v, vec![-1, 0, 7, 42]),
            _ => panic!("wrong dtype"),
        }
    }

    #[test]
    fn shape_mismatch_rejected() {
        let t = TensorData::F32(vec![1.0, 2.0]);
        let s = spec("x", &[3], DType::F32);
        assert!(t.to_literal(&s).is_err());
    }

    #[test]
    fn dtype_mismatch_rejected() {
        let t = TensorData::I32(vec![1, 2]);
        let s = spec("x", &[2], DType::F32);
        assert!(t.to_literal(&s).is_err());
    }

    #[test]
    fn scalar_accessor() {
        assert_eq!(TensorData::F32(vec![2.5]).scalar_f32().unwrap(), 2.5);
        assert!(TensorData::F32(vec![1.0, 2.0]).scalar_f32().is_err());
    }
}
