//! `artifacts/manifest.json` parsing — the contract between the build-time
//! Python AOT pass and the Rust runtime. Every artifact's positional
//! input/output binding is declared here; the runtime refuses shape
//! mismatches at load time rather than at execute time.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor dtype — the subset the stack exchanges.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> anyhow::Result<DType> {
        Ok(match s {
            "float32" | "f32" => DType::F32,
            "int32" | "i32" => DType::I32,
            "uint32" | "u32" => DType::U32,
            other => anyhow::bail!("unsupported dtype '{other}'"),
        })
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// One declared tensor binding.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn parse(j: &Json) -> anyhow::Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str().unwrap_or("").to_string(),
            shape: j
                .req("shape")?
                .as_usize_vec()
                .ok_or_else(|| anyhow::anyhow!("bad shape"))?,
            dtype: DType::parse(j.req("dtype")?.as_str().unwrap_or(""))?,
        })
    }
}

/// One AOT artifact entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    pub mechanism: Option<String>,
    pub preset: Option<String>,
    pub batch: Option<usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub param_names: Vec<String>,
    pub config: BTreeMap<String, Json>,
}

impl ArtifactEntry {
    /// Number of flattened parameter tensors (train_step/init/loss kinds).
    pub fn n_params(&self) -> usize {
        self.param_names.len()
    }

    /// Model config field accessor.
    pub fn config_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key).and_then(|v| v.as_usize())
    }
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub src_digest: String,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = Json::from_file(&dir.join("manifest.json"))?;
        let mut artifacts = BTreeMap::new();
        let arts = j
            .req("artifacts")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("artifacts must be an object"))?;
        for (name, e) in arts {
            let inputs = e
                .req("inputs")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("inputs must be array"))?
                .iter()
                .map(TensorSpec::parse)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = e
                .req("outputs")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("outputs must be array"))?
                .iter()
                .map(TensorSpec::parse)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let param_names = e
                .get("param_names")
                .and_then(|v| v.as_arr())
                .map(|a| {
                    a.iter()
                        .filter_map(|x| x.as_str().map(|s| s.to_string()))
                        .collect()
                })
                .unwrap_or_default();
            let config = e
                .get("config")
                .and_then(|v| v.as_obj())
                .cloned()
                .unwrap_or_default();
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    path: dir.join(e.req("path")?.as_str().unwrap_or("")),
                    kind: e.req("kind")?.as_str().unwrap_or("").to_string(),
                    mechanism: e.get("mechanism").and_then(|v| v.as_str()).map(String::from),
                    preset: e.get("preset").and_then(|v| v.as_str()).map(String::from),
                    batch: e.get("batch").and_then(|v| v.as_usize()),
                    inputs,
                    outputs,
                    param_names,
                    config,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            src_digest: j
                .get("src_digest")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            artifacts,
        })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }

    /// All artifacts of a kind, sorted by name.
    pub fn of_kind(&self, kind: &str) -> Vec<&ArtifactEntry> {
        self.artifacts.values().filter(|a| a.kind == kind).collect()
    }

    /// Default artifacts directory: `$SLAY_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("SLAY_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest(dir: &Path) {
        let text = r#"{
          "version": 1,
          "src_digest": "abc123",
          "artifacts": {
            "attn_slay": {
              "path": "attn_slay.hlo.txt",
              "kind": "attn_fwd",
              "mechanism": "slay",
              "inputs": [
                {"name": "q", "shape": [512, 32], "dtype": "float32"},
                {"name": "k", "shape": [512, 32], "dtype": "float32"},
                {"name": "v", "shape": [512, 32], "dtype": "float32"}
              ],
              "outputs": [{"name": "y", "shape": [512, 32], "dtype": "float32"}]
            },
            "init_task": {
              "path": "init_task.hlo.txt",
              "kind": "init",
              "inputs": [{"name": "seed", "shape": [], "dtype": "uint32"}],
              "outputs": [{"name": "wte", "shape": [64, 64], "dtype": "float32"}],
              "param_names": ["wte"],
              "config": {"vocab": 64, "seq_len": 64}
            }
          }
        }"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
    }

    #[test]
    fn parses_manifest() {
        let dir = std::env::temp_dir().join("slay_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        fake_manifest(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.src_digest, "abc123");
        let a = m.get("attn_slay").unwrap();
        assert_eq!(a.kind, "attn_fwd");
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[0].shape, vec![512, 32]);
        assert_eq!(a.inputs[0].elements(), 512 * 32);
        let init = m.get("init_task").unwrap();
        assert_eq!(init.param_names, vec!["wte"]);
        assert_eq!(init.config_usize("vocab"), Some(64));
        assert_eq!(init.inputs[0].dtype, DType::U32);
        assert_eq!(init.inputs[0].elements(), 1); // scalar
        assert_eq!(m.of_kind("attn_fwd").len(), 1);
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn missing_manifest_errors_cleanly() {
        let dir = std::env::temp_dir().join("slay_manifest_missing");
        std::fs::create_dir_all(&dir).unwrap();
        let _ = std::fs::remove_file(dir.join("manifest.json"));
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn dtype_parsing() {
        assert_eq!(DType::parse("float32").unwrap(), DType::F32);
        assert_eq!(DType::parse("int32").unwrap(), DType::I32);
        assert!(DType::parse("float64").is_err());
    }
}
