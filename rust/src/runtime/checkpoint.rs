//! Checkpoint I/O — a simple self-describing binary tensor container
//! (`.slayckpt`) for trained parameters. Layout (little-endian):
//!
//! ```text
//! magic  b"SLAYCKPT"            8 bytes
//! version u32                   4
//! count   u32                   4
//! repeated count times:
//!   name_len u32 | name utf-8 | ndim u32 | dims u64×ndim | f32 data
//! ```

use crate::runtime::executor::TensorData;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"SLAYCKPT";

/// A named tensor collection (parameter snapshot).
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub tensors: Vec<(String, Vec<usize>, Vec<f32>)>,
}

impl Checkpoint {
    pub fn push(&mut self, name: &str, shape: Vec<usize>, data: Vec<f32>) {
        assert_eq!(shape.iter().product::<usize>().max(1), data.len().max(1));
        self.tensors.push((name.to_string(), shape, data));
    }

    /// Build from manifest-ordered outputs of an init/train_step artifact.
    pub fn from_tensor_data(
        names: &[String],
        shapes: &[Vec<usize>],
        data: &[TensorData],
    ) -> anyhow::Result<Checkpoint> {
        anyhow::ensure!(names.len() == data.len() && names.len() == shapes.len());
        let mut ck = Checkpoint::default();
        for ((n, s), t) in names.iter().zip(shapes.iter()).zip(data.iter()) {
            ck.push(n, s.clone(), t.as_f32()?.to_vec());
        }
        Ok(ck)
    }

    /// Extract as TensorData in the stored order.
    pub fn to_tensor_data(&self) -> Vec<TensorData> {
        self.tensors
            .iter()
            .map(|(_, _, d)| TensorData::F32(d.clone()))
            .collect()
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&1u32.to_le_bytes())?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (name, shape, data) in &self.tensors {
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            // safe f32 → bytes
            let mut buf = Vec::with_capacity(data.len() * 4);
            for &x in data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            f.write_all(&buf)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a slay checkpoint: {}", path.display());
        let version = read_u32(&mut f)?;
        anyhow::ensure!(version == 1, "unsupported checkpoint version {version}");
        let count = read_u32(&mut f)? as usize;
        anyhow::ensure!(count < 1_000_000, "implausible tensor count {count}");
        let mut ck = Checkpoint::default();
        for _ in 0..count {
            let name_len = read_u32(&mut f)? as usize;
            anyhow::ensure!(name_len < 4096, "implausible name length");
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let ndim = read_u32(&mut f)? as usize;
            anyhow::ensure!(ndim <= 8, "implausible rank {ndim}");
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                let mut b = [0u8; 8];
                f.read_exact(&mut b)?;
                shape.push(u64::from_le_bytes(b) as usize);
            }
            let n: usize = shape.iter().product::<usize>().max(1);
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            ck.push(&String::from_utf8(name)?, shape, data);
        }
        Ok(ck)
    }
}

fn read_u32(f: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut ck = Checkpoint::default();
        ck.push("wte", vec![3, 2], vec![1.0, -2.0, 3.5, 0.0, 1e-9, 7.25]);
        ck.push("bias", vec![4], vec![0.1, 0.2, 0.3, 0.4]);
        ck.push("scalar", vec![], vec![42.0]);
        let path = std::env::temp_dir().join("slay_ckpt_test.slayckpt");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors.len(), 3);
        for ((n1, s1, d1), (n2, s2, d2)) in ck.tensors.iter().zip(back.tensors.iter()) {
            assert_eq!(n1, n2);
            assert_eq!(s1, s2);
            assert_eq!(d1, d2);
        }
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("slay_ckpt_garbage");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn tensor_data_conversion() {
        let names = vec!["a".to_string(), "b".to_string()];
        let shapes = vec![vec![2], vec![1, 2]];
        let data = vec![
            TensorData::F32(vec![1.0, 2.0]),
            TensorData::F32(vec![3.0, 4.0]),
        ];
        let ck = Checkpoint::from_tensor_data(&names, &shapes, &data).unwrap();
        let back = ck.to_tensor_data();
        assert_eq!(back[1].as_f32().unwrap(), &[3.0, 4.0]);
    }
}
