//! Run-level configuration: JSON config files / CLI flags → typed configs
//! for the coordinator, benches and training driver. One place where
//! defaults, file overrides and flag overrides merge (flags win).

use crate::coordinator::CoordinatorConfig;
use crate::kernels::config::{Fusion, Mechanism, PolyMethod, SlayConfig};
use crate::util::cli::Args;
use crate::util::json::Json;
use std::time::Duration;

/// Build a [`SlayConfig`] from flags (`--eps`, `--r-nodes`, `--n-poly`,
/// `--d-prf`, `--poly`, `--fusion`, `--seed`).
pub fn slay_config_from_args(args: &Args) -> anyhow::Result<SlayConfig> {
    let mut cfg = SlayConfig::default();
    cfg.eps = args.f64_or("eps", cfg.eps)?;
    cfg.r_nodes = args.usize_or("r-nodes", cfg.r_nodes)?;
    cfg.n_poly = args.usize_or("n-poly", cfg.n_poly)?;
    cfg.d_prf = args.usize_or("d-prf", cfg.d_prf)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    if let Some(p) = args.get("poly") {
        cfg.poly = PolyMethod::parse(p)?;
    }
    if let Some(f) = args.get("fusion") {
        cfg.fusion = Fusion::parse(f)?;
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Mechanism from `--mechanism` (+ SLAY flags when applicable). Accepts
/// either a bare registry name or a full [`Mechanism::parse`] spec
/// (`--mechanism slay:n_poly=16,d_prf=64`); dedicated SLAY flags apply on
/// top of the bare `slay` name.
pub fn mechanism_from_args(args: &Args) -> anyhow::Result<Mechanism> {
    let name = args.get_or("mechanism", "slay");
    if name == "slay" {
        Ok(Mechanism::Slay(slay_config_from_args(args)?))
    } else {
        Mechanism::parse(&name)
    }
}

/// CoordinatorConfig from flags (`--workers`, `--max-batch`,
/// `--max-wait-us`, `--queue-cap`, `--d-head`, `--d-v`, `--horizon`,
/// `--window`, `--spill-dir`, `--prefix-cache-mb`,
/// `--request-timeout-ms`).
pub fn coordinator_from_args(args: &Args) -> anyhow::Result<CoordinatorConfig> {
    let mut cfg = CoordinatorConfig {
        mechanism: mechanism_from_args(args)?,
        ..CoordinatorConfig::default()
    };
    cfg.workers = args.usize_or("workers", cfg.workers)?;
    cfg.max_batch = args.usize_or("max-batch", cfg.max_batch)?;
    cfg.max_wait = Duration::from_micros(args.u64_or(
        "max-wait-us",
        cfg.max_wait.as_micros() as u64,
    )?);
    cfg.queue_cap = args.usize_or("queue-cap", cfg.queue_cap)?;
    cfg.d_head = args.usize_or("d-head", cfg.d_head)?;
    cfg.d_v = args.usize_or("d-v", cfg.d_v)?;
    cfg.horizon = args.usize_or("horizon", cfg.horizon)?;
    cfg.window = args.usize_or("window", cfg.window)?;
    if let Some(dir) = args.get("spill-dir") {
        cfg.store.spill_dir = Some(std::path::PathBuf::from(dir));
    }
    // Shared-prefix cache byte budget (ADR-006), in MiB for the flag;
    // `--prefix-cache-mb 0` disables the cache entirely.
    cfg.store.prefix_cache_budget =
        args.usize_or("prefix-cache-mb", cfg.store.prefix_cache_budget >> 20)? << 20;
    if let Some(dir) = args.get("snapshot-root") {
        cfg.snapshot_root = Some(std::path::PathBuf::from(dir));
    }
    // Per-request deadline (ADR-008): `--request-timeout-ms 0` means no
    // deadline (the seed's unbounded behavior).
    let timeout_ms = args.u64_or(
        "request-timeout-ms",
        cfg.request_timeout.map_or(0, |t| t.as_millis() as u64),
    )?;
    cfg.request_timeout = (timeout_ms > 0).then(|| Duration::from_millis(timeout_ms));
    Ok(cfg)
}

/// Serialize a coordinator config for logs/results.
pub fn coordinator_to_json(cfg: &CoordinatorConfig) -> Json {
    Json::obj(vec![
        ("mechanism", Json::Str(cfg.mechanism.to_string())),
        ("d_head", Json::Num(cfg.d_head as f64)),
        ("d_v", Json::Num(cfg.d_v as f64)),
        ("workers", Json::Num(cfg.workers as f64)),
        ("max_batch", Json::Num(cfg.max_batch as f64)),
        ("max_wait_us", Json::Num(cfg.max_wait.as_micros() as f64)),
        ("queue_cap", Json::Num(cfg.queue_cap as f64)),
        ("horizon", Json::Num(cfg.horizon as f64)),
        ("window", Json::Num(cfg.window as f64)),
        (
            "spill_dir",
            match &cfg.store.spill_dir {
                Some(d) => Json::Str(d.display().to_string()),
                None => Json::Null,
            },
        ),
        ("prefix_cache_budget", Json::Num(cfg.store.prefix_cache_budget as f64)),
        (
            "request_timeout_ms",
            match cfg.request_timeout {
                Some(t) => Json::Num(t.as_millis() as f64),
                None => Json::Null,
            },
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(&toks.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn slay_flags_override_defaults() {
        let a = parse(&["x", "--eps", "0.01", "--r-nodes", "5", "--poly", "exact"]);
        let c = slay_config_from_args(&a).unwrap();
        assert_eq!(c.eps, 0.01);
        assert_eq!(c.r_nodes, 5);
        assert_eq!(c.poly, PolyMethod::Exact);
    }

    #[test]
    fn sketch_fusion_parses_dim() {
        let a = parse(&["x", "--fusion", "sketch:64"]);
        let c = slay_config_from_args(&a).unwrap();
        assert_eq!(c.fusion, Fusion::Sketch { d_t: 64 });
        assert!(slay_config_from_args(&parse(&["x", "--fusion", "sketch:63"])).is_err());
    }

    #[test]
    fn mechanism_dispatch() {
        assert_eq!(
            mechanism_from_args(&parse(&["x", "--mechanism", "favor"]))
                .unwrap()
                .name(),
            "favor"
        );
        assert!(matches!(
            mechanism_from_args(&parse(&["x"])).unwrap(),
            Mechanism::Slay(_)
        ));
        assert!(mechanism_from_args(&parse(&["x", "--mechanism", "bogus"])).is_err());
    }

    #[test]
    fn mechanism_flag_accepts_registry_specs() {
        let m = mechanism_from_args(&parse(&["x", "--mechanism", "favor:m=16,seed=5"])).unwrap();
        assert_eq!(m, Mechanism::Favor { m_features: 16, seed: 5 });
        let m = mechanism_from_args(&parse(&["x", "--mechanism", "yat:eps=0.02"])).unwrap();
        assert_eq!(m, Mechanism::Yat { eps: 0.02 });
    }

    #[test]
    fn coordinator_flags() {
        let a = parse(&["x", "--workers", "2", "--max-batch", "8", "--max-wait-us", "500"]);
        let c = coordinator_from_args(&a).unwrap();
        assert_eq!(c.workers, 2);
        assert_eq!(c.max_batch, 8);
        assert_eq!(c.max_wait, Duration::from_micros(500));
        let j = coordinator_to_json(&c);
        assert_eq!(j.get("workers").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn spill_dir_flag_enables_the_spill_tier() {
        let c = coordinator_from_args(&parse(&["x", "--spill-dir", "/tmp/slay-spill"])).unwrap();
        assert_eq!(
            c.store.spill_dir.as_deref(),
            Some(std::path::Path::new("/tmp/slay-spill"))
        );
        let j = coordinator_to_json(&c);
        assert_eq!(j.get("spill_dir").unwrap().as_str(), Some("/tmp/slay-spill"));
        // default stays off (destructive eviction, seed behavior)
        let d = coordinator_from_args(&parse(&["x"])).unwrap();
        assert!(d.store.spill_dir.is_none());
        assert_eq!(coordinator_to_json(&d).get("spill_dir"), Some(&Json::Null));
    }

    #[test]
    fn prefix_cache_flag_sets_budget_in_mib_and_zero_disables() {
        let c = coordinator_from_args(&parse(&["x", "--prefix-cache-mb", "8"])).unwrap();
        assert_eq!(c.store.prefix_cache_budget, 8 << 20);
        let off = coordinator_from_args(&parse(&["x", "--prefix-cache-mb", "0"])).unwrap();
        assert_eq!(off.store.prefix_cache_budget, 0);
        // default: the store's own default budget survives untouched
        let d = coordinator_from_args(&parse(&["x"])).unwrap();
        assert_eq!(
            d.store.prefix_cache_budget,
            crate::coordinator::state::StoreConfig::default().prefix_cache_budget
        );
        let j = coordinator_to_json(&c);
        assert_eq!(j.get("prefix_cache_budget").unwrap().as_usize(), Some(8 << 20));
    }

    #[test]
    fn request_timeout_flag_zero_means_unbounded() {
        let c = coordinator_from_args(&parse(&["x", "--request-timeout-ms", "250"])).unwrap();
        assert_eq!(c.request_timeout, Some(Duration::from_millis(250)));
        let j = coordinator_to_json(&c);
        assert_eq!(j.get("request_timeout_ms").unwrap().as_usize(), Some(250));
        // 0 disables the deadline entirely (seed behavior)
        let off = coordinator_from_args(&parse(&["x", "--request-timeout-ms", "0"])).unwrap();
        assert_eq!(off.request_timeout, None);
        assert_eq!(coordinator_to_json(&off).get("request_timeout_ms"), Some(&Json::Null));
        // default: no deadline
        let d = coordinator_from_args(&parse(&["x"])).unwrap();
        assert_eq!(d.request_timeout, None);
    }

    #[test]
    fn window_flag_decouples_from_horizon() {
        let c = coordinator_from_args(&parse(&[
            "x", "--horizon", "131072", "--window", "512",
        ]))
        .unwrap();
        assert_eq!(c.horizon, 131_072);
        assert_eq!(c.window, 512);
        let j = coordinator_to_json(&c);
        assert_eq!(j.get("window").unwrap().as_usize(), Some(512));
        // default: window falls back to the bounded default, not horizon
        let d = coordinator_from_args(&parse(&["x"])).unwrap();
        assert_eq!(d.window, crate::kernels::DEFAULT_QUADRATIC_WINDOW);
    }
}
