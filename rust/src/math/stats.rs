//! Summary statistics and the error metrics used throughout the paper's
//! evaluation: relative ℓ2 error, cosine similarity, MSE (Table 2/6),
//! Shannon entropy of attention rows (Fig. 15/16), Pearson correlation
//! (Fig. 18), and latency percentiles for the bench harness.

/// Relative ℓ2 error `‖a − b‖₂ / ‖b‖₂` (b is the reference).
pub fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&x, &y) in a.iter().zip(b.iter()) {
        let d = x as f64 - y as f64;
        num += d * d;
        den += (y as f64) * (y as f64);
    }
    (num / den.max(1e-300)).sqrt()
}

/// Cosine similarity between flattened tensors.
pub fn cosine(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut ab = 0.0f64;
    let mut aa = 0.0f64;
    let mut bb = 0.0f64;
    for (&x, &y) in a.iter().zip(b.iter()) {
        ab += x as f64 * y as f64;
        aa += x as f64 * x as f64;
        bb += y as f64 * y as f64;
    }
    ab / (aa.sqrt() * bb.sqrt()).max(1e-300)
}

/// Mean squared error.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut acc = 0.0f64;
    for (&x, &y) in a.iter().zip(b.iter()) {
        let d = x as f64 - y as f64;
        acc += d * d;
    }
    acc / a.len() as f64
}

/// Pearson correlation coefficient.
pub fn pearson(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let n = a.len() as f64;
    let ma = a.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mb = b.iter().map(|&x| x as f64).sum::<f64>() / n;
    let mut cov = 0.0;
    let mut va = 0.0;
    let mut vb = 0.0;
    for (&x, &y) in a.iter().zip(b.iter()) {
        let dx = x as f64 - ma;
        let dy = y as f64 - mb;
        cov += dx * dy;
        va += dx * dx;
        vb += dy * dy;
    }
    cov / (va.sqrt() * vb.sqrt()).max(1e-300)
}

/// Shannon entropy (nats) of a nonnegative weight vector, normalized to a
/// distribution first. Zero-mass rows return 0.
pub fn entropy(weights: &[f32]) -> f64 {
    let total: f64 = weights.iter().map(|&w| w.max(0.0) as f64).sum();
    if total <= 0.0 {
        return 0.0;
    }
    let mut h = 0.0;
    for &w in weights {
        let p = (w.max(0.0) as f64) / total;
        if p > 0.0 {
            h -= p * p.ln();
        }
    }
    h
}

/// Mean of a sample.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile with linear interpolation, `q ∈ [0, 100]`.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Welford online mean/variance accumulator (used by streaming metrics).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rel_l2_zero_for_identical() {
        let a = [1.0f32, -2.0, 3.0];
        assert!(rel_l2(&a, &a) < 1e-12);
    }

    #[test]
    fn rel_l2_one_for_zero_estimate() {
        let a = [0.0f32; 4];
        let b = [1.0f32, 1.0, 1.0, 1.0];
        assert!((rel_l2(&a, &b) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cosine_bounds_and_signs() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        let c = [-1.0f32, 0.0];
        assert!(cosine(&a, &a) > 0.999999);
        assert!(cosine(&a, &b).abs() < 1e-9);
        assert!((cosine(&a, &c) + 1.0).abs() < 1e-9);
    }

    #[test]
    fn entropy_uniform_is_log_n() {
        let w = [0.25f32; 4];
        assert!((entropy(&w) - 4.0f64.ln()).abs() < 1e-9);
        // peaked distribution has lower entropy
        assert!(entropy(&[1.0, 0.0, 0.0, 0.0]) < 1e-12);
        // scale invariance
        assert!((entropy(&[2.0, 2.0]) - 2.0f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 4.0, 9.0, 16.0, 25.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std_dev(&xs)).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_linear() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [2.0f32, 4.0, 6.0, 8.0];
        assert!((pearson(&a, &b) - 1.0).abs() < 1e-9);
        let c = [8.0f32, 6.0, 4.0, 2.0];
        assert!((pearson(&a, &c) + 1.0).abs() < 1e-9);
    }
}
