//! Numerical substrates: PRNG, dense linear algebra, Gauss–Laguerre
//! quadrature, and statistics. All implemented from scratch (the offline
//! image vendors no rand/ndarray/BLAS/scipy-equivalent for Rust).

pub mod eigen;
pub mod fft;
pub mod linalg;
pub mod quadrature;
pub mod rng;
pub mod simd;
pub mod stats;
