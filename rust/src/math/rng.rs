//! Deterministic pseudo-random number generation.
//!
//! The offline image vendors no `rand` crate, so this module implements the
//! generators the whole stack uses: SplitMix64 (seeding), xoshiro256++
//! (bulk generation), uniform/normal/categorical sampling, shuffles and
//! Zipf draws. Every experiment in the repo threads an explicit [`Rng`] so
//! results are reproducible from a single `u64` seed.

/// SplitMix64 step — used to expand a single `u64` seed into xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ generator (Blackman & Vigna). Fast, 256-bit state,
/// equidistributed in 4 dimensions — more than adequate for Monte Carlo
/// feature maps and data generation.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller output.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (for per-head / per-worker use).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Unbiased integer in `[0, n)` (Lemire-style rejection).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        // 128-bit multiply trick with rejection for exact uniformity.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // u in (0,1] to avoid ln(0).
        let u = 1.0 - self.uniform();
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Standard normal as `f32`.
    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Vector of iid uniforms in `[lo, hi)`.
    pub fn uniform_vec(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n)
            .map(|_| lo + (hi - lo) * self.uniform_f32())
            .collect()
    }

    /// Rademacher (+1/−1) vector.
    pub fn rademacher_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 })
            .collect()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized nonnegative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0, "categorical needs positive mass");
        let mut u = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf(α) sample over `{0, …, n−1}` by inverse-CDF on precomputed
    /// weights — used by the synthetic corpus / Eurlex label generators.
    pub fn zipf(&mut self, cdf: &[f64]) -> usize {
        let u = self.uniform();
        match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(cdf.len() - 1),
        }
    }
}

/// Precompute a Zipf(α) CDF over `n` items (item 0 most frequent).
pub fn zipf_cdf(n: usize, alpha: f64) -> Vec<f64> {
    let mut w: Vec<f64> = (0..n).map(|i| 1.0 / ((i + 1) as f64).powf(alpha)).collect();
    let total: f64 = w.iter().sum();
    let mut acc = 0.0;
    for x in w.iter_mut() {
        acc += *x / total;
        *x = acc;
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_mean_centered() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_is_unbiased_over_small_range() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(6);
        let mut hits = [0usize; 3];
        for _ in 0..30_000 {
            hits[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(hits[2] > hits[1] && hits[1] > hits[0], "hits={hits:?}");
        assert!((hits[2] as f64 / 30_000.0 - 0.7).abs() < 0.02);
    }

    #[test]
    fn zipf_head_dominates() {
        let cdf = zipf_cdf(100, 1.1);
        assert!((cdf.last().unwrap() - 1.0).abs() < 1e-12);
        let mut r = Rng::new(8);
        let mut head = 0;
        for _ in 0..10_000 {
            if r.zipf(&cdf) == 0 {
                head += 1;
            }
        }
        // item 0 mass ≈ 1/H_{100,1.1} ≈ 0.19
        assert!(head > 1_000, "head={head}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_decorrelated() {
        let mut parent = Rng::new(10);
        let mut c1 = parent.fork(1);
        let mut c2 = parent.fork(2);
        let a: Vec<u64> = (0..10).map(|_| c1.next_u64()).collect();
        let b: Vec<u64> = (0..10).map(|_| c2.next_u64()).collect();
        assert_ne!(a, b);
    }
}
