//! Runtime-dispatched SIMD microkernel layer (ADR-010).
//!
//! The hot numeric paths — the GEMM family behind `math::linalg`, the
//! dot/axpy/sq_dist primitives, and the exp-heavy feature-map/softmax
//! inner loops — bottom out in one [`Kernels`] table of plain function
//! pointers. The table is resolved **once per process** (first use), from
//! `is_x86_feature_detected!` on x86_64 (AVX2+FMA), NEON on aarch64, and
//! an always-compiled safe scalar fallback everywhere; `SLAY_SIMD=auto|`
//! `scalar|avx2|neon` overrides detection (unrecognized values warn
//! loudly on stderr and fall back to `auto`, matching `SLAY_LOG`).
//!
//! Determinism policy (ADR-010): because every call site in a process
//! goes through the same resolved table, all bit-identity properties the
//! test suite pins (chunked==per-token, fused==sequential, fork/COW,
//! codec round-trips, chaos replay, threaded==serial, strided==owned)
//! compare paths through the *same* kernels and keep holding under any
//! backend. Cross-ISA (and cross-`SLAY_SIMD`) bit-identity is explicitly
//! **not** claimed — AVX2/NEON accumulate with fused multiply-add and
//! a polynomial `expf` ([`expf::exp_ps`]), the scalar backend with
//! separate mul+add and libm exp.

use std::cell::RefCell;
use std::sync::OnceLock;

use crate::math::linalg::{MatView, MatViewMut, Scratch};

#[cfg(target_arch = "x86_64")]
pub(crate) mod avx2;
pub mod expf;
#[cfg(target_arch = "aarch64")]
pub(crate) mod neon;
pub(crate) mod scalar;

/// One resolved microkernel backend. All entries share the layer's
/// determinism contract: per output element a single accumulator chain,
/// sequential over the contraction dimension, independent of striping,
/// striding, and alignment (see the backend modules for the per-ISA
/// details).
pub struct Kernels {
    pub name: &'static str,
    /// Dot product of two equal-length slices.
    pub dot: fn(&[f32], &[f32]) -> f32,
    /// `y += alpha · x`.
    pub axpy: fn(f32, &[f32], &mut [f32]),
    /// `y += x`.
    pub add_assign: fn(&[f32], &mut [f32]),
    /// Squared L2 distance.
    pub sq_dist: fn(&[f32], &[f32]) -> f32,
    /// One row stripe of `C = A·B` (overwrites `out`).
    pub gemm_nn: fn(MatView, MatView, MatViewMut),
    /// Accumulate output rows `[c0, c0+out.rows())` of `AᵀB` into `out`.
    pub gemm_tn_acc: fn(MatView, MatView, usize, MatViewMut),
    /// One row stripe of `C = A·Bᵀ`; element `(i,j)` is bit-identical to
    /// `dot(a.row(i), b.row(j))` — the fused-decode invariant.
    pub gemm_nt: fn(MatView, MatView, MatViewMut),
    /// In-place stabilized softmax over one row.
    pub softmax_row: fn(&mut [f32]),
    /// `row *= 1/(Σrow + delta)`.
    pub normalize_row_sum: fn(&mut [f32], f32),
    /// `x = exp(a·x + b)·scale` element-wise (PRF/FAVOR+/score exps).
    pub exp_affine_scale: fn(&mut [f32], f32, f32, f32),
    /// `x = max(x,0)·scale` element-wise.
    pub relu_scale: fn(&mut [f32], f32),
    /// `x = x²·scale` element-wise.
    pub square_scale: fn(&mut [f32], f32),
    /// `out = elu(x)+1` element-wise.
    pub elu_plus_one: fn(&[f32], &mut [f32]),
}

static SCALAR: Kernels = Kernels {
    name: "scalar",
    dot: scalar::dot,
    axpy: scalar::axpy,
    add_assign: scalar::add_assign,
    sq_dist: scalar::sq_dist,
    gemm_nn: scalar::gemm_nn,
    gemm_tn_acc: scalar::gemm_tn_acc,
    gemm_nt: scalar::gemm_nt,
    softmax_row: scalar::softmax_row,
    normalize_row_sum: scalar::normalize_row_sum,
    exp_affine_scale: scalar::exp_affine_scale,
    relu_scale: scalar::relu_scale,
    square_scale: scalar::square_scale,
    elu_plus_one: scalar::elu_plus_one,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    name: "avx2",
    dot: avx2::dot,
    axpy: avx2::axpy,
    add_assign: avx2::add_assign,
    sq_dist: avx2::sq_dist,
    gemm_nn: avx2::gemm_nn,
    gemm_tn_acc: avx2::gemm_tn_acc,
    gemm_nt: avx2::gemm_nt,
    softmax_row: avx2::softmax_row,
    normalize_row_sum: avx2::normalize_row_sum,
    exp_affine_scale: avx2::exp_affine_scale,
    relu_scale: avx2::relu_scale,
    square_scale: avx2::square_scale,
    elu_plus_one: avx2::elu_plus_one,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    name: "neon",
    dot: neon::dot,
    axpy: neon::axpy,
    add_assign: neon::add_assign,
    sq_dist: neon::sq_dist,
    gemm_nn: neon::gemm_nn,
    gemm_tn_acc: neon::gemm_tn_acc,
    gemm_nt: neon::gemm_nt,
    softmax_row: neon::softmax_row,
    normalize_row_sum: neon::normalize_row_sum,
    exp_affine_scale: neon::exp_affine_scale,
    relu_scale: neon::relu_scale,
    square_scale: neon::square_scale,
    elu_plus_one: neon::elu_plus_one,
};

/// Selectable backends. `Avx2`/`Neon` resolve only on their ISA with the
/// features present; see [`kernels_for`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Scalar,
    Avx2,
    Neon,
}

/// Hardware auto-detection (the `SLAY_SIMD=auto` path).
fn detect() -> Backend {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            return Backend::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        // NEON is baseline on every aarch64 target this crate builds for.
        return Backend::Neon;
    }
    #[allow(unreachable_code)]
    Backend::Scalar
}

/// The kernel table for `b`, or `None` when this host can't run it
/// (wrong ISA or missing CPU features). Safe to call from tests/benches
/// to compare backends in-process.
pub fn kernels_for(b: Backend) -> Option<&'static Kernels> {
    match b {
        Backend::Scalar => Some(&SCALAR),
        Backend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                if std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
                {
                    return Some(&AVX2);
                }
            }
            None
        }
        Backend::Neon => {
            #[cfg(target_arch = "aarch64")]
            {
                return Some(&NEON);
            }
            #[allow(unreachable_code)]
            None
        }
    }
}

fn select() -> &'static Kernels {
    let forced = match std::env::var("SLAY_SIMD").as_deref() {
        Ok("auto") | Err(_) => None,
        Ok("scalar") => Some(Backend::Scalar),
        Ok("avx2") => Some(Backend::Avx2),
        Ok("neon") => Some(Backend::Neon),
        Ok(other) => {
            // Loud once (ADR-008: misconfiguration never fails silently) —
            // a typo'd SLAY_SIMD would otherwise just quietly mean "auto".
            eprintln!(
                "SLAY_SIMD={other:?} is not a SIMD backend \
                 (expected auto|scalar|avx2|neon); defaulting to auto"
            );
            None
        }
    };
    let table = match forced {
        Some(b) => kernels_for(b).unwrap_or_else(|| {
            eprintln!(
                "SLAY_SIMD={b:?} requested but this host cannot run it \
                 (wrong ISA or missing CPU features); using auto-detection"
            );
            kernels_for(detect()).unwrap_or(&SCALAR)
        }),
        None => kernels_for(detect()).unwrap_or(&SCALAR),
    };
    crate::log_trace!("SIMD dispatch resolved: backend={}", table.name);
    table
}

/// The process-wide resolved kernel table. First call reads `SLAY_SIMD`
/// and probes the CPU; every later call is an atomic load. All linalg
/// entry points and feature-map inner loops route through this, so one
/// process always computes through one backend (the per-host determinism
/// policy of ADR-010).
#[inline]
pub fn kernels() -> &'static Kernels {
    static K: OnceLock<&'static Kernels> = OnceLock::new();
    K.get_or_init(select)
}

/// Name of the resolved backend (`"scalar"|"avx2"|"neon"`) — exposed as
/// a label in the metrics snapshot and the bench records.
pub fn backend_name() -> &'static str {
    kernels().name
}

thread_local! {
    /// Per-thread arena for the packed-GEMM micro-panels. Thread-local
    /// (rather than caller-passed) because the linalg entry points take no
    /// scratch argument; steady-state calls on a warm thread are
    /// allocation-free (pinned by `rust/tests/alloc_discipline.rs`).
    /// Scoped worker threads of the threaded matmul fan-outs start cold —
    /// that one-buffer-per-spawned-thread cost sits inside the O(threads)
    /// spawn allowance ADR-003 already grants the fan-out path.
    static PACK: RefCell<Scratch> = RefCell::new(Scratch::new());
}

/// Run `f` with a zeroed pack buffer of `len` floats from the per-thread
/// arena (returned to the pool afterwards).
pub(crate) fn with_pack<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    PACK.with(|s| {
        let mut s = s.borrow_mut();
        let mut buf = s.take(len);
        let r = f(&mut buf);
        s.put(buf);
        r
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_table_always_available() {
        let k = kernels_for(Backend::Scalar).expect("scalar table must exist");
        assert_eq!(k.name, "scalar");
        assert_eq!((k.dot)(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
    }

    #[test]
    fn resolved_table_reports_a_known_name() {
        let name = backend_name();
        assert!(
            name == "scalar" || name == "avx2" || name == "neon",
            "unexpected backend {name:?}"
        );
    }

    #[test]
    fn forced_tables_match_their_names() {
        for (b, want) in [
            (Backend::Scalar, "scalar"),
            (Backend::Avx2, "avx2"),
            (Backend::Neon, "neon"),
        ] {
            if let Some(k) = kernels_for(b) {
                assert_eq!(k.name, want);
            }
        }
    }

    #[test]
    fn with_pack_hands_out_zeroed_reusable_buffers() {
        with_pack(64, |buf| {
            assert_eq!(buf.len(), 64);
            assert!(buf.iter().all(|&x| x == 0.0));
            buf.fill(7.0);
        });
        // Re-taken buffer comes back zeroed despite the previous fill.
        with_pack(32, |buf| {
            assert_eq!(buf.len(), 32);
            assert!(buf.iter().all(|&x| x == 0.0));
        });
    }
}
