//! NEON backend for aarch64 (ADR-010) — the 4-lane mirror of `avx2.rs`.
//!
//! NEON is baseline on aarch64 Linux targets, so the dispatch table can
//! install this backend unconditionally there; the `target_feature`
//! annotations keep the compiler honest about instruction selection.
//! The determinism rules are the same as the AVX2 backend's: one
//! accumulator chain per output element, sequential over k, fused
//! multiply-add in lanes and `f32::mul_add` in scalar tails, `gemm_nt`
//! element chains identical to [`dot`], and exp lanes mirroring
//! [`super::expf::exp_ps`] operation for operation.

#![allow(clippy::needless_range_loop)]

use core::arch::aarch64::*;

use super::expf::{self, exp_ps};
use super::with_pack;
use crate::math::linalg::{MatView, MatViewMut};

/// Rows per packed A micro-panel (6×8: 12 accumulator q-registers).
const MR: usize = 6;

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: NEON is baseline on every aarch64 target we build for.
    unsafe { dot_impl(a.as_ptr(), b.as_ptr(), a.len()) }
}

pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // SAFETY: as above.
    unsafe { axpy_impl(alpha, x.as_ptr(), y.as_mut_ptr(), x.len()) }
}

pub fn add_assign(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // SAFETY: as above.
    unsafe { add_assign_impl(x.as_ptr(), y.as_mut_ptr(), x.len()) }
}

pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: as above.
    unsafe { sq_dist_impl(a.as_ptr(), b.as_ptr(), a.len()) }
}

pub fn gemm_nn(a: MatView, b: MatView, mut out: MatViewMut) {
    if a.cols() == 0 {
        out.fill_zero();
        return;
    }
    if out.rows() == 0 || out.cols() == 0 {
        return;
    }
    // SAFETY: as above; shapes pre-checked by the linalg entry points.
    with_pack(MR * a.cols(), |pack| unsafe { gemm_nn_impl(&a, &b, pack, &mut out) })
}

pub fn gemm_tn_acc(a: MatView, b: MatView, c0: usize, mut out: MatViewMut) {
    if a.rows() == 0 || out.rows() == 0 || out.cols() == 0 {
        return;
    }
    // SAFETY: as above.
    with_pack(MR * a.rows(), |pack| unsafe { gemm_tn_impl(&a, &b, c0, pack, &mut out) })
}

pub fn gemm_nt(a: MatView, b: MatView, mut out: MatViewMut) {
    if out.rows() == 0 || out.cols() == 0 {
        return;
    }
    // SAFETY: as above.
    unsafe { gemm_nt_impl(&a, &b, &mut out) }
}

pub fn softmax_row(row: &mut [f32]) {
    // SAFETY: as above.
    unsafe { softmax_row_impl(row) }
}

pub fn normalize_row_sum(row: &mut [f32], delta: f32) {
    // SAFETY: as above.
    unsafe { normalize_row_sum_impl(row, delta) }
}

pub fn exp_affine_scale(xs: &mut [f32], a: f32, b: f32, scale: f32) {
    // SAFETY: as above.
    unsafe { exp_affine_scale_impl(xs, a, b, scale) }
}

pub fn relu_scale(xs: &mut [f32], scale: f32) {
    // SAFETY: as above.
    unsafe { relu_scale_impl(xs, scale) }
}

pub fn square_scale(xs: &mut [f32], scale: f32) {
    // SAFETY: as above.
    unsafe { square_scale_impl(xs, scale) }
}

pub fn elu_plus_one(xs: &[f32], out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    // SAFETY: as above.
    unsafe { elu_plus_one_impl(xs, out) }
}

// ---------------------------------------------------------------------------
// Reductions
// ---------------------------------------------------------------------------

/// Fixed-order horizontal sum: fold halves, then the remaining pair.
#[target_feature(enable = "neon")]
#[inline]
unsafe fn hsum4(v: float32x4_t) -> f32 {
    let s = vadd_f32(vget_low_f32(v), vget_high_f32(v));
    vget_lane_f32::<0>(s) + vget_lane_f32::<1>(s)
}

#[target_feature(enable = "neon")]
#[inline]
unsafe fn hmax4(v: float32x4_t) -> f32 {
    let m = vpmax_f32(vget_low_f32(v), vget_high_f32(v));
    let m = vpmax_f32(m, m);
    vget_lane_f32::<0>(m)
}

// ---------------------------------------------------------------------------
// Vector kernels
// ---------------------------------------------------------------------------

/// Canonical dot chain (two lane accumulators over 8-element steps, one
/// 4-wide cleanup, fixed-order reduction, `mul_add` tail) — `gemm_nt`
/// replicates this per element.
#[target_feature(enable = "neon")]
unsafe fn dot_impl(a: *const f32, b: *const f32, n: usize) -> f32 {
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut k = 0usize;
    while k + 8 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(a.add(k)), vld1q_f32(b.add(k)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(a.add(k + 4)), vld1q_f32(b.add(k + 4)));
        k += 8;
    }
    if k + 4 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(a.add(k)), vld1q_f32(b.add(k)));
        k += 4;
    }
    let mut s = hsum4(vaddq_f32(acc0, acc1));
    while k < n {
        s = (*a.add(k)).mul_add(*b.add(k), s);
        k += 1;
    }
    s
}

#[target_feature(enable = "neon")]
unsafe fn axpy_impl(alpha: f32, x: *const f32, y: *mut f32, n: usize) {
    let av = vdupq_n_f32(alpha);
    let mut k = 0usize;
    while k + 4 <= n {
        vst1q_f32(y.add(k), vfmaq_f32(vld1q_f32(y.add(k)), av, vld1q_f32(x.add(k))));
        k += 4;
    }
    while k < n {
        *y.add(k) = alpha.mul_add(*x.add(k), *y.add(k));
        k += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn add_assign_impl(x: *const f32, y: *mut f32, n: usize) {
    let mut k = 0usize;
    while k + 4 <= n {
        vst1q_f32(y.add(k), vaddq_f32(vld1q_f32(y.add(k)), vld1q_f32(x.add(k))));
        k += 4;
    }
    while k < n {
        *y.add(k) += *x.add(k);
        k += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn sq_dist_impl(a: *const f32, b: *const f32, n: usize) -> f32 {
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut k = 0usize;
    while k + 8 <= n {
        let d0 = vsubq_f32(vld1q_f32(a.add(k)), vld1q_f32(b.add(k)));
        let d1 = vsubq_f32(vld1q_f32(a.add(k + 4)), vld1q_f32(b.add(k + 4)));
        acc0 = vfmaq_f32(acc0, d0, d0);
        acc1 = vfmaq_f32(acc1, d1, d1);
        k += 8;
    }
    if k + 4 <= n {
        let d0 = vsubq_f32(vld1q_f32(a.add(k)), vld1q_f32(b.add(k)));
        acc0 = vfmaq_f32(acc0, d0, d0);
        k += 4;
    }
    let mut s = hsum4(vaddq_f32(acc0, acc1));
    while k < n {
        let d = *a.add(k) - *b.add(k);
        s = d.mul_add(d, s);
        k += 1;
    }
    s
}

// ---------------------------------------------------------------------------
// Packed GEMM
// ---------------------------------------------------------------------------

/// 6×8 microkernel over a k-major packed A panel and 8 consecutive B
/// columns; `LOAD_C` selects chain root (0 for nn, existing C for tn).
#[target_feature(enable = "neon")]
unsafe fn mk6x8<const LOAD_C: bool>(
    kc: usize,
    pack: *const f32,
    bp: *const f32,
    bs: usize,
    c: &[*mut f32; MR],
) {
    let mut acc = [[vdupq_n_f32(0.0); 2]; MR];
    if LOAD_C {
        for r in 0..MR {
            acc[r][0] = vld1q_f32(c[r]);
            acc[r][1] = vld1q_f32(c[r].add(4));
        }
    }
    for kk in 0..kc {
        let b0 = vld1q_f32(bp.add(kk * bs));
        let b1 = vld1q_f32(bp.add(kk * bs + 4));
        let pk = pack.add(kk * MR);
        for r in 0..MR {
            let av = vdupq_n_f32(*pk.add(r));
            acc[r][0] = vfmaq_f32(acc[r][0], av, b0);
            acc[r][1] = vfmaq_f32(acc[r][1], av, b1);
        }
    }
    for r in 0..MR {
        vst1q_f32(c[r], acc[r][0]);
        vst1q_f32(c[r].add(4), acc[r][1]);
    }
}

/// 6×4 column-tail variant of [`mk6x8`].
#[target_feature(enable = "neon")]
unsafe fn mk6x4<const LOAD_C: bool>(
    kc: usize,
    pack: *const f32,
    bp: *const f32,
    bs: usize,
    c: &[*mut f32; MR],
) {
    let mut acc = [vdupq_n_f32(0.0); MR];
    if LOAD_C {
        for r in 0..MR {
            acc[r] = vld1q_f32(c[r]);
        }
    }
    for kk in 0..kc {
        let b0 = vld1q_f32(bp.add(kk * bs));
        let pk = pack.add(kk * MR);
        for r in 0..MR {
            acc[r] = vfmaq_f32(acc[r], vdupq_n_f32(*pk.add(r)), b0);
        }
    }
    for r in 0..MR {
        vst1q_f32(c[r], acc[r]);
    }
}

#[target_feature(enable = "neon")]
unsafe fn panel6<const LOAD_C: bool>(
    kc: usize,
    pack: *const f32,
    bp: *const f32,
    bs: usize,
    c: &[*mut f32; MR],
    n: usize,
) {
    let mut j = 0usize;
    while j + 8 <= n {
        let cj = [
            c[0].add(j),
            c[1].add(j),
            c[2].add(j),
            c[3].add(j),
            c[4].add(j),
            c[5].add(j),
        ];
        mk6x8::<LOAD_C>(kc, pack, bp.add(j), bs, &cj);
        j += 8;
    }
    if j + 4 <= n {
        let cj = [
            c[0].add(j),
            c[1].add(j),
            c[2].add(j),
            c[3].add(j),
            c[4].add(j),
            c[5].add(j),
        ];
        mk6x4::<LOAD_C>(kc, pack, bp.add(j), bs, &cj);
        j += 4;
    }
    while j < n {
        for r in 0..MR {
            let mut s = if LOAD_C { *c[r].add(j) } else { 0.0 };
            for kk in 0..kc {
                s = (*pack.add(kk * MR + r)).mul_add(*bp.add(kk * bs + j), s);
            }
            *c[r].add(j) = s;
        }
        j += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn panel1<const LOAD_C: bool>(
    kc: usize,
    ar: *const f32,
    bp: *const f32,
    bs: usize,
    co: *mut f32,
    n: usize,
) {
    let mut j = 0usize;
    while j + 8 <= n {
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        if LOAD_C {
            acc0 = vld1q_f32(co.add(j));
            acc1 = vld1q_f32(co.add(j + 4));
        }
        for kk in 0..kc {
            let av = vdupq_n_f32(*ar.add(kk));
            acc0 = vfmaq_f32(acc0, av, vld1q_f32(bp.add(kk * bs + j)));
            acc1 = vfmaq_f32(acc1, av, vld1q_f32(bp.add(kk * bs + j + 4)));
        }
        vst1q_f32(co.add(j), acc0);
        vst1q_f32(co.add(j + 4), acc1);
        j += 8;
    }
    if j + 4 <= n {
        let mut acc0 = vdupq_n_f32(0.0);
        if LOAD_C {
            acc0 = vld1q_f32(co.add(j));
        }
        for kk in 0..kc {
            acc0 = vfmaq_f32(acc0, vdupq_n_f32(*ar.add(kk)), vld1q_f32(bp.add(kk * bs + j)));
        }
        vst1q_f32(co.add(j), acc0);
        j += 4;
    }
    while j < n {
        let mut s = if LOAD_C { *co.add(j) } else { 0.0 };
        for kk in 0..kc {
            s = (*ar.add(kk)).mul_add(*bp.add(kk * bs + j), s);
        }
        *co.add(j) = s;
        j += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn gemm_nn_impl(a: &MatView, b: &MatView, pack: &mut [f32], out: &mut MatViewMut) {
    let (m, kd, n) = (a.rows(), a.cols(), b.cols());
    let (ap, astride) = (a.base_ptr(), a.row_stride());
    let (bp, bs) = (b.base_ptr(), b.row_stride());
    let ostride = out.row_stride();
    let op = out.base_ptr_mut();
    let pp = pack.as_mut_ptr();
    let mut i = 0usize;
    while i + MR <= m {
        for r in 0..MR {
            let arow = ap.add((i + r) * astride);
            for kk in 0..kd {
                *pp.add(kk * MR + r) = *arow.add(kk);
            }
        }
        let c = [
            op.add(i * ostride),
            op.add((i + 1) * ostride),
            op.add((i + 2) * ostride),
            op.add((i + 3) * ostride),
            op.add((i + 4) * ostride),
            op.add((i + 5) * ostride),
        ];
        panel6::<false>(kd, pp, bp, bs, &c, n);
        i += MR;
    }
    while i < m {
        panel1::<false>(kd, ap.add(i * astride), bp, bs, op.add(i * ostride), n);
        i += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn gemm_tn_impl(
    a: &MatView,
    b: &MatView,
    c0: usize,
    pack: &mut [f32],
    out: &mut MatViewMut,
) {
    let (kd, m, n) = (a.rows(), out.rows(), out.cols());
    let (ap, astride) = (a.base_ptr(), a.row_stride());
    let (bp, bs) = (b.base_ptr(), b.row_stride());
    let ostride = out.row_stride();
    let op = out.base_ptr_mut();
    let pp = pack.as_mut_ptr();
    let mut i = 0usize;
    while i + MR <= m {
        for kk in 0..kd {
            let src = ap.add(kk * astride + c0 + i);
            let dst = pp.add(kk * MR);
            for r in 0..MR {
                *dst.add(r) = *src.add(r);
            }
        }
        let c = [
            op.add(i * ostride),
            op.add((i + 1) * ostride),
            op.add((i + 2) * ostride),
            op.add((i + 3) * ostride),
            op.add((i + 4) * ostride),
            op.add((i + 5) * ostride),
        ];
        panel6::<true>(kd, pp, bp, bs, &c, n);
        i += MR;
    }
    while i < m {
        for kk in 0..kd {
            *pp.add(kk) = *ap.add(kk * astride + c0 + i);
        }
        panel1::<true>(kd, pp, bp, bs, op.add(i * ostride), n);
        i += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn gemm_nt_impl(a: &MatView, b: &MatView, out: &mut MatViewMut) {
    let (m, kd, nj) = (a.rows(), a.cols(), b.rows());
    let (ap, astride) = (a.base_ptr(), a.row_stride());
    let (bp, bs) = (b.base_ptr(), b.row_stride());
    let ostride = out.row_stride();
    let op = out.base_ptr_mut();
    for i in 0..m {
        let ar = ap.add(i * astride);
        let orow = op.add(i * ostride);
        let mut j = 0usize;
        while j + 4 <= nj {
            dot4(
                ar,
                [
                    bp.add(j * bs),
                    bp.add((j + 1) * bs),
                    bp.add((j + 2) * bs),
                    bp.add((j + 3) * bs),
                ],
                kd,
                orow.add(j),
            );
            j += 4;
        }
        while j < nj {
            *orow.add(j) = dot_impl(ar, bp.add(j * bs), kd);
            j += 1;
        }
    }
}

/// Four [`dot_impl`] chains sharing the A loads.
#[target_feature(enable = "neon")]
unsafe fn dot4(a: *const f32, b: [*const f32; 4], n: usize, out: *mut f32) {
    let mut acc0 = [vdupq_n_f32(0.0); 4];
    let mut acc1 = [vdupq_n_f32(0.0); 4];
    let mut k = 0usize;
    while k + 8 <= n {
        let a0 = vld1q_f32(a.add(k));
        let a1 = vld1q_f32(a.add(k + 4));
        for l in 0..4 {
            acc0[l] = vfmaq_f32(acc0[l], a0, vld1q_f32(b[l].add(k)));
            acc1[l] = vfmaq_f32(acc1[l], a1, vld1q_f32(b[l].add(k + 4)));
        }
        k += 8;
    }
    if k + 4 <= n {
        let a0 = vld1q_f32(a.add(k));
        for l in 0..4 {
            acc0[l] = vfmaq_f32(acc0[l], a0, vld1q_f32(b[l].add(k)));
        }
        k += 4;
    }
    for l in 0..4 {
        let mut s = hsum4(vaddq_f32(acc0[l], acc1[l]));
        let mut kk = k;
        while kk < n {
            s = (*a.add(kk)).mul_add(*b[l].add(kk), s);
            kk += 1;
        }
        *out.add(l) = s;
    }
}

// ---------------------------------------------------------------------------
// Row ops
// ---------------------------------------------------------------------------

/// Lane mirror of [`exp_ps`] — operation-for-operation identical.
#[target_feature(enable = "neon")]
#[inline]
unsafe fn exp128(x: float32x4_t) -> float32x4_t {
    let ord_mask = vceqq_f32(x, x); // 0 on NaN lanes
    let zero_mask = vcltq_f32(x, vdupq_n_f32(expf::EXP_LO));
    let xc = vminq_f32(x, vdupq_n_f32(expf::EXP_HI));
    let n = vrndmq_f32(vaddq_f32(vmulq_f32(xc, vdupq_n_f32(expf::LOG2EF)), vdupq_n_f32(0.5)));
    // r = xc − n·ln2_hi − n·ln2_lo (vfmsq ≡ (−n).mul_add(c, ·) per IEEE).
    let r = vfmsq_f32(xc, n, vdupq_n_f32(expf::LN2_HI));
    let r = vfmsq_f32(r, n, vdupq_n_f32(expf::LN2_LO));
    let mut p = vdupq_n_f32(expf::POLY[0]);
    for &c in &expf::POLY[1..] {
        p = vfmaq_f32(vdupq_n_f32(c), p, r);
    }
    let y = vfmaq_f32(vaddq_f32(r, vdupq_n_f32(1.0)), p, vmulq_f32(r, r));
    // n is integral after vrndmq, so the truncating convert is exact;
    // out-of-range lanes saturate and are discarded by the masks below.
    let pow2 = vreinterpretq_f32_s32(vshlq_n_s32::<23>(vaddq_s32(
        vcvtq_s32_f32(n),
        vdupq_n_s32(127),
    )));
    let res = vmulq_f32(y, pow2);
    let res = vbslq_f32(zero_mask, vdupq_n_f32(0.0), res);
    vbslq_f32(ord_mask, res, x)
}

#[target_feature(enable = "neon")]
unsafe fn exp_affine_scale_impl(xs: &mut [f32], a: f32, b: f32, scale: f32) {
    let (p, n) = (xs.as_mut_ptr(), xs.len());
    let av = vdupq_n_f32(a);
    let bv = vdupq_n_f32(b);
    let sv = vdupq_n_f32(scale);
    let mut k = 0usize;
    while k + 4 <= n {
        let t = vfmaq_f32(bv, av, vld1q_f32(p.add(k)));
        vst1q_f32(p.add(k), vmulq_f32(exp128(t), sv));
        k += 4;
    }
    while k < n {
        *p.add(k) = exp_ps(a.mul_add(*p.add(k), b)) * scale;
        k += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn softmax_row_impl(row: &mut [f32]) {
    let (p, n) = (row.as_mut_ptr(), row.len());
    let mut mv = vdupq_n_f32(f32::NEG_INFINITY);
    let mut k = 0usize;
    while k + 4 <= n {
        mv = vmaxq_f32(mv, vld1q_f32(p.add(k)));
        k += 4;
    }
    let mut mx = hmax4(mv);
    while k < n {
        mx = mx.max(*p.add(k));
        k += 1;
    }
    let mxv = vdupq_n_f32(mx);
    let mut sumv = vdupq_n_f32(0.0);
    k = 0;
    while k + 4 <= n {
        let e = exp128(vsubq_f32(vld1q_f32(p.add(k)), mxv));
        vst1q_f32(p.add(k), e);
        sumv = vaddq_f32(sumv, e);
        k += 4;
    }
    let mut sum = hsum4(sumv);
    while k < n {
        let e = exp_ps(*p.add(k) - mx);
        *p.add(k) = e;
        sum += e;
        k += 1;
    }
    scale_in_place(p, n, 1.0 / sum);
}

#[target_feature(enable = "neon")]
unsafe fn normalize_row_sum_impl(row: &mut [f32], delta: f32) {
    let (p, n) = (row.as_mut_ptr(), row.len());
    let mut sumv = vdupq_n_f32(0.0);
    let mut k = 0usize;
    while k + 4 <= n {
        sumv = vaddq_f32(sumv, vld1q_f32(p.add(k)));
        k += 4;
    }
    let mut sum = hsum4(sumv);
    while k < n {
        sum += *p.add(k);
        k += 1;
    }
    scale_in_place(p, n, 1.0 / (sum + delta));
}

#[target_feature(enable = "neon")]
#[inline]
unsafe fn scale_in_place(p: *mut f32, n: usize, inv: f32) {
    let iv = vdupq_n_f32(inv);
    let mut k = 0usize;
    while k + 4 <= n {
        vst1q_f32(p.add(k), vmulq_f32(vld1q_f32(p.add(k)), iv));
        k += 4;
    }
    while k < n {
        *p.add(k) *= inv;
        k += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn relu_scale_impl(xs: &mut [f32], scale: f32) {
    let (p, n) = (xs.as_mut_ptr(), xs.len());
    let zv = vdupq_n_f32(0.0);
    let sv = vdupq_n_f32(scale);
    let mut k = 0usize;
    while k + 4 <= n {
        let x = vld1q_f32(p.add(k));
        // vbsl on the x>0 mask (NaN compares false) matches f32::max's
        // NaN-to-0 behavior, unlike vmaxq which propagates NaN.
        let m = vcgtq_f32(x, zv);
        vst1q_f32(p.add(k), vmulq_f32(vbslq_f32(m, x, zv), sv));
        k += 4;
    }
    while k < n {
        *p.add(k) = (*p.add(k)).max(0.0) * scale;
        k += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn square_scale_impl(xs: &mut [f32], scale: f32) {
    let (p, n) = (xs.as_mut_ptr(), xs.len());
    let sv = vdupq_n_f32(scale);
    let mut k = 0usize;
    while k + 4 <= n {
        let x = vld1q_f32(p.add(k));
        vst1q_f32(p.add(k), vmulq_f32(vmulq_f32(x, x), sv));
        k += 4;
    }
    while k < n {
        let x = *p.add(k);
        *p.add(k) = x * x * scale;
        k += 1;
    }
}

#[target_feature(enable = "neon")]
unsafe fn elu_plus_one_impl(xs: &[f32], out: &mut [f32]) {
    let (xp, n) = (xs.as_ptr(), xs.len());
    let op = out.as_mut_ptr();
    let zv = vdupq_n_f32(0.0);
    let ov = vdupq_n_f32(1.0);
    let mut k = 0usize;
    while k + 4 <= n {
        let x = vld1q_f32(xp.add(k));
        let pos_mask = vcgtq_f32(x, zv);
        vst1q_f32(op.add(k), vbslq_f32(pos_mask, vaddq_f32(x, ov), exp128(x)));
        k += 4;
    }
    while k < n {
        let x = *xp.add(k);
        *op.add(k) = if x > 0.0 { x + 1.0 } else { exp_ps(x) };
        k += 1;
    }
}
