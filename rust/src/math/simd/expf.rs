//! Polynomial `expf` for the vectorized feature maps (ADR-010).
//!
//! Degree-5 minimax polynomial on the reduced interval (Cephes `expf`
//! coefficients), range reduction `x = n·ln2 + r` with a two-constant
//! (hi/lo) split of ln2, reconstruction by exponent-field bit twiddling.
//! Every step is expressible as lane-wise `mul_add`, so the scalar mirror
//! here ([`exp_ps`]) is bit-identical per element to the AVX2/NEON lane
//! implementations — the vector backends are tested against it exactly.
//!
//! Accuracy contract (tested below): ≤ 4 ulp vs `f64` exp over the whole
//! admissible range. Saturation: inputs ≥ [`EXP_HI`] clamp to
//! `exp(EXP_HI) ≈ 1.65e38` (never `inf`); inputs < [`EXP_LO`] flush to
//! `+0.0` (the true result would be below the f32 normal range anyway);
//! NaN propagates. `exp(0) == 1.0` exactly.
//!
//! This kernel is used by the AVX2/NEON backends only — the scalar
//! backend keeps libm `f32::exp` (no reason to give up its accuracy when
//! no lanes are in play).

/// Saturation threshold: largest input that reconstructs with an exponent
/// field ≤ 254 through the `floor(x·log2e + 0.5)` reduction.
pub const EXP_HI: f32 = 88.02;
/// Underflow threshold: below this the result would need a subnormal
/// scale factor; we flush to +0.0 instead (documented in ADR-010).
pub const EXP_LO: f32 = -87.33654;

pub const LOG2EF: f32 = 1.442695;
/// hi/lo split of ln 2: `LN2_HI` is exact in f32 (`0.693359375`), `LN2_LO`
/// carries the residual, so `r = x − n·LN2_HI − n·LN2_LO` stays accurate
/// for |n|≤128.
pub const LN2_HI: f32 = 0.6933594;
pub const LN2_LO: f32 = -2.1219444e-4;

/// Cephes expf minimax coefficients, highest degree first.
pub const POLY: [f32; 6] = [
    1.9875691e-4,
    1.3981999e-3,
    8.333452e-3,
    4.1665796e-2,
    1.6666666e-1,
    0.5,
];

/// Scalar mirror of the vector exp lanes: identical operation sequence
/// (`mul_add` everywhere the vector code uses fused multiply-add), so a
/// vector lane and this function agree bit-for-bit on every input.
#[inline]
pub fn exp_ps(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    if x < EXP_LO {
        return 0.0;
    }
    let xc = x.min(EXP_HI);
    let n = (xc * LOG2EF + 0.5).floor();
    let r = (-n).mul_add(LN2_HI, xc);
    let r = (-n).mul_add(LN2_LO, r);
    let mut p = POLY[0];
    for &c in &POLY[1..] {
        p = p.mul_add(r, c);
    }
    let y = p.mul_add(r * r, r + 1.0);
    // 2^n via the exponent field: n ∈ [−126, 127] inside the clamp range.
    let pow2 = f32::from_bits((((n as i32) + 127) as u32) << 23);
    y * pow2
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ulp_diff(a: f32, b: f64) -> u32 {
        // exp is strictly positive, so the bit patterns are directly
        // comparable as integers (monotonic over positive floats).
        let bf = b as f32;
        (a.to_bits() as i64 - bf.to_bits() as i64).unsigned_abs() as u32
    }

    #[test]
    fn exp_ps_within_4_ulp_of_f64_exp() {
        let mut worst = 0u32;
        // Dense sweep over the admissible range plus a fine grid near 0.
        let mut x = EXP_LO + 1e-3;
        while x < EXP_HI {
            let d = ulp_diff(exp_ps(x), (x as f64).exp());
            worst = worst.max(d);
            x += 0.037;
        }
        let mut x = -2.0f32;
        while x < 2.0 {
            let d = ulp_diff(exp_ps(x), (x as f64).exp());
            worst = worst.max(d);
            x += 1.7e-4;
        }
        assert!(worst <= 4, "worst ulp error {worst} > 4");
    }

    #[test]
    fn exp_ps_edge_cases() {
        assert_eq!(exp_ps(0.0), 1.0);
        assert_eq!(exp_ps(-0.0), 1.0);
        assert!(exp_ps(f32::NAN).is_nan());
        // Saturates finite, never inf.
        assert!(exp_ps(1e9).is_finite());
        assert!(exp_ps(f32::INFINITY).is_finite());
        assert!(exp_ps(1e9) > 1e38);
        // Deep negative flushes to +0.0 (true value is subnormal).
        assert_eq!(exp_ps(-200.0), 0.0);
        assert_eq!(exp_ps(f32::NEG_INFINITY), 0.0);
        assert!(exp_ps(-200.0).is_sign_positive());
        // Denormal inputs behave like 0.
        assert_eq!(exp_ps(1e-42), 1.0);
    }
}
