//! AVX2+FMA backend (ADR-010).
//!
//! Safety model: every intrinsic-bearing function carries
//! `#[target_feature(enable = "avx2", enable = "fma")]` and is
//! module-private; the safe wrapper functions below are the only entry
//! points and are installed into the dispatch table exclusively after
//! `is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")`
//! (see `kernels_for` in `mod.rs`), so the CPU contract holds whenever
//! they can be reached.
//!
//! Determinism rules (the bit-identity contract, ADR-010):
//! * every output element is one accumulator chain, sequential over k,
//!   rooted at `0.0` (`gemm_nn`/`gemm_nt`/`dot`) or at the initial output
//!   value (`gemm_tn_acc`/`axpy`) — independent of row striping, i/j
//!   tiling and view alignment (all loads are unaligned `loadu`);
//! * fused multiply-add everywhere: vector lanes use `fmadd`, scalar
//!   remainders use `f32::mul_add`, which is the same IEEE operation per
//!   element — so remainder lanes and vector lanes of different kernel
//!   widths agree bit-for-bit;
//! * `gemm_nt` produces each element through exactly the chain [`dot`]
//!   walks, so mapping a batch of feature rows (fused decode) and mapping
//!   one row at a time (sequential decode) are bit-identical;
//! * the vector `exp` lanes mirror [`super::expf::exp_ps`] operation for
//!   operation (tested exactly in `rust/tests/simd_kernels.rs`).

#![allow(clippy::needless_range_loop)]

use core::arch::x86_64::*;

use super::expf::{self, exp_ps};
use super::with_pack;
use crate::math::linalg::{MatView, MatViewMut};

/// Rows per packed A micro-panel (the classic 6×16 f32 AVX2 microkernel:
/// 12 accumulator registers + 2 B lanes + 1 broadcast = 15 of 16 ymm).
const MR: usize = 6;

// ---------------------------------------------------------------------------
// Safe wrappers — the dispatch-table entries.
// ---------------------------------------------------------------------------

pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: only reachable when avx2+fma were detected (module contract).
    unsafe { dot_impl(a.as_ptr(), b.as_ptr(), a.len()) }
}

pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // SAFETY: as above.
    unsafe { axpy_impl(alpha, x.as_ptr(), y.as_mut_ptr(), x.len()) }
}

pub fn add_assign(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    // SAFETY: as above.
    unsafe { add_assign_impl(x.as_ptr(), y.as_mut_ptr(), x.len()) }
}

pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    // SAFETY: as above.
    unsafe { sq_dist_impl(a.as_ptr(), b.as_ptr(), a.len()) }
}

pub fn gemm_nn(a: MatView, b: MatView, mut out: MatViewMut) {
    if a.cols() == 0 {
        out.fill_zero();
        return;
    }
    if out.rows() == 0 || out.cols() == 0 {
        return;
    }
    // SAFETY: as above; shapes pre-checked by the linalg entry points.
    with_pack(MR * a.cols(), |pack| unsafe { gemm_nn_impl(&a, &b, pack, &mut out) })
}

pub fn gemm_tn_acc(a: MatView, b: MatView, c0: usize, mut out: MatViewMut) {
    if a.rows() == 0 || out.rows() == 0 || out.cols() == 0 {
        return;
    }
    // SAFETY: as above.
    with_pack(MR * a.rows(), |pack| unsafe { gemm_tn_impl(&a, &b, c0, pack, &mut out) })
}

pub fn gemm_nt(a: MatView, b: MatView, mut out: MatViewMut) {
    if out.rows() == 0 || out.cols() == 0 {
        return;
    }
    // SAFETY: as above.
    unsafe { gemm_nt_impl(&a, &b, &mut out) }
}

pub fn softmax_row(row: &mut [f32]) {
    // SAFETY: as above.
    unsafe { softmax_row_impl(row) }
}

pub fn normalize_row_sum(row: &mut [f32], delta: f32) {
    // SAFETY: as above.
    unsafe { normalize_row_sum_impl(row, delta) }
}

pub fn exp_affine_scale(xs: &mut [f32], a: f32, b: f32, scale: f32) {
    // SAFETY: as above.
    unsafe { exp_affine_scale_impl(xs, a, b, scale) }
}

pub fn relu_scale(xs: &mut [f32], scale: f32) {
    // SAFETY: as above.
    unsafe { relu_scale_impl(xs, scale) }
}

pub fn square_scale(xs: &mut [f32], scale: f32) {
    // SAFETY: as above.
    unsafe { square_scale_impl(xs, scale) }
}

pub fn elu_plus_one(xs: &[f32], out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    // SAFETY: as above.
    unsafe { elu_plus_one_impl(xs, out) }
}

// ---------------------------------------------------------------------------
// Reductions
// ---------------------------------------------------------------------------

/// Fixed-order horizontal sum: (lo+hi) 4-wide, fold halves, fold pair.
/// Every kernel that reduces a ymm register uses this exact tree so equal
/// lane contents always reduce to the identical scalar.
#[target_feature(enable = "avx2", enable = "fma")]
#[inline]
unsafe fn hsum8(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps::<0b01>(s, s));
    _mm_cvtss_f32(s)
}

#[target_feature(enable = "avx2", enable = "fma")]
#[inline]
unsafe fn hmax8(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let s = _mm_max_ps(lo, hi);
    let s = _mm_max_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_max_ss(s, _mm_shuffle_ps::<0b01>(s, s));
    _mm_cvtss_f32(s)
}

// ---------------------------------------------------------------------------
// Vector kernels
// ---------------------------------------------------------------------------

/// Canonical dot chain: two lane accumulators over 16-element steps, one
/// 8-wide cleanup step, fixed-order horizontal sum, `mul_add` scalar tail.
/// `gemm_nt` replicates this chain per output element — keep in lockstep.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_impl(a: *const f32, b: *const f32, n: usize) -> f32 {
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut k = 0usize;
    while k + 16 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(a.add(k)), _mm256_loadu_ps(b.add(k)), acc0);
        acc1 = _mm256_fmadd_ps(
            _mm256_loadu_ps(a.add(k + 8)),
            _mm256_loadu_ps(b.add(k + 8)),
            acc1,
        );
        k += 16;
    }
    if k + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(a.add(k)), _mm256_loadu_ps(b.add(k)), acc0);
        k += 8;
    }
    let mut s = hsum8(_mm256_add_ps(acc0, acc1));
    while k < n {
        s = (*a.add(k)).mul_add(*b.add(k), s);
        k += 1;
    }
    s
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn axpy_impl(alpha: f32, x: *const f32, y: *mut f32, n: usize) {
    let av = _mm256_set1_ps(alpha);
    let mut k = 0usize;
    while k + 8 <= n {
        let yv = _mm256_fmadd_ps(av, _mm256_loadu_ps(x.add(k)), _mm256_loadu_ps(y.add(k)));
        _mm256_storeu_ps(y.add(k), yv);
        k += 8;
    }
    while k < n {
        *y.add(k) = alpha.mul_add(*x.add(k), *y.add(k));
        k += 1;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn add_assign_impl(x: *const f32, y: *mut f32, n: usize) {
    let mut k = 0usize;
    while k + 8 <= n {
        let yv = _mm256_add_ps(_mm256_loadu_ps(y.add(k)), _mm256_loadu_ps(x.add(k)));
        _mm256_storeu_ps(y.add(k), yv);
        k += 8;
    }
    while k < n {
        *y.add(k) += *x.add(k);
        k += 1;
    }
}

/// Mirrors the [`dot_impl`] chain with `d = a − b`, `acc += d·d`.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn sq_dist_impl(a: *const f32, b: *const f32, n: usize) -> f32 {
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut k = 0usize;
    while k + 16 <= n {
        let d0 = _mm256_sub_ps(_mm256_loadu_ps(a.add(k)), _mm256_loadu_ps(b.add(k)));
        let d1 = _mm256_sub_ps(_mm256_loadu_ps(a.add(k + 8)), _mm256_loadu_ps(b.add(k + 8)));
        acc0 = _mm256_fmadd_ps(d0, d0, acc0);
        acc1 = _mm256_fmadd_ps(d1, d1, acc1);
        k += 16;
    }
    if k + 8 <= n {
        let d0 = _mm256_sub_ps(_mm256_loadu_ps(a.add(k)), _mm256_loadu_ps(b.add(k)));
        acc0 = _mm256_fmadd_ps(d0, d0, acc0);
        k += 8;
    }
    let mut s = hsum8(_mm256_add_ps(acc0, acc1));
    while k < n {
        let d = *a.add(k) - *b.add(k);
        s = d.mul_add(d, s);
        k += 1;
    }
    s
}

// ---------------------------------------------------------------------------
// Packed GEMM (nn and tn share the microkernels; `LOAD_C` selects whether
// the accumulator chain roots at 0 — `C = A·B` — or at the existing output
// — `C += AᵀB`).
// ---------------------------------------------------------------------------

/// 6×16 register-blocked microkernel over a k-major packed A panel
/// (`pack[kk*MR + r]`) and 16 consecutive B columns at `bp`.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn mk6x16<const LOAD_C: bool>(
    kc: usize,
    pack: *const f32,
    bp: *const f32,
    bs: usize,
    c: &[*mut f32; MR],
) {
    let mut acc = [[_mm256_setzero_ps(); 2]; MR];
    if LOAD_C {
        for r in 0..MR {
            acc[r][0] = _mm256_loadu_ps(c[r]);
            acc[r][1] = _mm256_loadu_ps(c[r].add(8));
        }
    }
    for kk in 0..kc {
        let b0 = _mm256_loadu_ps(bp.add(kk * bs));
        let b1 = _mm256_loadu_ps(bp.add(kk * bs + 8));
        let pk = pack.add(kk * MR);
        for r in 0..MR {
            let av = _mm256_broadcast_ss(&*pk.add(r));
            acc[r][0] = _mm256_fmadd_ps(av, b0, acc[r][0]);
            acc[r][1] = _mm256_fmadd_ps(av, b1, acc[r][1]);
        }
    }
    for r in 0..MR {
        _mm256_storeu_ps(c[r], acc[r][0]);
        _mm256_storeu_ps(c[r].add(8), acc[r][1]);
    }
}

/// 6×8 column-tail variant of [`mk6x16`].
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn mk6x8<const LOAD_C: bool>(
    kc: usize,
    pack: *const f32,
    bp: *const f32,
    bs: usize,
    c: &[*mut f32; MR],
) {
    let mut acc = [_mm256_setzero_ps(); MR];
    if LOAD_C {
        for r in 0..MR {
            acc[r] = _mm256_loadu_ps(c[r]);
        }
    }
    for kk in 0..kc {
        let b0 = _mm256_loadu_ps(bp.add(kk * bs));
        let pk = pack.add(kk * MR);
        for r in 0..MR {
            acc[r] = _mm256_fmadd_ps(_mm256_broadcast_ss(&*pk.add(r)), b0, acc[r]);
        }
    }
    for r in 0..MR {
        _mm256_storeu_ps(c[r], acc[r]);
    }
}

/// Full j-sweep (16-wide, 8-wide, scalar-`mul_add` tail) for one packed
/// panel of `MR` A rows. `c` holds the six output-row base pointers.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn panel6<const LOAD_C: bool>(
    kc: usize,
    pack: *const f32,
    bp: *const f32,
    bs: usize,
    c: &[*mut f32; MR],
    n: usize,
) {
    let mut j = 0usize;
    while j + 16 <= n {
        let cj = [
            c[0].add(j),
            c[1].add(j),
            c[2].add(j),
            c[3].add(j),
            c[4].add(j),
            c[5].add(j),
        ];
        mk6x16::<LOAD_C>(kc, pack, bp.add(j), bs, &cj);
        j += 16;
    }
    if j + 8 <= n {
        let cj = [
            c[0].add(j),
            c[1].add(j),
            c[2].add(j),
            c[3].add(j),
            c[4].add(j),
            c[5].add(j),
        ];
        mk6x8::<LOAD_C>(kc, pack, bp.add(j), bs, &cj);
        j += 8;
    }
    while j < n {
        for r in 0..MR {
            let mut s = if LOAD_C { *c[r].add(j) } else { 0.0 };
            for kk in 0..kc {
                s = (*pack.add(kk * MR + r)).mul_add(*bp.add(kk * bs + j), s);
            }
            *c[r].add(j) = s;
        }
        j += 1;
    }
}

/// Single-row kernel (`1×16`, `1×8`, scalar tail) for the `rows % MR`
/// remainder; `ar` is a contiguous k-vector (an A row, or a packed A
/// column for the tn case). Per-element chains match [`panel6`] exactly.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn panel1<const LOAD_C: bool>(
    kc: usize,
    ar: *const f32,
    bp: *const f32,
    bs: usize,
    co: *mut f32,
    n: usize,
) {
    let mut j = 0usize;
    while j + 16 <= n {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        if LOAD_C {
            acc0 = _mm256_loadu_ps(co.add(j));
            acc1 = _mm256_loadu_ps(co.add(j + 8));
        }
        for kk in 0..kc {
            let av = _mm256_broadcast_ss(&*ar.add(kk));
            acc0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(bp.add(kk * bs + j)), acc0);
            acc1 = _mm256_fmadd_ps(av, _mm256_loadu_ps(bp.add(kk * bs + j + 8)), acc1);
        }
        _mm256_storeu_ps(co.add(j), acc0);
        _mm256_storeu_ps(co.add(j + 8), acc1);
        j += 16;
    }
    if j + 8 <= n {
        let mut acc0 = _mm256_setzero_ps();
        if LOAD_C {
            acc0 = _mm256_loadu_ps(co.add(j));
        }
        for kk in 0..kc {
            let av = _mm256_broadcast_ss(&*ar.add(kk));
            acc0 = _mm256_fmadd_ps(av, _mm256_loadu_ps(bp.add(kk * bs + j)), acc0);
        }
        _mm256_storeu_ps(co.add(j), acc0);
        j += 8;
    }
    while j < n {
        let mut s = if LOAD_C { *co.add(j) } else { 0.0 };
        for kk in 0..kc {
            s = (*ar.add(kk)).mul_add(*bp.add(kk * bs + j), s);
        }
        *co.add(j) = s;
        j += 1;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn gemm_nn_impl(a: &MatView, b: &MatView, pack: &mut [f32], out: &mut MatViewMut) {
    let (m, kd, n) = (a.rows(), a.cols(), b.cols());
    let (ap, astride) = (a.base_ptr(), a.row_stride());
    let (bp, bs) = (b.base_ptr(), b.row_stride());
    let ostride = out.row_stride();
    let op = out.base_ptr_mut();
    let pp = pack.as_mut_ptr();
    let mut i = 0usize;
    while i + MR <= m {
        // Pack MR rows of A k-major: pack[kk*MR + r] = a[i+r][kk].
        for r in 0..MR {
            let arow = ap.add((i + r) * astride);
            for kk in 0..kd {
                *pp.add(kk * MR + r) = *arow.add(kk);
            }
        }
        let c = [
            op.add(i * ostride),
            op.add((i + 1) * ostride),
            op.add((i + 2) * ostride),
            op.add((i + 3) * ostride),
            op.add((i + 4) * ostride),
            op.add((i + 5) * ostride),
        ];
        panel6::<false>(kd, pp, bp, bs, &c, n);
        i += MR;
    }
    while i < m {
        panel1::<false>(kd, ap.add(i * astride), bp, bs, op.add(i * ostride), n);
        i += 1;
    }
}

/// Accumulate output rows `[c0, c0 + out.rows())` of `AᵀB` into `out`.
/// A is k×(m_total); output row `i` is A column `c0+i`, packed k-major
/// into the same panel layout `gemm_nn` uses.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn gemm_tn_impl(
    a: &MatView,
    b: &MatView,
    c0: usize,
    pack: &mut [f32],
    out: &mut MatViewMut,
) {
    let (kd, m, n) = (a.rows(), out.rows(), out.cols());
    let (ap, astride) = (a.base_ptr(), a.row_stride());
    let (bp, bs) = (b.base_ptr(), b.row_stride());
    let ostride = out.row_stride();
    let op = out.base_ptr_mut();
    let pp = pack.as_mut_ptr();
    let mut i = 0usize;
    while i + MR <= m {
        // Transpose-pack MR columns of A: pack[kk*MR + r] = a[kk][c0+i+r]
        // (contiguous 6-float reads per k row, contiguous panel writes).
        for kk in 0..kd {
            let src = ap.add(kk * astride + c0 + i);
            let dst = pp.add(kk * MR);
            for r in 0..MR {
                *dst.add(r) = *src.add(r);
            }
        }
        let c = [
            op.add(i * ostride),
            op.add((i + 1) * ostride),
            op.add((i + 2) * ostride),
            op.add((i + 3) * ostride),
            op.add((i + 4) * ostride),
            op.add((i + 5) * ostride),
        ];
        panel6::<true>(kd, pp, bp, bs, &c, n);
        i += MR;
    }
    while i < m {
        // Pack the single A column c0+i into a contiguous k-vector.
        for kk in 0..kd {
            *pp.add(kk) = *ap.add(kk * astride + c0 + i);
        }
        panel1::<true>(kd, pp, bp, bs, op.add(i * ostride), n);
        i += 1;
    }
}

/// `C = A·Bᵀ`: each element is the [`dot_impl`] chain of an A row with a
/// B row; a 4-wide j-block shares the A loads, replicating that chain per
/// j so blocked and single-element paths agree bit-for-bit.
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn gemm_nt_impl(a: &MatView, b: &MatView, out: &mut MatViewMut) {
    let (m, kd, nj) = (a.rows(), a.cols(), b.rows());
    let (ap, astride) = (a.base_ptr(), a.row_stride());
    let (bp, bs) = (b.base_ptr(), b.row_stride());
    let ostride = out.row_stride();
    let op = out.base_ptr_mut();
    for i in 0..m {
        let ar = ap.add(i * astride);
        let orow = op.add(i * ostride);
        let mut j = 0usize;
        while j + 4 <= nj {
            dot4(
                ar,
                [
                    bp.add(j * bs),
                    bp.add((j + 1) * bs),
                    bp.add((j + 2) * bs),
                    bp.add((j + 3) * bs),
                ],
                kd,
                orow.add(j),
            );
            j += 4;
        }
        while j < nj {
            *orow.add(j) = dot_impl(ar, bp.add(j * bs), kd);
            j += 1;
        }
    }
}

/// Four [`dot_impl`] chains sharing the A loads (2 accumulators each →
/// 8 live ymm registers plus 2 A lanes).
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot4(a: *const f32, b: [*const f32; 4], n: usize, out: *mut f32) {
    let mut acc0 = [_mm256_setzero_ps(); 4];
    let mut acc1 = [_mm256_setzero_ps(); 4];
    let mut k = 0usize;
    while k + 16 <= n {
        let a0 = _mm256_loadu_ps(a.add(k));
        let a1 = _mm256_loadu_ps(a.add(k + 8));
        for l in 0..4 {
            acc0[l] = _mm256_fmadd_ps(a0, _mm256_loadu_ps(b[l].add(k)), acc0[l]);
            acc1[l] = _mm256_fmadd_ps(a1, _mm256_loadu_ps(b[l].add(k + 8)), acc1[l]);
        }
        k += 16;
    }
    if k + 8 <= n {
        let a0 = _mm256_loadu_ps(a.add(k));
        for l in 0..4 {
            acc0[l] = _mm256_fmadd_ps(a0, _mm256_loadu_ps(b[l].add(k)), acc0[l]);
        }
        k += 8;
    }
    for l in 0..4 {
        let mut s = hsum8(_mm256_add_ps(acc0[l], acc1[l]));
        let mut kk = k;
        while kk < n {
            s = (*a.add(kk)).mul_add(*b[l].add(kk), s);
            kk += 1;
        }
        *out.add(l) = s;
    }
}

// ---------------------------------------------------------------------------
// Row ops (feature maps, softmax, normalization)
// ---------------------------------------------------------------------------

/// Vector mirror of [`exp_ps`] — operation-for-operation identical per
/// lane (see the bit-identity test in `rust/tests/simd_kernels.rs`).
#[target_feature(enable = "avx2", enable = "fma")]
#[inline]
unsafe fn exp256(x: __m256) -> __m256 {
    let nan_mask = _mm256_cmp_ps::<_CMP_UNORD_Q>(x, x);
    let zero_mask = _mm256_cmp_ps::<_CMP_LT_OQ>(x, _mm256_set1_ps(expf::EXP_LO));
    let xc = _mm256_min_ps(x, _mm256_set1_ps(expf::EXP_HI));
    // n = floor(xc·log2e + 0.5) — plain mul+add, matching the scalar mirror.
    let n = _mm256_floor_ps(_mm256_add_ps(
        _mm256_mul_ps(xc, _mm256_set1_ps(expf::LOG2EF)),
        _mm256_set1_ps(0.5),
    ));
    // r = xc − n·ln2_hi − n·ln2_lo (fnmadd ≡ (−n).mul_add(c, ·) per IEEE).
    let r = _mm256_fnmadd_ps(n, _mm256_set1_ps(expf::LN2_HI), xc);
    let r = _mm256_fnmadd_ps(n, _mm256_set1_ps(expf::LN2_LO), r);
    let mut p = _mm256_set1_ps(expf::POLY[0]);
    for &c in &expf::POLY[1..] {
        p = _mm256_fmadd_ps(p, r, _mm256_set1_ps(c));
    }
    let y = _mm256_fmadd_ps(p, _mm256_mul_ps(r, r), _mm256_add_ps(r, _mm256_set1_ps(1.0)));
    // 2^n through the exponent field (n ∈ [−126, 127] inside the clamp;
    // lanes outside are discarded by the masks below).
    let pow2 = _mm256_castsi256_ps(_mm256_slli_epi32::<23>(_mm256_add_epi32(
        _mm256_cvtps_epi32(n),
        _mm256_set1_epi32(127),
    )));
    let res = _mm256_mul_ps(y, pow2);
    let res = _mm256_andnot_ps(zero_mask, res);
    _mm256_blendv_ps(res, x, nan_mask)
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn exp_affine_scale_impl(xs: &mut [f32], a: f32, b: f32, scale: f32) {
    let (p, n) = (xs.as_mut_ptr(), xs.len());
    let av = _mm256_set1_ps(a);
    let bv = _mm256_set1_ps(b);
    let sv = _mm256_set1_ps(scale);
    let mut k = 0usize;
    while k + 8 <= n {
        let t = _mm256_fmadd_ps(av, _mm256_loadu_ps(p.add(k)), bv);
        _mm256_storeu_ps(p.add(k), _mm256_mul_ps(exp256(t), sv));
        k += 8;
    }
    while k < n {
        *p.add(k) = exp_ps(a.mul_add(*p.add(k), b)) * scale;
        k += 1;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn softmax_row_impl(row: &mut [f32]) {
    let (p, n) = (row.as_mut_ptr(), row.len());
    let mut mv = _mm256_set1_ps(f32::NEG_INFINITY);
    let mut k = 0usize;
    while k + 8 <= n {
        mv = _mm256_max_ps(mv, _mm256_loadu_ps(p.add(k)));
        k += 8;
    }
    let mut mx = hmax8(mv);
    while k < n {
        mx = mx.max(*p.add(k));
        k += 1;
    }
    let mxv = _mm256_set1_ps(mx);
    let mut sumv = _mm256_setzero_ps();
    k = 0;
    while k + 8 <= n {
        let e = exp256(_mm256_sub_ps(_mm256_loadu_ps(p.add(k)), mxv));
        _mm256_storeu_ps(p.add(k), e);
        sumv = _mm256_add_ps(sumv, e);
        k += 8;
    }
    let mut sum = hsum8(sumv);
    while k < n {
        let e = exp_ps(*p.add(k) - mx);
        *p.add(k) = e;
        sum += e;
        k += 1;
    }
    scale_in_place(p, n, 1.0 / sum);
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn normalize_row_sum_impl(row: &mut [f32], delta: f32) {
    let (p, n) = (row.as_mut_ptr(), row.len());
    let mut sumv = _mm256_setzero_ps();
    let mut k = 0usize;
    while k + 8 <= n {
        sumv = _mm256_add_ps(sumv, _mm256_loadu_ps(p.add(k)));
        k += 8;
    }
    let mut sum = hsum8(sumv);
    while k < n {
        sum += *p.add(k);
        k += 1;
    }
    scale_in_place(p, n, 1.0 / (sum + delta));
}

#[target_feature(enable = "avx2", enable = "fma")]
#[inline]
unsafe fn scale_in_place(p: *mut f32, n: usize, inv: f32) {
    let iv = _mm256_set1_ps(inv);
    let mut k = 0usize;
    while k + 8 <= n {
        _mm256_storeu_ps(p.add(k), _mm256_mul_ps(_mm256_loadu_ps(p.add(k)), iv));
        k += 8;
    }
    while k < n {
        *p.add(k) *= inv;
        k += 1;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn relu_scale_impl(xs: &mut [f32], scale: f32) {
    let (p, n) = (xs.as_mut_ptr(), xs.len());
    let zv = _mm256_setzero_ps();
    let sv = _mm256_set1_ps(scale);
    let mut k = 0usize;
    while k + 8 <= n {
        // max_ps(x, 0) returns 0 for NaN lanes, matching f32::max.
        let v = _mm256_mul_ps(_mm256_max_ps(_mm256_loadu_ps(p.add(k)), zv), sv);
        _mm256_storeu_ps(p.add(k), v);
        k += 8;
    }
    while k < n {
        *p.add(k) = (*p.add(k)).max(0.0) * scale;
        k += 1;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn square_scale_impl(xs: &mut [f32], scale: f32) {
    let (p, n) = (xs.as_mut_ptr(), xs.len());
    let sv = _mm256_set1_ps(scale);
    let mut k = 0usize;
    while k + 8 <= n {
        let x = _mm256_loadu_ps(p.add(k));
        _mm256_storeu_ps(p.add(k), _mm256_mul_ps(_mm256_mul_ps(x, x), sv));
        k += 8;
    }
    while k < n {
        let x = *p.add(k);
        *p.add(k) = x * x * scale;
        k += 1;
    }
}

#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn elu_plus_one_impl(xs: &[f32], out: &mut [f32]) {
    let (xp, n) = (xs.as_ptr(), xs.len());
    let op = out.as_mut_ptr();
    let zv = _mm256_setzero_ps();
    let ov = _mm256_set1_ps(1.0);
    let mut k = 0usize;
    while k + 8 <= n {
        let x = _mm256_loadu_ps(xp.add(k));
        let pos_mask = _mm256_cmp_ps::<_CMP_GT_OQ>(x, zv);
        let pos = _mm256_add_ps(x, ov);
        let neg = exp256(x);
        _mm256_storeu_ps(op.add(k), _mm256_blendv_ps(neg, pos, pos_mask));
        k += 8;
    }
    while k < n {
        let x = *xp.add(k);
        *op.add(k) = if x > 0.0 { x + 1.0 } else { exp_ps(x) };
        k += 1;
    }
}
