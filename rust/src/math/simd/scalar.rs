//! Always-compiled safe scalar backend (ADR-010).
//!
//! This is both the portable fallback and the reference the SIMD backends
//! are property-tested against. It is deliberately written with wide
//! independent accumulators and no per-element branches so that rustc's
//! autovectorizer produces respectable code even here — the "scalar"
//! label means "no explicit intrinsics", not "naive".
//!
//! Determinism contract: every output element is produced by a single
//! accumulator chain walked sequentially over the contraction dimension,
//! independent of row striping, view striding, and slice alignment. That
//! is the invariant the bit-identity tests (threaded==serial,
//! strided==owned, fused==sequential) lean on — see ADR-010.

use crate::math::linalg::{MatView, MatViewMut};

/// 8-accumulator dot product (the autovectorizer turns this into two
/// 4-wide SSE chains on baseline x86_64).
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for i in 0..chunks {
        let j = i * 8;
        for (l, s) in acc.iter_mut().enumerate() {
            *s += a[j + l] * b[j + l];
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for j in chunks * 8..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// `y += x` (the column-sum inner loop of `z += Ψ(K)ᵀ1`).
pub fn add_assign(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += xi;
    }
}

/// 8-accumulator squared L2 distance.
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 8];
    let chunks = a.len() / 8;
    for i in 0..chunks {
        let j = i * 8;
        for (l, s) in acc.iter_mut().enumerate() {
            let d = a[j + l] - b[j + l];
            *s += d * d;
        }
    }
    let mut s = ((acc[0] + acc[4]) + (acc[1] + acc[5])) + ((acc[2] + acc[6]) + (acc[3] + acc[7]));
    for j in chunks * 8..a.len() {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// One row stripe of `C = A·B` (i-k-j, axpy inner loop over contiguous
/// rows of B, k-blocked so the B panel stays cache-resident). Branch-free:
/// the old `if aik != 0.0` skip mispredicted on dense serving data and the
/// sparsity it exploited never occurs on the hot path.
pub fn gemm_nn(a: MatView, b: MatView, mut out: MatViewMut) {
    let k_dim = a.cols();
    const KB: usize = 64;
    out.fill_zero();
    for kb in (0..k_dim).step_by(KB) {
        let k_end = (kb + KB).min(k_dim);
        for i in 0..a.rows() {
            let a_row = a.row(i);
            let c_row = out.row_mut(i);
            for (k, &aik) in a_row.iter().enumerate().take(k_end).skip(kb) {
                axpy(aik, b.row(k), c_row);
            }
        }
    }
}

/// Accumulate output rows `[c0, c0 + out.rows())` of `AᵀB` into `out`
/// (k-outer so per-element accumulation order is stripe-independent).
pub fn gemm_tn_acc(a: MatView, b: MatView, c0: usize, mut out: MatViewMut) {
    for k in 0..a.rows() {
        let a_row = &a.row(k)[c0..c0 + out.rows()];
        let b_row = b.row(k);
        for (i, &aik) in a_row.iter().enumerate() {
            axpy(aik, b_row, out.row_mut(i));
        }
    }
}

/// One row stripe of `C = A·Bᵀ` — per-element [`dot`], so a 1-row call is
/// bit-identical to the batched call (fused decode maps a batch of rows
/// through the same chain a sequential decode walks one at a time).
pub fn gemm_nt(a: MatView, b: MatView, mut out: MatViewMut) {
    for i in 0..a.rows() {
        let ar = a.row(i);
        let orow = out.row_mut(i);
        for (j, o) in orow.iter_mut().enumerate() {
            *o = dot(ar, b.row(j));
        }
    }
}

/// In-place numerically-stabilized softmax over one row.
pub fn softmax_row(row: &mut [f32]) {
    let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in row.iter_mut() {
        *x = (*x - mx).exp();
        sum += *x;
    }
    let inv = 1.0 / sum;
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// `row *= 1 / (Σrow + delta)` — kernel normalization of Eq. 11.
pub fn normalize_row_sum(row: &mut [f32], delta: f32) {
    let sum: f32 = row.iter().sum();
    let inv = 1.0 / (sum + delta);
    for x in row.iter_mut() {
        *x *= inv;
    }
}

/// `x = exp(a·x + b) · scale` — the shared inner loop of the PRF map
/// (`a=√(2s), b=−s`), FAVOR+ softmax features (`a=1, b=−‖u‖²/2`) and the
/// stabilized score exponentials (`a=scale, b=−max`).
pub fn exp_affine_scale(xs: &mut [f32], a: f32, b: f32, scale: f32) {
    for x in xs.iter_mut() {
        *x = (a * *x + b).exp() * scale;
    }
}

/// `x = max(x, 0) · scale` (FAVOR+ ReLU features).
pub fn relu_scale(xs: &mut [f32], scale: f32) {
    for x in xs.iter_mut() {
        *x = x.max(0.0) * scale;
    }
}

/// `x = x² · scale` (anchored quadratic features).
pub fn square_scale(xs: &mut [f32], scale: f32) {
    for x in xs.iter_mut() {
        *x = *x * *x * scale;
    }
}

/// `out[i] = elu(x[i]) + 1` (cosFormer/linear-transformer feature map).
pub fn elu_plus_one(xs: &[f32], out: &mut [f32]) {
    debug_assert_eq!(xs.len(), out.len());
    for (o, &x) in out.iter_mut().zip(xs.iter()) {
        *o = if x > 0.0 { x + 1.0 } else { x.exp() };
    }
}
