//! Gauss–Laguerre quadrature (§2.4.1 / Appendix J of the paper).
//!
//! Computes nodes `t_r` and weights `α_r` for `∫₀^∞ e^{−t} f(t) dt ≈
//! Σ α_r f(t_r)` via Newton iteration on the Laguerre polynomial `L_R`,
//! then applies the paper's change of variables `t = C·s` so that
//! `∫₀^∞ e^{−Cs} h(s) ds ≈ Σ w_r h(s_r)` with `s_r = t_r/C`, `w_r = α_r/C`.
//!
//! No scipy equivalent exists on the Rust side, so this is implemented from
//! scratch (f64 throughout; validated against closed-form integrals and the
//! spherical Yat-kernel's exact value in the tests).

/// One quadrature rule: `nodes[i]` ↔ `weights[i]`.
#[derive(Clone, Debug)]
pub struct GaussLaguerre {
    pub nodes: Vec<f64>,
    pub weights: Vec<f64>,
}

/// Evaluate `(L_n(x), L_n'(x))` by the three-term recurrence.
fn laguerre_and_deriv(n: usize, x: f64) -> (f64, f64) {
    // L_0 = 1, L_1 = 1 - x, (k+1) L_{k+1} = (2k+1-x) L_k − k L_{k−1}
    let mut lm1 = 1.0; // L_{k-1}
    let mut l = 1.0 - x; // L_k
    if n == 0 {
        return (1.0, 0.0);
    }
    for k in 1..n {
        let lp1 = ((2.0 * k as f64 + 1.0 - x) * l - k as f64 * lm1) / (k as f64 + 1.0);
        lm1 = l;
        l = lp1;
    }
    // L_n'(x) = n (L_n(x) − L_{n−1}(x)) / x
    let deriv = if x.abs() > 1e-300 {
        n as f64 * (l - lm1) / x
    } else {
        -(n as f64) // L_n'(0) = −n
    };
    (l, deriv)
}

impl GaussLaguerre {
    /// Standard rule for weight `e^{−t}` on `[0, ∞)` with `r` nodes.
    pub fn new(r: usize) -> Self {
        assert!(r >= 1 && r <= 128, "unsupported node count {r}");
        let mut nodes = Vec::with_capacity(r);
        let mut weights = Vec::with_capacity(r);
        let n = r as f64;
        let mut x = 0.0f64;
        for i in 0..r {
            // Stroud & Secrest initial guesses.
            x = match i {
                0 => 3.0 / (1.0 + 2.4 * n),
                1 => x + 15.0 / (1.0 + 2.5 * n),
                _ => {
                    let ai = i as f64 - 1.0;
                    x + (1.0 + 2.55 * ai) / (1.9 * ai) * (x - nodes[i - 2])
                }
            };
            // Newton iterations on L_r(x) = 0.
            let mut l;
            let mut dl = 0.0;
            for _ in 0..100 {
                let (li, dli) = laguerre_and_deriv(r, x);
                l = li;
                dl = dli;
                let dx = l / dl;
                x -= dx;
                if dx.abs() < 1e-14 * (1.0 + x.abs()) {
                    break;
                }
            }
            let _ = dl;
            // α_i = x_i / ((r+1)² L_{r+1}(x_i)²)
            let (lp1, _) = laguerre_and_deriv(r + 1, x);
            let w = x / ((n + 1.0) * (n + 1.0) * lp1 * lp1);
            nodes.push(x);
            weights.push(w);
        }
        GaussLaguerre { nodes, weights }
    }

    /// Paper's scaled rule for `∫₀^∞ e^{−Cs} h(s) ds` (App. J): nodes
    /// `s_r = t_r/C`, weights `w_r = α_r/C` (the `1/C` factor from `t=Cs`
    /// is folded into the weights).
    pub fn scaled(r: usize, c: f64) -> Self {
        assert!(c > 0.0);
        let base = GaussLaguerre::new(r);
        GaussLaguerre {
            nodes: base.nodes.iter().map(|t| t / c).collect(),
            weights: base.weights.iter().map(|a| a / c).collect(),
        }
    }

    /// `Σ w_r f(s_r)`.
    pub fn integrate(&self, f: impl Fn(f64) -> f64) -> f64 {
        self.nodes
            .iter()
            .zip(self.weights.iter())
            .map(|(&s, &w)| w * f(s))
            .sum()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

/// Exact spherical Yat-kernel `E_sph(x) = x²/(C − 2x)` with `C = 2 + ε`
/// (Eq. 5) — the ground truth the quadrature approximates.
#[inline]
pub fn e_sph_exact(x: f64, eps: f64) -> f64 {
    let c = 2.0 + eps;
    x * x / (c - 2.0 * x)
}

/// Quadrature approximation of `E_sph(x)` with `R` nodes (Eq. 8 + §2.4.1):
/// `Σ_r w_r · x² e^{2 s_r x}`.
pub fn e_sph_quadrature(x: f64, eps: f64, r: usize) -> f64 {
    let c = 2.0 + eps;
    let q = GaussLaguerre::scaled(r, c);
    q.integrate(|s| x * x * (2.0 * s * x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn integrates_polynomials_exactly() {
        // ∫ e^{-t} t^k dt = k!  — exact for k ≤ 2R−1.
        let q = GaussLaguerre::new(5);
        let fact = [1.0, 1.0, 2.0, 6.0, 24.0, 120.0, 720.0, 5040.0, 40320.0, 362880.0];
        for k in 0..=9usize {
            let got = q.integrate(|t| t.powi(k as i32));
            assert!(
                (got - fact[k]).abs() < 1e-8 * fact[k].max(1.0),
                "k={k} got={got} want={}",
                fact[k]
            );
        }
    }

    #[test]
    fn weights_sum_to_one() {
        // ∫ e^{-t} dt = 1
        for r in [1, 2, 3, 4, 8, 16, 32] {
            let q = GaussLaguerre::new(r);
            let s: f64 = q.weights.iter().sum();
            assert!((s - 1.0).abs() < 1e-10, "r={r} sum={s}");
        }
    }

    #[test]
    fn nodes_positive_and_increasing() {
        let q = GaussLaguerre::new(16);
        assert!(q.nodes[0] > 0.0);
        for w in q.nodes.windows(2) {
            assert!(w[1] > w[0]);
        }
        assert!(q.weights.iter().all(|&w| w > 0.0));
    }

    #[test]
    fn matches_known_gl2_rule() {
        // R=2: nodes 2∓√2, weights (2±√2)/4.
        let q = GaussLaguerre::new(2);
        let s2 = 2f64.sqrt();
        assert!((q.nodes[0] - (2.0 - s2)).abs() < 1e-12);
        assert!((q.nodes[1] - (2.0 + s2)).abs() < 1e-12);
        assert!((q.weights[0] - (2.0 + s2) / 4.0).abs() < 1e-12);
        assert!((q.weights[1] - (2.0 - s2) / 4.0).abs() < 1e-12);
    }

    #[test]
    fn scaled_rule_integrates_exponential() {
        // ∫ e^{-Cs} ds = 1/C
        let c = 2.001;
        let q = GaussLaguerre::scaled(8, c);
        let got = q.integrate(|_| 1.0);
        assert!((got - 1.0 / c).abs() < 1e-10);
    }

    #[test]
    fn quadrature_converges_to_exact_kernel() {
        // Fig. 9 phenomenon: exponential convergence in R.
        let eps = 1e-3;
        for &x in &[-1.0, -0.5, 0.0, 0.3, 0.7, 0.9] {
            let exact = e_sph_exact(x, eps);
            let mut prev_err = f64::INFINITY;
            for r in [2usize, 4, 8, 16] {
                let err = (e_sph_quadrature(x, eps, r) - exact).abs();
                assert!(err <= prev_err + 1e-12, "x={x} r={r}: {err} > {prev_err}");
                prev_err = err;
            }
            // Relative tolerance: convergence base worsens as x → 1 (the
            // effective decay is e^{-(C-2x)s}); 1% at R=16 matches Fig. 9.
            assert!(
                prev_err < 1e-2 * exact.abs().max(1e-3),
                "x={x} final rel err {}",
                prev_err / exact.abs().max(1e-300)
            );
        }
    }

    #[test]
    fn kernel_bound_holds_exactly_at_x_one() {
        // Prop. 3: max over [-1,1] is 1/ε at x=1.
        let eps = 1e-3;
        assert!((e_sph_exact(1.0, eps) - 1.0 / eps).abs() < 1e-6 / eps);
        for i in 0..=200 {
            let x = -1.0 + 2.0 * i as f64 / 200.0;
            let v = e_sph_exact(x, eps);
            assert!(v >= 0.0 && v <= 1.0 / eps + 1e-9);
        }
    }

    #[test]
    fn laplace_only_identity_appendix_f() {
        // x²/(C−2x) = (C²/4)∫e^{−Cs}e^{2sx}ds − C/4 − x/2 (App. F).
        let eps = 0.05;
        let c = 2.0 + eps;
        let q = GaussLaguerre::scaled(48, c);
        for &x in &[-0.9, -0.3, 0.0, 0.4, 0.8] {
            let lhs = e_sph_exact(x, eps);
            let integral = q.integrate(|s| (2.0 * s * x).exp());
            let rhs = c * c / 4.0 * integral - c / 4.0 - x / 2.0;
            assert!((lhs - rhs).abs() < 1e-4, "x={x}: {lhs} vs {rhs}");
        }
    }
}
