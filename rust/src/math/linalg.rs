//! Dense linear algebra on row-major `f32` matrices.
//!
//! No BLAS/ndarray is available offline, so this module provides the small
//! but heavily optimized core the attention engines need: cache-blocked,
//! optionally multi-threaded matmul (plain / A-transposed), row ops,
//! normalization, softmax and reductions. Everything is `f32` storage with
//! `f32` accumulation in the blocked kernels (matching the JAX side) except
//! where noted.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major vector (length must match).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Build by evaluating `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    /// Random N(0,1) entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut crate::math::rng::Rng) -> Self {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Transpose (out-of-place).
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // simple tiling for cache behaviour
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// L2-normalize every row in place (unit-sphere projection, Eq. 2 of the
    /// paper). Rows with norm below `1e-12` are left untouched.
    pub fn normalize_rows(&mut self) {
        for r in 0..self.rows {
            let row = self.row_mut(r);
            let n: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
            if n > 1e-12 {
                let inv = 1.0 / n;
                for x in row.iter_mut() {
                    *x *= inv;
                }
            }
        }
    }

    /// Returned normalized copy.
    pub fn normalized_rows(&self) -> Mat {
        let mut m = self.clone();
        m.normalize_rows();
        m
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f32) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }

    /// `self += other`.
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "hcat: row mismatch");
        let cols = self.cols + other.cols;
        let mut out = Mat::zeros(self.rows, cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

/// Dot product of two slices (f32 accumulate, unrolled by the compiler).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc0 += a[j] * b[j];
        acc1 += a[j + 1] * b[j + 1];
        acc2 += a[j + 2] * b[j + 2];
        acc3 += a[j + 3] * b[j + 3];
    }
    let mut acc = acc0 + acc1 + acc2 + acc3;
    for j in chunks * 4..a.len() {
        acc += a[j] * b[j];
    }
    acc
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// Squared L2 distance between two slices.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        acc += d * d;
    }
    acc
}

/// Number of worker threads used by the threaded matmul. Defaults to the
/// available parallelism minus one (leader thread keeps a share), clamped
/// to [1, 16]; override with `SLAY_THREADS`.
pub fn num_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("SLAY_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(1, 16)
    })
}

/// `C = A · B` — cache-blocked (i-k-j loop order so the inner loop is an
/// axpy over contiguous rows of B), threaded over row stripes of A when the
/// problem is big enough.
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul: inner dim mismatch {}x{} · {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    let flops = a.rows * a.cols * b.cols;
    let nt = num_threads();
    if flops < 64 * 64 * 64 || nt == 1 || a.rows < 2 {
        matmul_stripe(a, b, &mut c.data, 0, a.rows);
        return c;
    }
    let stripe = a.rows.div_ceil(nt);
    let bc = b.cols;
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = &mut c.data;
        let mut r0 = 0;
        let mut handles = Vec::new();
        while r0 < a.rows {
            let take = stripe.min(a.rows - r0);
            let (chunk, tail) = rest.split_at_mut(take * bc);
            rest = tail;
            let start = r0;
            handles.push(s.spawn(move || matmul_stripe(a, b, chunk, start, take)));
            r0 += take;
        }
        for h in handles {
            h.join().expect("matmul worker panicked");
        }
    });
    c
}

/// Compute rows `[start, start+n)` of `A·B` into `out` (n × b.cols).
fn matmul_stripe(a: &Mat, b: &Mat, out: &mut [f32], start: usize, n: usize) {
    let k_dim = a.cols;
    let j_dim = b.cols;
    const KB: usize = 64; // k-blocking keeps the B panel in L1/L2
    for kb in (0..k_dim).step_by(KB) {
        let k_end = (kb + KB).min(k_dim);
        for i in 0..n {
            let a_row = a.row(start + i);
            let c_row = &mut out[i * j_dim..(i + 1) * j_dim];
            for k in kb..k_end {
                let aik = a_row[k];
                if aik != 0.0 {
                    axpy(aik, &b.data[k * j_dim..(k + 1) * j_dim], c_row);
                }
            }
        }
    }
}

/// `C = Aᵀ · B` without materializing the transpose (A: k×m, B: k×n → m×n).
pub fn matmul_at_b(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.rows, b.rows, "matmul_at_b: row mismatch");
    let m = a.cols;
    let n = b.cols;
    let mut c = Mat::zeros(m, n);
    for k in 0..a.rows {
        let a_row = a.row(k);
        let b_row = b.row(k);
        for i in 0..m {
            let aik = a_row[i];
            if aik != 0.0 {
                axpy(aik, b_row, &mut c.data[i * n..(i + 1) * n]);
            }
        }
    }
    c
}

/// `C = A · Bᵀ` (A: m×k, B: n×k → m×n) — rows of both operands are
/// contiguous, so the inner kernel is a dot product.
pub fn matmul_a_bt(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.cols, "matmul_a_bt: col mismatch");
    let mut c = Mat::zeros(a.rows, b.rows);
    let nt = num_threads();
    if a.rows * b.rows * a.cols < 64 * 64 * 64 || nt == 1 || a.rows < 2 {
        for i in 0..a.rows {
            let ar = a.row(i);
            for j in 0..b.rows {
                c.data[i * b.rows + j] = dot(ar, b.row(j));
            }
        }
        return c;
    }
    let stripe = a.rows.div_ceil(nt);
    let bn = b.rows;
    std::thread::scope(|s| {
        let mut rest: &mut [f32] = &mut c.data;
        let mut r0 = 0;
        while r0 < a.rows {
            let take = stripe.min(a.rows - r0);
            let (chunk, tail) = rest.split_at_mut(take * bn);
            rest = tail;
            let start = r0;
            s.spawn(move || {
                for i in 0..take {
                    let ar = a.row(start + i);
                    for j in 0..bn {
                        chunk[i * bn + j] = dot(ar, b.row(j));
                    }
                }
            });
            r0 += take;
        }
    });
    c
}

/// Row-wise softmax in place (numerically stabilized).
pub fn softmax_rows(m: &mut Mat) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for x in row.iter_mut() {
            *x = (*x - mx).exp();
            sum += *x;
        }
        let inv = 1.0 / sum;
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

/// Row-wise normalization by row sums with stabilizer δ (kernel
/// normalization of Eq. 11 — *not* a softmax).
pub fn normalize_rows_by_sum(m: &mut Mat, delta: f32) {
    for r in 0..m.rows {
        let row = m.row_mut(r);
        let sum: f32 = row.iter().sum();
        let inv = 1.0 / (sum + delta);
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(b.data.iter()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 9, 33), (64, 64, 64), (100, 31, 57)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            assert_close(&matmul(&a, &b), &naive_matmul(&a, &b), 1e-5);
        }
    }

    #[test]
    fn threaded_matmul_matches_naive_large() {
        let mut rng = Rng::new(12);
        let a = Mat::randn(130, 70, &mut rng);
        let b = Mat::randn(70, 90, &mut rng);
        assert_close(&matmul(&a, &b), &naive_matmul(&a, &b), 1e-4);
    }

    #[test]
    fn at_b_and_a_bt_match_explicit_transpose() {
        let mut rng = Rng::new(13);
        let a = Mat::randn(40, 17, &mut rng);
        let b = Mat::randn(40, 23, &mut rng);
        assert_close(&matmul_at_b(&a, &b), &naive_matmul(&a.transpose(), &b), 1e-4);
        let c = Mat::randn(31, 17, &mut rng);
        assert_close(&matmul_a_bt(&a, &c), &naive_matmul(&a, &c.transpose()), 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(14);
        let a = Mat::randn(12, 12, &mut rng);
        assert_close(&matmul(&a, &Mat::eye(12)), &a, 1e-6);
        assert_close(&matmul(&Mat::eye(12), &a), &a, 1e-6);
    }

    #[test]
    fn normalize_rows_gives_unit_norm() {
        let mut rng = Rng::new(15);
        let mut a = Mat::randn(20, 8, &mut rng);
        a.normalize_rows();
        for r in 0..a.rows {
            let n: f32 = a.row(r).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5, "row {r} norm {n}");
        }
    }

    #[test]
    fn normalize_rows_handles_zero_row() {
        let mut a = Mat::zeros(2, 4);
        a.set(1, 0, 3.0);
        a.normalize_rows();
        assert_eq!(a.row(0), &[0.0, 0.0, 0.0, 0.0]);
        assert!((a.get(1, 0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_rows_sums_to_one_and_is_stable() {
        let mut m = Mat::from_vec(2, 3, vec![1e4, 1e4 + 1.0, 1e4 - 1.0, -5.0, 0.0, 5.0]);
        softmax_rows(&mut m);
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(r).iter().all(|&x| x.is_finite() && x >= 0.0));
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(16);
        let a = Mat::randn(37, 53, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn hcat_shapes_and_contents() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 1, vec![5., 6.]);
        let c = a.hcat(&b);
        assert_eq!((c.rows, c.cols), (2, 3));
        assert_eq!(c.row(0), &[1., 2., 5.]);
        assert_eq!(c.row(1), &[3., 4., 6.]);
    }

    #[test]
    fn dot_matches_reference() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32).collect();
        let reference: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - reference).abs() < 1e-4);
    }

    #[test]
    fn kernel_normalization_uses_delta() {
        let mut m = Mat::from_vec(1, 2, vec![0.0, 0.0]);
        normalize_rows_by_sum(&mut m, 1e-6);
        assert!(m.data.iter().all(|x| x.is_finite()));
    }
}
