//! Dense linear algebra on row-major `f32` matrices.
//!
//! No BLAS/ndarray is available offline, so this module provides the small
//! but heavily optimized core the attention engines need: cache-blocked,
//! optionally multi-threaded matmul (plain / A-transposed), row ops,
//! normalization, softmax and reductions. Everything is `f32` storage with
//! `f32` accumulation in the blocked kernels (matching the JAX side) except
//! where noted.
//!
//! # Views (ADR-002)
//!
//! [`Mat`] owns its buffer; [`MatView`]/[`MatViewMut`] are borrowed,
//! strided `(ptr, rows, cols, row_stride)` windows over any row-major
//! buffer. They are the argument type of every matrix-consuming kernel in
//! this crate, so head column-blocks, chunk row-ranges and single decode
//! rows flow through the math layer without being gathered into fresh
//! `Mat`s first. The layout contract:
//!
//! * row `r` occupies `ptr[r·row_stride .. r·row_stride + cols]`;
//!   `row_stride ≥ cols` (checked at construction, the gap bytes are never
//!   read or written);
//! * a view never outlives the buffer it borrows (enforced by lifetimes);
//! * [`MatViewMut`]s are exclusive over their *elements* — disjoint
//!   column/row blocks of one buffer may be written concurrently (that is
//!   how the multi-head fan-out packs head outputs in place), but two
//!   mutable views of overlapping elements must never coexist. Safe code
//!   can only obtain disjoint views ([`MatViewMut::split_rows_at`] /
//!   [`MatViewMut::split_cols_at`]), which is what keeps the raw-pointer
//!   plumbing sound;
//! * kernels touch views only through `row()`/`row_mut()`, so a strided
//!   view and an owned contiguous copy of the same data take bit-identical
//!   code paths (property-tested in `tests/properties.rs`).

use std::marker::PhantomData;

use crate::math::simd;

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build from a flat row-major vector (length must match).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Mat { rows, cols, data }
    }

    /// Build by evaluating `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Identity.
    pub fn eye(n: usize) -> Self {
        Mat::from_fn(n, n, |r, c| if r == c { 1.0 } else { 0.0 })
    }

    /// Random N(0,1) entries.
    pub fn randn(rows: usize, cols: usize, rng: &mut crate::math::rng::Rng) -> Self {
        Mat { rows, cols, data: rng.normal_vec(rows * cols) }
    }

    /// Borrowed view of the whole matrix (contiguous, `row_stride == cols`).
    #[inline]
    pub fn view(&self) -> MatView<'_> {
        MatView::new(&self.data, self.rows, self.cols)
    }

    /// Mutable view of the whole matrix.
    #[inline]
    pub fn view_mut(&mut self) -> MatViewMut<'_> {
        MatViewMut::new(&mut self.data, self.rows, self.cols)
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Transpose (out-of-place).
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // simple tiling for cache behaviour
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// L2-normalize every row in place (unit-sphere projection, Eq. 2 of the
    /// paper). Rows with norm below `1e-12` are left untouched.
    pub fn normalize_rows(&mut self) {
        for r in 0..self.rows {
            normalize_row(self.row_mut(r));
        }
    }

    /// Returned normalized copy.
    pub fn normalized_rows(&self) -> Mat {
        let mut m = self.clone();
        m.normalize_rows();
        m
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Scale in place.
    pub fn scale(&mut self, s: f32) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }

    /// `self += other`.
    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    /// Horizontal concatenation `[self | other]`.
    pub fn hcat(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "hcat: row mismatch");
        let cols = self.cols + other.cols;
        let mut out = Mat::zeros(self.rows, cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
            out.row_mut(r)[self.cols..].copy_from_slice(other.row(r));
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }
}

// ---------------------------------------------------------------------------
// Borrowed strided views
// ---------------------------------------------------------------------------

/// Immutable strided view: `rows × cols` window with `row_stride` floats
/// between row starts. `Copy`, pointer-sized cheap, `Send + Sync` — the
/// universal read-only matrix argument (see the module docs for the layout
/// contract).
#[derive(Clone, Copy, Debug)]
pub struct MatView<'a> {
    ptr: *const f32,
    rows: usize,
    cols: usize,
    row_stride: usize,
    _marker: PhantomData<&'a [f32]>,
}

// SAFETY: a MatView is semantically a shared `&[f32]` borrow; f32 data can
// be read from any thread.
unsafe impl Send for MatView<'_> {}
unsafe impl Sync for MatView<'_> {}

impl<'a> MatView<'a> {
    /// Contiguous view over `data` (`row_stride == cols`); the slice length
    /// must equal `rows * cols`.
    pub fn new(data: &'a [f32], rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "MatView::new: shape/data mismatch");
        MatView { ptr: data.as_ptr(), rows, cols, row_stride: cols, _marker: PhantomData }
    }

    /// Strided view over `data`. Panics when `row_stride < cols` or when
    /// `data` is too short to cover the last row.
    pub fn strided(data: &'a [f32], rows: usize, cols: usize, row_stride: usize) -> Self {
        assert!(
            row_stride >= cols,
            "MatView::strided: row_stride {row_stride} < cols {cols}"
        );
        let need = if rows == 0 { 0 } else { (rows - 1) * row_stride + cols };
        assert!(
            data.len() >= need,
            "MatView::strided: buffer of {} floats cannot hold {rows}x{cols} (stride {row_stride}, needs {need})",
            data.len()
        );
        MatView { ptr: data.as_ptr(), rows, cols, row_stride, _marker: PhantomData }
    }

    /// 1-row view of a token slice — the zero-copy decode-path wrapper.
    #[inline]
    pub fn from_row(row: &'a [f32]) -> Self {
        MatView::new(row, 1, row.len())
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row_stride(&self) -> usize {
        self.row_stride
    }

    #[inline]
    pub fn is_contiguous(&self) -> bool {
        self.row_stride == self.cols
    }

    /// Base pointer for the SIMD kernels (row `r`, col `c` lives at
    /// `ptr + r*row_stride + c`). Provenance covers the whole backing
    /// buffer, so kernels may address any in-bounds element from it.
    #[inline]
    pub(crate) fn base_ptr(&self) -> *const f32 {
        self.ptr
    }

    /// Row `r` as a slice. The returned borrow lives as long as the
    /// underlying buffer, not just this view value.
    #[inline]
    pub fn row(&self, r: usize) -> &'a [f32] {
        assert!(r < self.rows, "row {r} out of {}", self.rows);
        // SAFETY: construction guaranteed `ptr[r*stride .. r*stride+cols]`
        // is in-bounds of the borrowed buffer for all r < rows.
        unsafe { std::slice::from_raw_parts(self.ptr.add(r * self.row_stride), self.cols) }
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        assert!(c < self.cols, "col {c} out of {}", self.cols);
        self.row(r)[c]
    }

    /// Columns `[lo, hi)` of every row — the per-head block of a packed
    /// `L × d_model` tensor. Zero-copy: same buffer, same `row_stride`.
    pub fn col_block(&self, lo: usize, hi: usize) -> MatView<'a> {
        assert!(
            lo <= hi && hi <= self.cols,
            "col_block: {lo}..{hi} out of 0..{}",
            self.cols
        );
        MatView {
            ptr: self.ptr.wrapping_add(lo),
            rows: self.rows,
            cols: hi - lo,
            row_stride: self.row_stride,
            _marker: PhantomData,
        }
    }

    /// Rows `[lo, hi)` — a chunk of a longer sequence.
    pub fn row_block(&self, lo: usize, hi: usize) -> MatView<'a> {
        assert!(
            lo <= hi && hi <= self.rows,
            "row_block: {lo}..{hi} out of 0..{}",
            self.rows
        );
        MatView {
            ptr: self.ptr.wrapping_add(lo * self.row_stride),
            rows: hi - lo,
            cols: self.cols,
            row_stride: self.row_stride,
            _marker: PhantomData,
        }
    }

    /// Split into `[0, at)` and `[at, rows)` row ranges.
    pub fn split_rows(&self, at: usize) -> (MatView<'a>, MatView<'a>) {
        (self.row_block(0, at), self.row_block(at, self.rows))
    }

    /// Materialize an owned contiguous copy.
    pub fn to_mat(&self) -> Mat {
        let mut data = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            data.extend_from_slice(self.row(r));
        }
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Elementwise map into an owned matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        let mut data = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            data.extend(self.row(r).iter().map(|&x| f(x)));
        }
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Owned row-normalized copy (unit-sphere projection).
    pub fn normalized_rows(&self) -> Mat {
        let mut m = self.to_mat();
        m.normalize_rows();
        m
    }
}

/// Normalize one row to unit L2 norm in place; rows with norm below
/// `1e-12` are left untouched. The single definition behind
/// [`Mat::normalize_rows`] and [`normalize_rows_into`].
#[inline]
fn normalize_row(row: &mut [f32]) {
    let n: f32 = row.iter().map(|x| x * x).sum::<f32>().sqrt();
    if n > 1e-12 {
        let inv = 1.0 / n;
        for x in row.iter_mut() {
            *x *= inv;
        }
    }
}

/// Row-wise unit-sphere projection of `x` into a flat row-major buffer —
/// the scratch-backed counterpart of [`MatView::normalized_rows`] (same
/// zero-row guard), used by the zero-allocation feature pipeline
/// (ADR-003).
pub fn normalize_rows_into(x: MatView, buf: &mut [f32]) {
    let (l, d) = (x.rows(), x.cols());
    debug_assert_eq!(buf.len(), l * d);
    for r in 0..l {
        let dst = &mut buf[r * d..(r + 1) * d];
        dst.copy_from_slice(x.row(r));
        normalize_row(dst);
    }
}

impl<'a> From<&'a Mat> for MatView<'a> {
    #[inline]
    fn from(m: &'a Mat) -> Self {
        m.view()
    }
}

/// Mutable strided view — the write-side counterpart of [`MatView`].
/// Not `Copy`; obtained from [`Mat::view_mut`] and narrowed by the
/// consuming `split_*` methods, so safe code always holds element-disjoint
/// mutable views (the property the thread fan-outs rely on).
#[derive(Debug)]
pub struct MatViewMut<'a> {
    ptr: *mut f32,
    rows: usize,
    cols: usize,
    row_stride: usize,
    _marker: PhantomData<&'a mut [f32]>,
}

// SAFETY: a MatViewMut is an exclusive borrow of its element set; moving it
// to another thread moves that exclusivity with it (f32: Send).
unsafe impl Send for MatViewMut<'_> {}

impl<'a> MatViewMut<'a> {
    /// Contiguous mutable view over `data` (`row_stride == cols`).
    pub fn new(data: &'a mut [f32], rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "MatViewMut::new: shape/data mismatch");
        MatViewMut { ptr: data.as_mut_ptr(), rows, cols, row_stride: cols, _marker: PhantomData }
    }

    /// Strided mutable view over `data`; same bounds rules as
    /// [`MatView::strided`].
    pub fn strided(data: &'a mut [f32], rows: usize, cols: usize, row_stride: usize) -> Self {
        assert!(
            row_stride >= cols,
            "MatViewMut::strided: row_stride {row_stride} < cols {cols}"
        );
        let need = if rows == 0 { 0 } else { (rows - 1) * row_stride + cols };
        assert!(
            data.len() >= need,
            "MatViewMut::strided: buffer of {} floats cannot hold {rows}x{cols} (stride {row_stride}, needs {need})",
            data.len()
        );
        MatViewMut { ptr: data.as_mut_ptr(), rows, cols, row_stride, _marker: PhantomData }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row_stride(&self) -> usize {
        self.row_stride
    }

    /// Mutable base pointer for the SIMD kernels (same addressing rule as
    /// [`MatView::base_ptr`]; rows are element-disjoint since
    /// `row_stride ≥ cols`).
    #[inline]
    pub(crate) fn base_ptr_mut(&mut self) -> *mut f32 {
        self.ptr
    }

    /// Mutable row `r`. Borrows `self` exclusively, so only one row slice
    /// is live at a time through this method.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        assert!(r < self.rows, "row {r} out of {}", self.rows);
        // SAFETY: in-bounds by construction; exclusivity via &mut self.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(r * self.row_stride), self.cols) }
    }

    /// Read-only alias of this view (no narrowing, same region).
    #[inline]
    pub fn as_view(&self) -> MatView<'_> {
        MatView {
            ptr: self.ptr,
            rows: self.rows,
            cols: self.cols,
            row_stride: self.row_stride,
            _marker: PhantomData,
        }
    }

    /// Fresh mutable view of the same region with a shorter lifetime —
    /// lets a caller pass `self` to an `_into` kernel and keep using it.
    #[inline]
    pub fn reborrow(&mut self) -> MatViewMut<'_> {
        MatViewMut {
            ptr: self.ptr,
            rows: self.rows,
            cols: self.cols,
            row_stride: self.row_stride,
            _marker: PhantomData,
        }
    }

    /// Split into the first `at` rows and the rest (element-disjoint, both
    /// usable concurrently).
    pub fn split_rows_at(self, at: usize) -> (MatViewMut<'a>, MatViewMut<'a>) {
        assert!(at <= self.rows, "split_rows_at: {at} out of 0..={}", self.rows);
        let top = MatViewMut {
            ptr: self.ptr,
            rows: at,
            cols: self.cols,
            row_stride: self.row_stride,
            _marker: PhantomData,
        };
        let bottom = MatViewMut {
            ptr: self.ptr.wrapping_add(at * self.row_stride),
            rows: self.rows - at,
            cols: self.cols,
            row_stride: self.row_stride,
            _marker: PhantomData,
        };
        (top, bottom)
    }

    /// Split into the first `at` columns and the rest (element-disjoint —
    /// the multi-head output packer hands one block per head thread).
    pub fn split_cols_at(self, at: usize) -> (MatViewMut<'a>, MatViewMut<'a>) {
        assert!(at <= self.cols, "split_cols_at: {at} out of 0..={}", self.cols);
        let left = MatViewMut {
            ptr: self.ptr,
            rows: self.rows,
            cols: at,
            row_stride: self.row_stride,
            _marker: PhantomData,
        };
        let right = MatViewMut {
            ptr: self.ptr.wrapping_add(at),
            rows: self.rows,
            cols: self.cols - at,
            row_stride: self.row_stride,
            _marker: PhantomData,
        };
        (left, right)
    }

    /// Zero every element.
    pub fn fill_zero(&mut self) {
        for r in 0..self.rows {
            self.row_mut(r).fill(0.0);
        }
    }
}

impl<'a> From<&'a mut Mat> for MatViewMut<'a> {
    #[inline]
    fn from(m: &'a mut Mat) -> Self {
        m.view_mut()
    }
}

// ---------------------------------------------------------------------------
// Scratch arena
// ---------------------------------------------------------------------------

/// Reusable buffer arena for steady-state zero-allocation pipelines
/// (ADR-003).
///
/// [`Scratch::take`] hands out an owned, zero-filled `Vec<f32>` of exactly
/// `len` elements, recycling the smallest pooled buffer whose capacity
/// already fits (best-fit — so interleaving call patterns with different
/// buffer sizes, e.g. prefill chunks between decode steps, cannot keep
/// regrowing small buffers into big slots). Once every size a call path
/// needs has been seen, the arena stops allocating — the property
/// `tests/alloc_discipline.rs` locks in for the serving hot path.
///
/// Ownership rules:
/// * pair every `take` with a `put` once the buffer is dead — dropping the
///   buffer instead is safe but forfeits its capacity;
/// * buffers come back zeroed, so callers may treat them exactly like a
///   fresh `vec![0.0; len]`;
/// * a `Scratch` belongs to one thread at a time (`&mut` access only) —
///   give each worker/thread its own arena rather than sharing one.
#[derive(Default)]
pub struct Scratch {
    pool: Vec<Vec<f32>>,
    /// Index buffers (per-row positions of a fused decode block, ADR-005)
    /// — pooled separately so they never contend with the float pool.
    idx_pool: Vec<Vec<usize>>,
}

impl Scratch {
    pub fn new() -> Self {
        Scratch::default()
    }

    /// A zeroed buffer of `len` floats (allocation-free once a buffer of
    /// sufficient capacity has been `put` back). The pool is a handful of
    /// buffers at most, so the best-fit scan is noise next to the work
    /// the buffer is taken for. Zero-filling is the safety contract the
    /// accumulating consumers (`u += Ψ(K_b)ᵀV_b`, `z += colsum`) rely on;
    /// it costs one write pass per take, which overwrite-only consumers
    /// could skip — but that needs `set_len` on uninitialized memory, not
    /// worth the unsafety at current buffer sizes.
    pub fn take(&mut self, len: usize) -> Vec<f32> {
        best_fit(&mut self.pool, len)
    }

    /// Return a buffer to the pool for reuse.
    pub fn put(&mut self, buf: Vec<f32>) {
        self.pool.push(buf);
    }

    /// A zeroed index buffer of `len` elements — [`Scratch::take`]'s
    /// `usize` sibling (same ownership rules), used for the per-row
    /// position vectors of fused decode blocks (ADR-005).
    pub fn take_idx(&mut self, len: usize) -> Vec<usize> {
        best_fit(&mut self.idx_pool, len)
    }

    /// Return an index buffer to the pool for reuse.
    pub fn put_idx(&mut self, buf: Vec<usize>) {
        self.idx_pool.push(buf);
    }
}

/// The arena's selection rule, shared by the `f32` and index pools: the
/// smallest pooled buffer whose capacity already fits `len` (best-fit),
/// else grow whatever is at hand. Returns the buffer zero-filled to
/// exactly `len` elements.
fn best_fit<T: Clone + Default>(pool: &mut Vec<Vec<T>>, len: usize) -> Vec<T> {
    let mut pick: Option<usize> = None;
    for (i, b) in pool.iter().enumerate() {
        if b.capacity() < len {
            continue;
        }
        let better = match pick {
            None => true,
            Some(j) => b.capacity() < pool[j].capacity(),
        };
        if better {
            pick = Some(i);
        }
    }
    let mut buf = match pick {
        Some(i) => pool.swap_remove(i),
        None => pool.pop().unwrap_or_default(),
    };
    buf.clear();
    buf.resize(len, T::default());
    buf
}

// ---------------------------------------------------------------------------
// Kernels
// ---------------------------------------------------------------------------

/// Dot product of two slices — dispatched to the resolved SIMD backend
/// (ADR-010).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    (simd::kernels().dot)(a, b)
}

/// `y += alpha * x` — dispatched to the resolved SIMD backend.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    (simd::kernels().axpy)(alpha, x, y)
}

/// Squared L2 distance between two slices — dispatched to the resolved
/// SIMD backend.
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    (simd::kernels().sq_dist)(a, b)
}

/// Problem-size floor (in multiply-accumulate flops) below which the
/// threaded kernels stay single-threaded, and the per-thread work target
/// when they do fan out (thread count scales as `flops / PAR_FLOPS` up to
/// [`num_threads`]): a scoped-thread spawn costs ~tens of µs, so each
/// spawn must buy at least this much arithmetic.
pub const PAR_FLOPS: usize = 64 * 64 * 64;

/// Number of worker threads used by the threaded matmul. Defaults to the
/// available parallelism minus one (leader thread keeps a share), clamped
/// to [1, 16]; override with `SLAY_THREADS`.
pub fn num_threads() -> usize {
    static N: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *N.get_or_init(|| {
        if let Ok(v) = std::env::var("SLAY_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .clamp(1, 16)
    })
}

/// `C = A · B` — cache-blocked (i-k-j loop order so the inner loop is an
/// axpy over contiguous rows of B), threaded over row stripes of A when the
/// problem is big enough. Accepts owned matrices (`&Mat`) or strided views.
pub fn matmul<'a, 'b>(a: impl Into<MatView<'a>>, b: impl Into<MatView<'b>>) -> Mat {
    let (a, b) = (a.into(), b.into());
    let mut c = Mat::zeros(a.rows(), b.cols());
    matmul_into(a, b, c.view_mut());
    c
}

fn check_matmul_shapes(a: &MatView, b: &MatView, out: &MatViewMut) {
    assert_eq!(
        a.cols(),
        b.rows(),
        "matmul: inner dim mismatch {}x{} · {}x{}",
        a.rows(),
        a.cols(),
        b.rows(),
        b.cols()
    );
    assert_eq!(
        (out.rows(), out.cols()),
        (a.rows(), b.cols()),
        "matmul_into: out is {}x{}, need {}x{}",
        out.rows(),
        out.cols(),
        a.rows(),
        b.cols()
    );
}

/// `out = A · B` writing through a (possibly strided) mutable view — the
/// zero-copy output path (e.g. one head's column block of a packed tensor).
pub fn matmul_into(a: MatView, b: MatView, out: MatViewMut) {
    check_matmul_shapes(&a, &b, &out);
    let flops = a.rows() * a.cols() * b.cols();
    let nt = num_threads().min((flops / PAR_FLOPS).max(1));
    if nt == 1 || a.rows() < 2 {
        matmul_stripe(a, b, out);
        return;
    }
    let stripe = a.rows().div_ceil(nt);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut r0 = 0;
        while r0 < a.rows() {
            let take = stripe.min(a.rows() - r0);
            let (chunk, tail) = rest.split_rows_at(take);
            rest = tail;
            let a_block = a.row_block(r0, r0 + take);
            s.spawn(move || matmul_stripe(a_block, b, chunk));
            r0 += take;
        }
    });
}

/// Single-threaded [`matmul_into`] — a building block for callers (the
/// chunkwise causal engine) that own the thread fan-out themselves.
pub fn matmul_serial_into(a: MatView, b: MatView, out: MatViewMut) {
    check_matmul_shapes(&a, &b, &out);
    matmul_stripe(a, b, out);
}

/// One row stripe of `A·B` into `out` (same row count as `a`) —
/// dispatched to the resolved backend's register-blocked packed GEMM.
fn matmul_stripe(a: MatView, b: MatView, out: MatViewMut) {
    (simd::kernels().gemm_nn)(a, b, out)
}

/// `C = Aᵀ · B` without materializing the transpose (A: k×m, B: k×n → m×n),
/// threaded over row stripes of the output — this is the `Ψ(K)ᵀV`
/// workhorse of the linear-attention engines.
pub fn matmul_at_b<'a, 'b>(a: impl Into<MatView<'a>>, b: impl Into<MatView<'b>>) -> Mat {
    let (a, b) = (a.into(), b.into());
    let mut c = Mat::zeros(a.cols(), b.cols());
    matmul_at_b_acc_into(a, b, c.view_mut());
    c
}

fn check_at_b_shapes(a: &MatView, b: &MatView, out: &MatViewMut) {
    assert_eq!(a.rows(), b.rows(), "matmul_at_b: row mismatch");
    assert_eq!(
        (out.rows(), out.cols()),
        (a.cols(), b.cols()),
        "matmul_at_b_acc_into: out is {}x{}, need {}x{}",
        out.rows(),
        out.cols(),
        a.cols(),
        b.cols()
    );
}

/// `out += Aᵀ · B` — accumulating and allocation-free, so the streaming
/// state update `S += Ψ(K_b)ᵀV_b` writes straight into the state buffer.
/// Threaded over row stripes of `out` (column ranges of A); per-element
/// accumulation order is independent of the striping, so threaded and
/// serial runs are bit-identical.
pub fn matmul_at_b_acc_into(a: MatView, b: MatView, out: MatViewMut) {
    check_at_b_shapes(&a, &b, &out);
    let flops = a.rows() * a.cols() * b.cols();
    let nt = num_threads().min((flops / PAR_FLOPS).max(1));
    if nt == 1 || a.cols() < 2 {
        at_b_acc_stripe(a, b, 0, out);
        return;
    }
    let stripe = a.cols().div_ceil(nt);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut c0 = 0;
        while c0 < a.cols() {
            let take = stripe.min(a.cols() - c0);
            let (chunk, tail) = rest.split_rows_at(take);
            rest = tail;
            let start = c0;
            s.spawn(move || at_b_acc_stripe(a, b, start, chunk));
            c0 += take;
        }
    });
}

/// Single-threaded [`matmul_at_b_acc_into`] (callers own the parallelism).
pub fn matmul_at_b_acc_serial(a: MatView, b: MatView, out: MatViewMut) {
    check_at_b_shapes(&a, &b, &out);
    at_b_acc_stripe(a, b, 0, out);
}

/// Accumulate output rows `[c0, c0 + out.rows())` of `AᵀB` into `out` —
/// dispatched; per-element accumulation chains root at the existing
/// output values and walk k sequentially, so striping stays invisible.
fn at_b_acc_stripe(a: MatView, b: MatView, c0: usize, out: MatViewMut) {
    (simd::kernels().gemm_tn_acc)(a, b, c0, out)
}

/// `C = A · Bᵀ` (A: m×k, B: n×k → m×n) — rows of both operands are
/// contiguous-per-row even under striding, so the inner kernel is a dot
/// product.
pub fn matmul_a_bt<'a, 'b>(a: impl Into<MatView<'a>>, b: impl Into<MatView<'b>>) -> Mat {
    let (a, b) = (a.into(), b.into());
    let mut c = Mat::zeros(a.rows(), b.rows());
    matmul_a_bt_into(a, b, c.view_mut());
    c
}

fn check_a_bt_shapes(a: &MatView, b: &MatView, out: &MatViewMut) {
    assert_eq!(a.cols(), b.cols(), "matmul_a_bt: col mismatch");
    assert_eq!(
        (out.rows(), out.cols()),
        (a.rows(), b.rows()),
        "matmul_a_bt_into: out is {}x{}, need {}x{}",
        out.rows(),
        out.cols(),
        a.rows(),
        b.rows()
    );
}

/// `out = A · Bᵀ` through a (possibly strided) view, threaded over row
/// stripes of the output when the problem is big enough.
pub fn matmul_a_bt_into(a: MatView, b: MatView, out: MatViewMut) {
    check_a_bt_shapes(&a, &b, &out);
    let flops = a.rows() * b.rows() * a.cols();
    let nt = num_threads().min((flops / PAR_FLOPS).max(1));
    if nt == 1 || a.rows() < 2 {
        a_bt_stripe(a, b, out);
        return;
    }
    let stripe = a.rows().div_ceil(nt);
    std::thread::scope(|s| {
        let mut rest = out;
        let mut r0 = 0;
        while r0 < a.rows() {
            let take = stripe.min(a.rows() - r0);
            let (chunk, tail) = rest.split_rows_at(take);
            rest = tail;
            let a_block = a.row_block(r0, r0 + take);
            s.spawn(move || a_bt_stripe(a_block, b, chunk));
            r0 += take;
        }
    });
}

/// Single-threaded [`matmul_a_bt_into`] (callers own the parallelism).
pub fn matmul_a_bt_serial_into(a: MatView, b: MatView, out: MatViewMut) {
    check_a_bt_shapes(&a, &b, &out);
    a_bt_stripe(a, b, out);
}

fn a_bt_stripe(a: MatView, b: MatView, out: MatViewMut) {
    (simd::kernels().gemm_nt)(a, b, out)
}

/// Row-wise softmax in place (numerically stabilized). Accepts `&mut Mat`
/// or any strided mutable view. Per-row dispatched kernel (vectorized
/// max/exp/sum on SIMD backends), so row order never matters.
pub fn softmax_rows<'a>(m: impl Into<MatViewMut<'a>>) {
    let mut m = m.into();
    let k = simd::kernels();
    for r in 0..m.rows() {
        (k.softmax_row)(m.row_mut(r));
    }
}

/// Row-wise normalization by row sums with stabilizer δ (kernel
/// normalization of Eq. 11 — *not* a softmax). Per-row dispatched kernel.
pub fn normalize_rows_by_sum<'a>(m: impl Into<MatViewMut<'a>>, delta: f32) {
    let mut m = m.into();
    let k = simd::kernels();
    for r in 0..m.rows() {
        (k.normalize_row_sum)(m.row_mut(r), delta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.get(i, k) * b.get(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    fn assert_close(a: &Mat, b: &Mat, tol: f32) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(b.data.iter()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        let mut rng = Rng::new(11);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 9, 33), (64, 64, 64), (100, 31, 57)] {
            let a = Mat::randn(m, k, &mut rng);
            let b = Mat::randn(k, n, &mut rng);
            assert_close(&matmul(&a, &b), &naive_matmul(&a, &b), 1e-5);
        }
    }

    #[test]
    fn threaded_matmul_matches_naive_large() {
        let mut rng = Rng::new(12);
        let a = Mat::randn(130, 70, &mut rng);
        let b = Mat::randn(70, 90, &mut rng);
        assert_close(&matmul(&a, &b), &naive_matmul(&a, &b), 1e-4);
    }

    #[test]
    fn at_b_and_a_bt_match_explicit_transpose() {
        let mut rng = Rng::new(13);
        let a = Mat::randn(40, 17, &mut rng);
        let b = Mat::randn(40, 23, &mut rng);
        assert_close(&matmul_at_b(&a, &b), &naive_matmul(&a.transpose(), &b), 1e-4);
        let c = Mat::randn(31, 17, &mut rng);
        assert_close(&matmul_a_bt(&a, &c), &naive_matmul(&a, &c.transpose()), 1e-4);
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(14);
        let a = Mat::randn(12, 12, &mut rng);
        assert_close(&matmul(&a, &Mat::eye(12)), &a, 1e-6);
        assert_close(&matmul(&Mat::eye(12), &a), &a, 1e-6);
    }

    #[test]
    fn normalize_rows_gives_unit_norm() {
        let mut rng = Rng::new(15);
        let mut a = Mat::randn(20, 8, &mut rng);
        a.normalize_rows();
        for r in 0..a.rows {
            let n: f32 = a.row(r).iter().map(|x| x * x).sum::<f32>().sqrt();
            assert!((n - 1.0).abs() < 1e-5, "row {r} norm {n}");
        }
    }

    #[test]
    fn normalize_rows_handles_zero_row() {
        let mut a = Mat::zeros(2, 4);
        a.set(1, 0, 3.0);
        a.normalize_rows();
        assert_eq!(a.row(0), &[0.0, 0.0, 0.0, 0.0]);
        assert!((a.get(1, 0) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_rows_sums_to_one_and_is_stable() {
        let mut m = Mat::from_vec(2, 3, vec![1e4, 1e4 + 1.0, 1e4 - 1.0, -5.0, 0.0, 5.0]);
        softmax_rows(&mut m);
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(m.row(r).iter().all(|&x| x.is_finite() && x >= 0.0));
        }
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(16);
        let a = Mat::randn(37, 53, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn hcat_shapes_and_contents() {
        let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
        let b = Mat::from_vec(2, 1, vec![5., 6.]);
        let c = a.hcat(&b);
        assert_eq!((c.rows, c.cols), (2, 3));
        assert_eq!(c.row(0), &[1., 2., 5.]);
        assert_eq!(c.row(1), &[3., 4., 6.]);
    }

    #[test]
    fn dot_matches_reference() {
        let a: Vec<f32> = (0..13).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..13).map(|i| (13 - i) as f32).collect();
        let reference: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - reference).abs() < 1e-4);
    }

    #[test]
    fn kernel_normalization_uses_delta() {
        let mut m = Mat::from_vec(1, 2, vec![0.0, 0.0]);
        normalize_rows_by_sum(&mut m, 1e-6);
        assert!(m.data.iter().all(|x| x.is_finite()));
    }

    // ---- view semantics ---------------------------------------------------

    #[test]
    fn view_blocks_read_the_right_elements() {
        let m = Mat::from_fn(4, 6, |r, c| (r * 10 + c) as f32);
        let v = m.view();
        assert!(v.is_contiguous());
        let block = v.col_block(2, 5);
        assert_eq!((block.rows(), block.cols(), block.row_stride()), (4, 3, 6));
        assert!(!block.is_contiguous());
        for r in 0..4 {
            assert_eq!(block.row(r), &m.row(r)[2..5]);
        }
        let rows = v.row_block(1, 3);
        assert_eq!(rows.row(0), m.row(1));
        assert_eq!(rows.row(1), m.row(2));
        let (top, bottom) = v.split_rows(2);
        assert_eq!((top.rows(), bottom.rows()), (2, 2));
        assert_eq!(bottom.row(0), m.row(2));
        // composition: col block of a row block
        let inner = rows.col_block(1, 4);
        assert_eq!(inner.row(1), &m.row(2)[1..4]);
        assert_eq!(inner.to_mat().row(1), &m.row(2)[1..4]);
    }

    #[test]
    fn strided_matmul_bit_identical_to_owned() {
        let mut rng = Rng::new(17);
        // big packed buffers; operands are interior column blocks
        let packed_a = Mat::randn(70, 48, &mut rng);
        let packed_b = Mat::randn(70, 48, &mut rng);
        let packed_c = Mat::randn(31, 48, &mut rng);
        let a = packed_a.view().col_block(8, 25); // 70 x 17, strided
        let b = packed_b.view().col_block(5, 28); // 70 x 23, strided
        let c = packed_c.view().col_block(8, 25); // 31 x 17, strided
        let (ao, bo, co) = (a.to_mat(), b.to_mat(), c.to_mat());
        // A·Bᵀ (shared col dim): 70x17 · (31x17)ᵀ
        assert_eq!(matmul_a_bt(a, c).data, matmul_a_bt(&ao, &co).data);
        // Aᵀ·B (shared row dim): (70x17)ᵀ · 70x23
        assert_eq!(matmul_at_b(a, b).data, matmul_at_b(&ao, &bo).data);
        // plain A·B: 70x17 · 17x31
        let ct = co.transpose();
        assert_eq!(matmul(a, &ct).data, matmul(&ao, &ct).data);
    }

    #[test]
    fn matmul_into_strided_out_matches_owned() {
        let mut rng = Rng::new(18);
        let a = Mat::randn(9, 5, &mut rng);
        let b = Mat::randn(5, 4, &mut rng);
        let want = matmul(&a, &b);
        // write into a column block of a wider packed output
        let mut packed = Mat::from_fn(9, 10, |_, _| 7.0);
        let (left, rest) = packed.view_mut().split_cols_at(3);
        let (mid, right) = rest.split_cols_at(4);
        drop((left, right));
        matmul_into(a.view(), b.view(), mid);
        for r in 0..9 {
            assert_eq!(&packed.row(r)[3..7], want.row(r));
            // untouched columns keep their sentinel
            assert!(packed.row(r)[..3].iter().all(|&x| x == 7.0));
            assert!(packed.row(r)[7..].iter().all(|&x| x == 7.0));
        }
    }

    #[test]
    fn split_cols_write_disjointly_across_threads() {
        let mut out = Mat::zeros(8, 6);
        let (mut left, mut right) = out.view_mut().split_cols_at(2);
        std::thread::scope(|s| {
            s.spawn(move || {
                for r in 0..left.rows() {
                    left.row_mut(r).fill(1.0);
                }
            });
            s.spawn(move || {
                for r in 0..right.rows() {
                    right.row_mut(r).fill(2.0);
                }
            });
        });
        for r in 0..8 {
            assert_eq!(out.row(r)[..2], [1.0, 1.0]);
            assert!(out.row(r)[2..].iter().all(|&x| x == 2.0));
        }
    }

    #[test]
    fn softmax_and_normalize_on_strided_views() {
        let mut rng = Rng::new(19);
        let base = Mat::randn(6, 9, &mut rng);
        let mut packed = base.clone();
        let mut owned = packed.view().col_block(2, 7).to_mat();
        softmax_rows(MatViewMut::strided(&mut packed.data[2..], 6, 5, 9));
        softmax_rows(&mut owned);
        for r in 0..6 {
            assert_eq!(&packed.row(r)[2..7], owned.row(r), "softmax row {r}");
            // columns outside the view untouched
            assert_eq!(packed.row(r)[..2], base.row(r)[..2]);
            assert_eq!(packed.row(r)[7..], base.row(r)[7..]);
        }
        let mut packed2 = base.clone();
        let mut owned2 = packed2.view().col_block(2, 7).to_mat();
        normalize_rows_by_sum(MatViewMut::strided(&mut packed2.data[2..], 6, 5, 9), 1e-6);
        normalize_rows_by_sum(&mut owned2, 1e-6);
        for r in 0..6 {
            assert_eq!(&packed2.row(r)[2..7], owned2.row(r), "normalize row {r}");
        }
    }

    #[test]
    fn from_row_is_a_one_row_view() {
        let data = [1.0f32, 2.0, 3.0];
        let v = MatView::from_row(&data);
        assert_eq!((v.rows(), v.cols()), (1, 3));
        assert_eq!(v.row(0), &data);
    }

    #[test]
    #[should_panic(expected = "col_block")]
    fn col_block_out_of_bounds_panics() {
        let m = Mat::zeros(2, 4);
        let _ = m.view().col_block(2, 5);
    }

    #[test]
    #[should_panic(expected = "row_block")]
    fn row_block_out_of_bounds_panics() {
        let m = Mat::zeros(2, 4);
        let _ = m.view().row_block(1, 3);
    }

    #[test]
    #[should_panic(expected = "row_stride")]
    fn strided_with_stride_below_cols_panics() {
        let data = vec![0.0f32; 12];
        let _ = MatView::strided(&data, 3, 4, 3);
    }

    #[test]
    #[should_panic(expected = "cannot hold")]
    fn strided_with_short_buffer_panics() {
        let data = vec![0.0f32; 10];
        let _ = MatView::strided(&data, 3, 4, 4);
    }

    #[test]
    #[should_panic(expected = "out of")]
    fn view_row_out_of_bounds_panics() {
        let m = Mat::zeros(2, 4);
        let _ = m.view().row(2);
    }

    // ---- accumulating / serial kernels (ADR-003) --------------------------

    #[test]
    fn at_b_acc_accumulates_onto_existing_values() {
        let mut rng = Rng::new(21);
        // big enough that the threaded path actually fans out
        let a = Mat::randn(128, 80, &mut rng);
        let b = Mat::randn(128, 70, &mut rng);
        let base = Mat::randn(80, 70, &mut rng);
        let mut acc = base.clone();
        matmul_at_b_acc_into(a.view(), b.view(), acc.view_mut());
        let want = matmul_at_b(&a, &b);
        for r in 0..80 {
            for c in 0..70 {
                let expect = base.get(r, c) + want.get(r, c);
                assert!(
                    (acc.get(r, c) - expect).abs() < 1e-4 * (1.0 + expect.abs()),
                    "({r},{c}): {} vs {expect}",
                    acc.get(r, c)
                );
            }
        }
        // the serial building block is bit-identical to the threaded entry
        let mut acc2 = base.clone();
        matmul_at_b_acc_serial(a.view(), b.view(), acc2.view_mut());
        assert_eq!(acc.data, acc2.data);
    }

    #[test]
    fn threaded_at_b_matches_naive_transpose() {
        let mut rng = Rng::new(23);
        let a = Mat::randn(130, 90, &mut rng);
        let b = Mat::randn(130, 60, &mut rng);
        assert_close(&matmul_at_b(&a, &b), &naive_matmul(&a.transpose(), &b), 1e-4);
    }

    #[test]
    fn serial_kernels_bit_identical_to_threaded() {
        let mut rng = Rng::new(22);
        let a = Mat::randn(128, 70, &mut rng);
        let b = Mat::randn(70, 90, &mut rng);
        let mut out = Mat::zeros(128, 90);
        matmul_serial_into(a.view(), b.view(), out.view_mut());
        assert_eq!(out.data, matmul(&a, &b).data);
        let bt = Mat::randn(96, 70, &mut rng);
        let mut out2 = Mat::zeros(128, 96);
        matmul_a_bt_serial_into(a.view(), bt.view(), out2.view_mut());
        assert_eq!(out2.data, matmul_a_bt(&a, &bt).data);
    }

    #[test]
    fn scratch_recycles_capacity_and_zeroes() {
        let mut s = Scratch::new();
        let mut a = s.take(16);
        assert_eq!(a.len(), 16);
        a.iter_mut().for_each(|x| *x = 7.0);
        let p = a.as_ptr();
        s.put(a);
        let b = s.take(8);
        assert_eq!(b.as_ptr(), p, "LIFO reuse of the same allocation");
        assert_eq!(b.len(), 8);
        assert!(b.iter().all(|&x| x == 0.0), "buffers come back zeroed");
        s.put(b);
        let c = s.take(16);
        assert_eq!(c.as_ptr(), p, "capacity survives a smaller take");
        assert!(c.iter().all(|&x| x == 0.0));
    }
}
