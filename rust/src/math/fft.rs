//! Iterative radix-2 complex FFT — the only consumer is TensorSketch's
//! circular convolution (`CS(a ⊗ b) = IFFT(FFT(CS₁a) · FFT(CS₂b))`), so the
//! implementation is deliberately minimal: in-place Cooley–Tukey over
//! power-of-two lengths, f64 precision.

/// Complex number (we avoid pulling in num-complex's API surface).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cpx {
    pub re: f64,
    pub im: f64,
}

impl Cpx {
    #[inline]
    pub fn new(re: f64, im: f64) -> Cpx {
        Cpx { re, im }
    }

    #[inline]
    pub fn mul(self, o: Cpx) -> Cpx {
        Cpx::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    #[inline]
    fn add(self, o: Cpx) -> Cpx {
        Cpx::new(self.re + o.re, self.im + o.im)
    }

    #[inline]
    fn sub(self, o: Cpx) -> Cpx {
        Cpx::new(self.re - o.re, self.im - o.im)
    }
}

/// Round `n` up to the next power of two.
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// In-place FFT (`inverse = false`) or unnormalized IFFT (`inverse = true`).
/// Length must be a power of two. The caller divides by `n` after an
/// inverse transform.
pub fn fft_in_place(a: &mut [Cpx], inverse: bool) {
    let n = a.len();
    assert!(n.is_power_of_two(), "fft length {n} not a power of two");
    // bit reversal permutation
    let mut j = 0usize;
    for i in 1..n {
        let mut bit = n >> 1;
        while j & bit != 0 {
            j ^= bit;
            bit >>= 1;
        }
        j |= bit;
        if i < j {
            a.swap(i, j);
        }
    }
    let sign = if inverse { 1.0 } else { -1.0 };
    let mut len = 2;
    while len <= n {
        let ang = sign * 2.0 * std::f64::consts::PI / len as f64;
        let wlen = Cpx::new(ang.cos(), ang.sin());
        let mut i = 0;
        while i < n {
            let mut w = Cpx::new(1.0, 0.0);
            for k in 0..len / 2 {
                let u = a[i + k];
                let v = a[i + k + len / 2].mul(w);
                a[i + k] = u.add(v);
                a[i + k + len / 2] = u.sub(v);
                w = w.mul(wlen);
            }
            i += len;
        }
        len <<= 1;
    }
}

/// Circular convolution of two real vectors of equal power-of-two length.
pub fn circular_convolve(x: &[f64], y: &[f64]) -> Vec<f64> {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    assert!(n.is_power_of_two());
    let mut fx: Vec<Cpx> = x.iter().map(|&v| Cpx::new(v, 0.0)).collect();
    let mut fy: Vec<Cpx> = y.iter().map(|&v| Cpx::new(v, 0.0)).collect();
    fft_in_place(&mut fx, false);
    fft_in_place(&mut fy, false);
    for (a, b) in fx.iter_mut().zip(fy.iter()) {
        *a = a.mul(*b);
    }
    fft_in_place(&mut fx, true);
    fx.iter().map(|c| c.re / n as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_roundtrip() {
        let mut a: Vec<Cpx> = (0..16).map(|i| Cpx::new(i as f64, -(i as f64) / 3.0)).collect();
        let orig = a.clone();
        fft_in_place(&mut a, false);
        fft_in_place(&mut a, true);
        for (x, y) in a.iter().zip(orig.iter()) {
            assert!((x.re / 16.0 - y.re).abs() < 1e-10);
            assert!((x.im / 16.0 - y.im).abs() < 1e-10);
        }
    }

    #[test]
    fn fft_of_impulse_is_flat() {
        let mut a = vec![Cpx::default(); 8];
        a[0] = Cpx::new(1.0, 0.0);
        fft_in_place(&mut a, false);
        for c in &a {
            assert!((c.re - 1.0).abs() < 1e-12 && c.im.abs() < 1e-12);
        }
    }

    #[test]
    fn convolution_matches_naive() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [0.5, -1.0, 0.25, 2.0];
        let got = circular_convolve(&x, &y);
        for k in 0..4 {
            let mut want = 0.0;
            for i in 0..4 {
                want += x[i] * y[(k + 4 - i) % 4];
            }
            assert!((got[k] - want).abs() < 1e-10, "k={k}: {} vs {want}", got[k]);
        }
    }

    #[test]
    fn parseval_holds() {
        let mut a: Vec<Cpx> = (0..32).map(|i| Cpx::new((i as f64).sin(), 0.0)).collect();
        let time_energy: f64 = a.iter().map(|c| c.re * c.re + c.im * c.im).sum();
        fft_in_place(&mut a, false);
        let freq_energy: f64 = a.iter().map(|c| c.re * c.re + c.im * c.im).sum::<f64>() / 32.0;
        assert!((time_energy - freq_energy).abs() < 1e-9);
    }
}
