//! Symmetric eigendecomposition via cyclic Jacobi rotations.
//!
//! Used by the Nystrom feature map (`(K_AA + λI)^{−1/2}`, Appendix C) and
//! the PSD property tests (Theorem 2: sampled Gram matrices of the
//! spherical Yat-kernel must have nonnegative spectra). Matrices are small
//! (anchor counts P ≤ 64), so the O(n³)-per-sweep Jacobi method is ideal:
//! simple, branch-predictable, and accurate to machine precision.

use crate::math::linalg::Mat;

/// Eigendecomposition `A = V diag(λ) Vᵀ` of a symmetric matrix.
/// Returns `(eigenvalues, eigenvectors)` with eigenvectors in the *columns*
/// of the returned matrix, sorted by descending eigenvalue.
pub fn symmetric_eig(a: &Mat) -> (Vec<f64>, Mat) {
    assert_eq!(a.rows, a.cols, "symmetric_eig needs a square matrix");
    let n = a.rows;
    // f64 working copy
    let mut m: Vec<f64> = a.data.iter().map(|&x| x as f64).collect();
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let idx = |r: usize, c: usize| r * n + c;

    for _sweep in 0..100 {
        // off-diagonal Frobenius mass
        let mut off = 0.0;
        for r in 0..n {
            for c in r + 1..n {
                off += m[idx(r, c)] * m[idx(r, c)];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + frob(&m)) {
            break;
        }
        for p in 0..n {
            for q in p + 1..n {
                let apq = m[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[idx(p, p)];
                let aqq = m[idx(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p,q of m
                for k in 0..n {
                    let mkp = m[idx(k, p)];
                    let mkq = m[idx(k, q)];
                    m[idx(k, p)] = c * mkp - s * mkq;
                    m[idx(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[idx(p, k)];
                    let mqk = m[idx(q, k)];
                    m[idx(p, k)] = c * mpk - s * mqk;
                    m[idx(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[idx(k, p)];
                    let vkq = v[idx(k, q)];
                    v[idx(k, p)] = c * vkp - s * vkq;
                    v[idx(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }

    let mut pairs: Vec<(f64, usize)> = (0..n).map(|i| (m[idx(i, i)], i)).collect();
    pairs.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let eigvals: Vec<f64> = pairs.iter().map(|p| p.0).collect();
    let mut vecs = Mat::zeros(n, n);
    for (new_col, &(_, old_col)) in pairs.iter().enumerate() {
        for r in 0..n {
            vecs.set(r, new_col, v[idx(r, old_col)] as f32);
        }
    }
    (eigvals, vecs)
}

fn frob(m: &[f64]) -> f64 {
    m.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// Inverse matrix square root `A^{−1/2}` of a symmetric PSD matrix, with
/// eigenvalue floor `floor` guarding near-singular directions.
pub fn inv_sqrt_psd(a: &Mat, floor: f64) -> Mat {
    let (vals, vecs) = symmetric_eig(a);
    let n = a.rows;
    // B = V diag(λ^{-1/2}) Vᵀ
    let mut scaled = vecs.clone(); // columns scaled by λ^{-1/2}
    for (j, &l) in vals.iter().enumerate() {
        let inv = 1.0 / l.max(floor).sqrt();
        for r in 0..n {
            let x = scaled.get(r, j) * inv as f32;
            scaled.set(r, j, x);
        }
    }
    crate::math::linalg::matmul_a_bt(&scaled, &vecs)
}

/// Smallest eigenvalue of a symmetric matrix (PSD witness for tests).
pub fn min_eigenvalue(a: &Mat) -> f64 {
    let (vals, _) = symmetric_eig(a);
    vals.last().copied().unwrap_or(f64::NAN)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::{matmul, matmul_a_bt, Mat};
    use crate::math::rng::Rng;

    fn random_symmetric(n: usize, rng: &mut Rng) -> Mat {
        let b = Mat::randn(n, n, rng);
        let mut s = Mat::zeros(n, n);
        for r in 0..n {
            for c in 0..n {
                s.set(r, c, 0.5 * (b.get(r, c) + b.get(c, r)));
            }
        }
        s
    }

    #[test]
    fn reconstructs_matrix() {
        let mut rng = Rng::new(21);
        let a = random_symmetric(8, &mut rng);
        let (vals, vecs) = symmetric_eig(&a);
        // A ?= V diag(vals) Vᵀ
        let mut scaled = vecs.clone();
        for j in 0..8 {
            for r in 0..8 {
                let x = scaled.get(r, j) * vals[j] as f32;
                scaled.set(r, j, x);
            }
        }
        let rec = matmul_a_bt(&scaled, &vecs);
        for (x, y) in rec.data.iter().zip(a.data.iter()) {
            assert!((x - y).abs() < 1e-4, "{x} vs {y}");
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Rng::new(22);
        let a = random_symmetric(10, &mut rng);
        let (_, v) = symmetric_eig(&a);
        let vtv = matmul(&v.transpose(), &v);
        for r in 0..10 {
            for c in 0..10 {
                let want = if r == c { 1.0 } else { 0.0 };
                assert!((vtv.get(r, c) - want).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let a = Mat::from_fn(3, 3, |r, c| if r == c { (3 - r) as f32 } else { 0.0 });
        let (vals, _) = symmetric_eig(&a);
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 2.0).abs() < 1e-10);
        assert!((vals[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn inv_sqrt_squares_to_inverse() {
        let mut rng = Rng::new(23);
        // PSD matrix: BᵀB + I
        let b = Mat::randn(6, 6, &mut rng);
        let mut a = matmul(&b.transpose(), &b);
        for i in 0..6 {
            let x = a.get(i, i) + 1.0;
            a.set(i, i, x);
        }
        let s = inv_sqrt_psd(&a, 1e-12);
        // s·a·s ≈ I
        let prod = matmul(&matmul(&s, &a), &s);
        for r in 0..6 {
            for c in 0..6 {
                let want = if r == c { 1.0 } else { 0.0 };
                assert!((prod.get(r, c) - want).abs() < 1e-3, "({r},{c})={}", prod.get(r, c));
            }
        }
    }

    #[test]
    fn psd_gram_has_nonnegative_spectrum() {
        let mut rng = Rng::new(24);
        let b = Mat::randn(12, 5, &mut rng);
        let gram = matmul_a_bt(&b, &b);
        assert!(min_eigenvalue(&gram) > -1e-4);
    }
}
