//! Tiny leveled logger (the `log` facade is vendored but no emitter is, so
//! we keep one self-contained implementation with zero setup).
//!
//! Level comes from `SLAY_LOG` (`error|warn|info|debug|trace`, default
//! `info`). Output goes to stderr with a monotonic-millis timestamp so
//! coordinator traces are orderable.

use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

fn max_level() -> Level {
    static L: OnceLock<Level> = OnceLock::new();
    *L.get_or_init(|| match std::env::var("SLAY_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        Ok(other) => {
            // Loud once (ADR-008: misconfiguration never fails silently):
            // a typo'd SLAY_LOG would otherwise just quietly mean "info".
            eprintln!(
                "SLAY_LOG={other:?} is not a log level \
                 (expected error|warn|info|debug|trace); defaulting to info"
            );
            Level::Info
        }
        Err(_) => Level::Info,
    })
}

fn start() -> Instant {
    static T: OnceLock<Instant> = OnceLock::new();
    *T.get_or_init(Instant::now)
}

/// True if `level` would be emitted (guard for expensive formatting).
pub fn enabled(level: Level) -> bool {
    level <= max_level()
}

#[doc(hidden)]
pub fn emit(level: Level, module: &str, msg: std::fmt::Arguments) {
    if !enabled(level) {
        return;
    }
    let ms = start().elapsed().as_secs_f64() * 1e3;
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{ms:>10.2}ms {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($($arg:tt)*) => {
        $crate::util::logging::emit($crate::util::logging::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_level_is_info() {
        // (SLAY_LOG unset in the test env)
        if std::env::var("SLAY_LOG").is_err() {
            assert!(enabled(Level::Info));
            assert!(!enabled(Level::Trace));
        }
    }

    #[test]
    fn macros_compile_and_run() {
        log_info!("hello {}", 42);
        log_debug!("debug {}", "msg");
        log_trace!("trace {}", 0.5);
        log_warn!("warn");
        log_error!("err");
    }
}
