//! Hand-rolled command-line parsing (clap is not vendored offline).
//!
//! Grammar: `slay <subcommand> [--flag] [--key value] [positional…]`.
//! `--key=value` is also accepted. Unknown flags are errors so typos fail
//! loudly.

use std::collections::BTreeMap;

/// Parsed arguments for one subcommand invocation.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the binary name).
    pub fn parse(argv: &[String]) -> anyhow::Result<Args> {
        let mut a = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                a.subcommand = it.next().unwrap().clone();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    a.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    a.flags.insert(body.to_string(), it.next().unwrap().clone());
                } else {
                    a.flags.insert(body.to_string(), "true".to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
        }
        Ok(a)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> anyhow::Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some("true") | Some("1") | Some("yes") => Ok(true),
            Some("false") | Some("0") | Some("no") => Ok(false),
            Some(v) => Err(anyhow::anyhow!("--{key} expects a boolean, got '{v}'")),
        }
    }

    /// Reject flags outside the allowed set (typo protection).
    pub fn validate(&self, allowed: &[&str]) -> anyhow::Result<()> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                return Err(anyhow::anyhow!(
                    "unknown flag --{k} for '{}' (allowed: {})",
                    self.subcommand,
                    allowed.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(&toks.iter().map(|s| s.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn subcommand_flags_positional() {
        // NB: a bare `--flag` greedily takes the next non-flag token as its
        // value, so positionals go before flags (or use `--flag=true`).
        let a = parse(&["serve", "model.hlo", "--port", "8080", "--verbose"]);
        assert_eq!(a.subcommand, "serve");
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get("verbose"), Some("true"));
        assert_eq!(a.positional, vec!["model.hlo"]);
    }

    #[test]
    fn equals_form() {
        let a = parse(&["bench", "--len=4096", "--mech=slay"]);
        assert_eq!(a.usize_or("len", 0).unwrap(), 4096);
        assert_eq!(a.get("mech"), Some("slay"));
    }

    #[test]
    fn typed_getters_and_defaults() {
        let a = parse(&["x", "--alpha", "0.5"]);
        assert_eq!(a.f64_or("alpha", 1.0).unwrap(), 0.5);
        assert_eq!(a.f64_or("beta", 2.0).unwrap(), 2.0);
        assert!(a.usize_or("alpha", 1).is_err());
    }

    #[test]
    fn trailing_bare_flag() {
        let a = parse(&["run", "--fast"]);
        assert!(a.bool_or("fast", false).unwrap());
    }

    #[test]
    fn validate_rejects_unknown() {
        let a = parse(&["run", "--typo", "1"]);
        assert!(a.validate(&["port"]).is_err());
        assert!(a.validate(&["typo"]).is_ok());
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--help"]);
        assert_eq!(a.subcommand, "");
        assert!(a.has("help"));
    }
}
