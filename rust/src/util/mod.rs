//! General-purpose substrates implemented in-repo because the offline image
//! vendors none of the usual crates: JSON (serde), CLI parsing (clap),
//! bench harness (criterion), property testing (proptest), logging.

pub mod benchkit;
pub mod cli;
pub mod fault;
pub mod json;
pub mod logging;
pub mod quickprop;
