//! Minimal JSON parser + writer (serde is not vendored offline).
//!
//! Supports the full JSON grammar we exchange with the build-time Python
//! side: objects, arrays, strings (with escapes incl. `\uXXXX`), numbers,
//! booleans, null. Used for `artifacts/manifest.json`, golden vectors,
//! configs and result files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Object keys are sorted (BTreeMap) so serialization
/// is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    // ---- accessors ---------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// `get` chain for required fields with a readable error.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json field '{key}'"))
    }

    /// Numeric array → Vec<f32>.
    pub fn as_f32_vec(&self) -> Option<Vec<f32>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as f32);
        }
        Some(out)
    }

    /// Numeric array → Vec<usize>.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        let arr = self.as_arr()?;
        let mut out = Vec::with_capacity(arr.len());
        for v in arr {
            out.push(v.as_f64()? as usize);
        }
        Some(out)
    }

    // ---- constructors ------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---- serialization -----------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing -----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    /// Parse a file.
    pub fn from_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?)
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no inf/nan — serialize as null (matches python json.dumps(allow_nan=False) avoidance)
        out.push_str("null");
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x:e}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len() && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r') {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)), // python json.dumps emits these
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
            if self.peek() == Some(b'I') {
                return self.lit("Infinity", Json::Num(f64::NEG_INFINITY));
            }
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: parse the low half if present.
                            if (0xD800..0xDC00).contains(&cp)
                                && self.b[self.pos + 5..].starts_with(b"\\u")
                            {
                                let hex2 =
                                    std::str::from_utf8(&self.b[self.pos + 7..self.pos + 11])
                                        .map_err(|_| self.err("bad surrogate"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad surrogate"))?;
                                let combined =
                                    0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(
                                    char::from_u32(combined).ok_or_else(|| self.err("bad surrogate"))?,
                                );
                                self.pos += 6; // extra \uXXXX
                            } else {
                                out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            }
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// ---- lazy path extraction (ADR-007) ----------------------------------------
//
// The serving hot path wants one or two fields out of a request line ("op",
// "seq") without materializing a `Json` tree — for an attend request the
// tree is dominated by float arrays the caller may never touch (mik-sdk's
// ADR-002 measured ~33× for partial-field reads over full-tree parsing).
// These scanners walk the raw bytes, skipping values structurally (strings
// by escape-aware scan, containers by bracket depth), and hand back the
// *unparsed* value slice; the caller then pays only for what it extracts
// via `lazy_str`/`lazy_f64`/`lazy_f32_array`. Malformed input returns
// `None` — callers fall back to `Json::parse` for a real error message.

fn skip_ws_b(b: &[u8], mut pos: usize) -> usize {
    while pos < b.len() && matches!(b[pos], b' ' | b'\t' | b'\n' | b'\r') {
        pos += 1;
    }
    pos
}

/// `pos` at the opening quote → position just past the closing quote.
fn skip_string_b(b: &[u8], mut pos: usize) -> Option<usize> {
    if b.get(pos) != Some(&b'"') {
        return None;
    }
    pos += 1;
    while pos < b.len() {
        match b[pos] {
            b'"' => return Some(pos + 1),
            b'\\' => pos += 2, // any escape is 1 byte except \uXXXX, whose hex can't contain '"'
            _ => pos += 1,
        }
    }
    None
}

/// `pos` at the first byte of a value → position just past it.
fn skip_value_b(b: &[u8], pos: usize) -> Option<usize> {
    let pos = skip_ws_b(b, pos);
    match *b.get(pos)? {
        b'"' => skip_string_b(b, pos),
        open @ (b'{' | b'[') => {
            let close = if open == b'{' { b'}' } else { b']' };
            let mut depth = 0usize;
            let mut p = pos;
            while p < b.len() {
                match b[p] {
                    b'"' => {
                        p = skip_string_b(b, p)?;
                        continue;
                    }
                    b'{' | b'[' => depth += 1,
                    b'}' | b']' => {
                        depth = depth.checked_sub(1)?;
                        if depth == 0 {
                            return if b[p] == close { Some(p + 1) } else { None };
                        }
                    }
                    _ => {}
                }
                p += 1;
            }
            None
        }
        _ => {
            // number / true / false / null: scan to a structural delimiter
            let mut p = pos;
            while p < b.len()
                && !matches!(b[p], b',' | b'}' | b']' | b' ' | b'\t' | b'\n' | b'\r')
            {
                p += 1;
            }
            if p == pos {
                None
            } else {
                Some(p)
            }
        }
    }
}

/// Top-level object field lookup without materializing a tree: returns the
/// *raw, unparsed* value slice for `key`, or `None` if `text` is not an
/// object or the key is absent/malformed. Keys are matched byte-for-byte
/// between the quotes, so a key containing JSON escapes won't match — the
/// serving protocol's keys are plain ASCII identifiers.
pub fn lazy_get<'a>(text: &'a str, key: &str) -> Option<&'a str> {
    let b = text.as_bytes();
    let mut pos = skip_ws_b(b, 0);
    if *b.get(pos)? != b'{' {
        return None;
    }
    pos += 1;
    loop {
        pos = skip_ws_b(b, pos);
        if *b.get(pos)? != b'"' {
            return None; // includes '}': key absent
        }
        let kstart = pos + 1;
        let after_key = skip_string_b(b, pos)?;
        let kend = after_key - 1;
        pos = skip_ws_b(b, after_key);
        if *b.get(pos)? != b':' {
            return None;
        }
        let vstart = skip_ws_b(b, pos + 1);
        let vend = skip_value_b(b, vstart)?;
        if &b[kstart..kend] == key.as_bytes() {
            return text.get(vstart..vend);
        }
        pos = skip_ws_b(b, vend);
        match *b.get(pos)? {
            b',' => pos += 1,
            _ => return None, // '}' = key absent; anything else = malformed
        }
    }
}

/// `lazy_get` folded over a key path (each step must be an object).
pub fn lazy_path<'a>(text: &'a str, path: &[&str]) -> Option<&'a str> {
    let mut cur = text;
    for key in path {
        cur = lazy_get(cur, key)?;
    }
    Some(cur)
}

/// Decode a raw string slice (as returned by [`lazy_get`]) into its
/// unescaped contents. `None` if the slice isn't a complete JSON string.
pub fn lazy_str(raw: &str) -> Option<String> {
    let mut p = Parser { b: raw.as_bytes(), pos: 0 };
    p.skip_ws();
    let s = p.string().ok()?;
    p.skip_ws();
    if p.pos == p.b.len() {
        Some(s)
    } else {
        None
    }
}

/// Parse a raw number slice. Slightly lenient (Rust's `f64` grammar is a
/// superset of JSON's) — fine for a hot-path getter; strict validation
/// happens on the `Json::parse` fallback.
pub fn lazy_f64(raw: &str) -> Option<f64> {
    raw.trim().parse::<f64>().ok()
}

/// Parse a raw `[n, n, ...]` slice of numbers straight into `Vec<f32>` —
/// the tensor hot path: no `Json::Arr` of boxed `Num`s, one allocation.
/// Flat numeric arrays only (nested arrays return `None`).
pub fn lazy_f32_array(raw: &str) -> Option<Vec<f32>> {
    let inner = raw.trim().strip_prefix('[')?.strip_suffix(']')?.trim();
    if inner.is_empty() {
        return Some(Vec::new());
    }
    let mut out = Vec::with_capacity(inner.len() / 4 + 1);
    for part in inner.split(',') {
        out.push(part.trim().parse::<f64>().ok()? as f32);
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic_values() {
        for text in [
            "null",
            "true",
            "false",
            "0",
            "-17",
            "[1,2,3]",
            "{\"a\":1,\"b\":[true,null]}",
        ] {
            let v = Json::parse(text).unwrap();
            let re = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, re, "{text}");
        }
    }

    #[test]
    fn parses_nested_manifest_shape() {
        let text = r#"
        {
          "artifacts": [
            {"name": "slay_fwd", "path": "slay_fwd.hlo.txt",
             "inputs": [{"shape": [8, 128, 256], "dtype": "f32"}],
             "scale": 1.5e-3}
          ],
          "version": 2
        }"#;
        let v = Json::parse(text).unwrap();
        let arts = v.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("name").unwrap().as_str().unwrap(), "slay_fwd");
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_usize_vec()
            .unwrap();
        assert_eq!(shape, vec![8, 128, 256]);
        assert!((arts[0].get("scale").unwrap().as_f64().unwrap() - 1.5e-3).abs() < 1e-12);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\tе🎈".to_string());
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".to_string())
        );
        assert_eq!(
            Json::parse(r#""🎈""#).unwrap(),
            Json::Str("🎈".to_string())
        );
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "\"abc", "{\"a\" 1}", "tru", "[1 2]", "1}", ""] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn python_nan_inf_literals() {
        assert!(Json::parse("NaN").unwrap().as_f64().unwrap().is_nan());
        assert_eq!(Json::parse("-Infinity").unwrap().as_f64().unwrap(), f64::NEG_INFINITY);
    }

    #[test]
    fn numbers_parse_precisely() {
        let v = Json::parse("[1e-3, 2.5, -0.125, 1000000]").unwrap();
        assert_eq!(v.as_f32_vec().unwrap(), vec![1e-3, 2.5, -0.125, 1e6]);
    }

    #[test]
    fn pretty_output_reparses() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f64(&[1.0, 2.5])),
            ("name", Json::Str("slay".into())),
        ]);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }

    // ---- lazy path extraction ----------------------------------------------

    #[test]
    fn lazy_get_extracts_raw_slices() {
        let text = r#"{"op": "attend", "seq": 7, "q": [1.5, -2, 3e-2], "nested": {"a": [1, 2]}}"#;
        assert_eq!(lazy_get(text, "op"), Some(r#""attend""#));
        assert_eq!(lazy_get(text, "seq"), Some("7"));
        assert_eq!(lazy_get(text, "q"), Some("[1.5, -2, 3e-2]"));
        assert_eq!(lazy_get(text, "nested"), Some(r#"{"a": [1, 2]}"#));
        assert_eq!(lazy_get(text, "missing"), None);
        assert_eq!(lazy_get("{}", "op"), None);
        assert_eq!(lazy_get("[1,2]", "op"), None);
        assert_eq!(lazy_get("not json", "op"), None);
    }

    #[test]
    fn lazy_get_skips_tricky_values() {
        // Strings containing braces, brackets, escaped quotes, colons and
        // commas must not confuse the structural scan.
        let text = r#"{"a": "}]\",{[", "b": {"x": "[\"", "y": [1, {"z": "}"}]}, "c": 42}"#;
        assert_eq!(lazy_get(text, "c"), Some("42"));
        assert_eq!(lazy_str(lazy_get(text, "a").unwrap()).unwrap(), "}]\",{[");
    }

    #[test]
    fn lazy_path_walks_nested_objects() {
        let text = r#"{"outer": {"inner": {"leaf": 3.5}}, "x": 1}"#;
        assert_eq!(lazy_path(text, &["outer", "inner", "leaf"]), Some("3.5"));
        assert_eq!(lazy_f64(lazy_path(text, &["outer", "inner", "leaf"]).unwrap()), Some(3.5));
        assert_eq!(lazy_path(text, &["outer", "nope"]), None);
        assert_eq!(lazy_path(text, &["x", "deeper"]), None); // leaf is not an object
    }

    #[test]
    fn lazy_f32_array_matches_full_parse() {
        let text = r#"{"q": [1e-3, 2.5, -0.125, 1000000], "empty": []}"#;
        let lazy = lazy_f32_array(lazy_get(text, "q").unwrap()).unwrap();
        let full = Json::parse(text).unwrap().get("q").unwrap().as_f32_vec().unwrap();
        assert_eq!(lazy, full);
        assert_eq!(lazy_f32_array(lazy_get(text, "empty").unwrap()).unwrap(), Vec::<f32>::new());
        assert_eq!(lazy_f32_array("[1, [2]]"), None);
        assert_eq!(lazy_f32_array("[1, oops]"), None);
        assert_eq!(lazy_f32_array("17"), None);
    }

    #[test]
    fn lazy_get_agrees_with_full_parse_on_random_objects() {
        // Serialize synthetic objects and check lazy slices reparse to the
        // same values the tree parser extracts.
        let mut rng = crate::math::rng::Rng::new(0x1a2f);
        for _ in 0..64 {
            let n = 1 + rng.below(6);
            let mut pairs = Vec::new();
            for i in 0..n {
                let key = format!("k{i}");
                let v = match rng.below(4) {
                    0 => Json::Num(rng.uniform() * 100.0),
                    1 => Json::Str(format!("s\"{{[,:]}}\\{i}")),
                    2 => Json::arr_f32(&[rng.uniform() as f32, -1.25, 3.0]),
                    _ => Json::obj(vec![("inner", Json::Num(i as f64))]),
                };
                pairs.push((key, v));
            }
            let obj = Json::Obj(pairs.iter().cloned().collect());
            for style in [obj.to_string(), obj.to_pretty()] {
                for (key, want) in &pairs {
                    let raw = lazy_get(&style, key)
                        .unwrap_or_else(|| panic!("lazy_get missed {key} in {style}"));
                    assert_eq!(&Json::parse(raw).unwrap(), want, "{key} in {style}");
                }
                assert_eq!(lazy_get(&style, "absent"), None);
            }
        }
    }
}
