//! Property-based testing mini-framework (proptest is not vendored offline).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it performs greedy shrinking via
//! the input's [`Shrink`] implementation and reports the minimal
//! counterexample. Deliberately small: generators are plain closures over
//! [`Rng`], shrinking is structural halving.

use crate::math::rng::Rng;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone + std::fmt::Debug {
    /// Candidate shrinks, larger-step candidates first. Empty = atomic.
    fn shrinks(&self) -> Vec<Self>;
}

impl Shrink for usize {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0 {
            vec![]
        } else {
            let mut v = vec![0, self / 2];
            if *self > 1 {
                v.push(self - 1);
            }
            v.dedup();
            v.retain(|x| x != self);
            v
        }
    }
}

impl Shrink for f64 {
    fn shrinks(&self) -> Vec<Self> {
        if *self == 0.0 {
            return vec![];
        }
        let mut v = vec![0.0, self / 2.0];
        if self.abs() > 1.0 {
            v.push(self.signum());
        }
        v.retain(|x| x != self);
        v
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrinks(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // drop halves
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[self.len() / 2..].to_vec());
        // drop one element
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        // shrink one element (first shrinkable)
        for (i, x) in self.iter().enumerate() {
            if let Some(sx) = x.shrinks().into_iter().next() {
                let mut v = self.clone();
                v[i] = sx;
                out.push(v);
                break;
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrinks().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

impl<A: Shrink, B: Shrink, C: Shrink> Shrink for (A, B, C) {
    fn shrinks(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrinks()
            .into_iter()
            .map(|a| (a, self.1.clone(), self.2.clone()))
            .collect();
        out.extend(
            self.1
                .shrinks()
                .into_iter()
                .map(|b| (self.0.clone(), b, self.2.clone())),
        );
        out.extend(
            self.2
                .shrinks()
                .into_iter()
                .map(|c| (self.0.clone(), self.1.clone(), c)),
        );
        out
    }
}

/// Result of a failed property: the original and shrunk counterexamples.
#[derive(Debug)]
pub struct Falsified<T: std::fmt::Debug> {
    pub original: T,
    pub minimal: T,
    pub message: String,
}

/// Run a property over `cases` random inputs. Panics with the minimal
/// counterexample on failure (test-friendly).
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    if let Err(f) = check_quiet(seed, cases, &mut gen, &mut prop) {
        panic!(
            "property falsified!\n  original: {:?}\n  minimal:  {:?}\n  error:    {}",
            f.original, f.minimal, f.message
        );
    }
}

/// Non-panicking variant (used by this module's own tests).
pub fn check_quiet<T, G, P>(
    seed: u64,
    cases: usize,
    gen: &mut G,
    prop: &mut P,
) -> Result<(), Falsified<T>>
where
    T: Shrink,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for _ in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let (minimal, message) = shrink_loop(input.clone(), msg, prop);
            return Err(Falsified { original: input, minimal, message });
        }
    }
    Ok(())
}

fn shrink_loop<T: Shrink>(
    mut current: T,
    mut msg: String,
    prop: &mut impl FnMut(&T) -> Result<(), String>,
) -> (T, String) {
    let mut budget = 200;
    'outer: while budget > 0 {
        for cand in current.shrinks() {
            budget -= 1;
            if let Err(m) = prop(&cand) {
                current = cand;
                msg = m;
                continue 'outer;
            }
            if budget == 0 {
                break;
            }
        }
        break;
    }
    (current, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, |r| r.below(100), |&x| {
            if x < 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let mut gen = |r: &mut Rng| r.below(1000);
        let mut prop = |&x: &usize| {
            if x < 50 {
                Ok(())
            } else {
                Err(format!("{x} >= 50"))
            }
        };
        let f = check_quiet(2, 500, &mut gen, &mut prop).unwrap_err();
        // greedy halving shrinks to a small witness ≥ 50
        assert!(f.minimal >= 50 && f.minimal <= f.original);
        assert!(f.minimal <= 100, "minimal={}", f.minimal);
    }

    #[test]
    fn vec_shrinking_reduces_length() {
        let mut gen = |r: &mut Rng| (0..r.below(50) + 10).map(|_| r.below(10)).collect::<Vec<_>>();
        let mut prop = |v: &Vec<usize>| {
            if v.len() < 5 {
                Ok(())
            } else {
                Err("too long".into())
            }
        };
        let f = check_quiet(3, 10, &mut gen, &mut prop).unwrap_err();
        assert!(f.minimal.len() >= 5 && f.minimal.len() <= 9, "{}", f.minimal.len());
    }

    #[test]
    fn tuple_shrinking_works() {
        let mut gen = |r: &mut Rng| (r.below(100), r.range(-4.0, 4.0));
        let mut prop =
            |t: &(usize, f64)| if t.0 < 90 { Ok(()) } else { Err("big".into()) };
        let f = check_quiet(4, 500, &mut gen, &mut prop).unwrap_err();
        assert!(f.minimal.0 >= 90);
        assert_eq!(f.minimal.1, 0.0); // second component shrunk away
    }
}
