//! Deterministic fault injection (ADR-008).
//!
//! A seeded [`FaultPlan`] is parsed once from the `SLAY_FAULTS` env var
//! and consulted from named *sites* threaded through the serving stack
//! (spill read/write, snapshot write, wire rx/tx, worker compute, the
//! worker loop itself). The spec grammar is
//!
//! ```text
//! SLAY_FAULTS = "spill_write:io@0.02;frame_rx:corrupt@0.01;decode:panic@0.005;seed=7"
//! ```
//!
//! i.e. `;`-separated `site:kind@probability` clauses plus an optional
//! `seed=N` clause. Three fault kinds exist — `io` (the site reports an
//! I/O-style error), `corrupt` (the site mangles bytes), `panic` (the
//! site panics) — and each site documents which kinds it honors.
//!
//! **Determinism.** Whether draw number `c` at a site fires is a pure
//! function of `(seed, site, c)` — a seeded hash compared against the
//! clause's probability — so the *set* of firing draws is independent of
//! thread scheduling. Two runs that make the same number of draws at a
//! site inject exactly the same faults at the same draw indices, which is
//! what lets the chaos harness (`rust/tests/chaos.rs`) make assertions
//! about fault counts instead of praying to `rand`.
//!
//! **Zero overhead when unset.** The global plan lives in a
//! `OnceLock<Option<FaultPlan>>`: after the first call, [`fire`] is one
//! atomic load and a branch on `None`. No site pays for the machinery in
//! production.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// What an armed site should do when its draw fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail the operation with an injected I/O-style error.
    Io,
    /// Corrupt the bytes the operation produces or consumes.
    Corrupt,
    /// Panic at the site.
    Panic,
}

impl FaultKind {
    fn parse(s: &str) -> anyhow::Result<FaultKind> {
        match s {
            "io" => Ok(FaultKind::Io),
            "corrupt" => Ok(FaultKind::Corrupt),
            "panic" => Ok(FaultKind::Panic),
            other => anyhow::bail!("unknown fault kind '{other}' (expected io|corrupt|panic)"),
        }
    }
}

/// One armed clause: a site name, what to inject, and how often.
struct Clause {
    site: String,
    kind: FaultKind,
    prob: f64,
    /// Draws made at this site so far (the deterministic sampling index).
    draws: AtomicU64,
}

/// A parsed, seeded fault schedule. See the module docs for the grammar.
pub struct FaultPlan {
    seed: u64,
    clauses: Vec<Clause>,
}

impl FaultPlan {
    /// Parse a `SLAY_FAULTS` spec. Errors on malformed clauses rather
    /// than guessing — a chaos run with a typo'd plan should fail loudly,
    /// not run fault-free.
    pub fn parse(spec: &str) -> anyhow::Result<FaultPlan> {
        let mut seed = 0x51A7_D6E8_FEB8_6659_u64;
        let mut clauses = Vec::new();
        for tok in spec.split(';').map(str::trim).filter(|t| !t.is_empty()) {
            if let Some(s) = tok.strip_prefix("seed=") {
                seed = s.parse::<u64>().map_err(|_| anyhow::anyhow!("bad seed '{s}'"))?;
                continue;
            }
            let (site, rest) = tok
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("clause '{tok}' is not site:kind@prob"))?;
            let (kind, prob) = rest
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("clause '{tok}' is not site:kind@prob"))?;
            let kind = FaultKind::parse(kind)?;
            let prob = prob
                .parse::<f64>()
                .ok()
                .filter(|p| (0.0..=1.0).contains(p))
                .ok_or_else(|| anyhow::anyhow!("bad probability '{prob}' in '{tok}'"))?;
            anyhow::ensure!(!site.is_empty(), "empty site name in '{tok}'");
            clauses.push(Clause {
                site: site.to_string(),
                kind,
                prob,
                draws: AtomicU64::new(0),
            });
        }
        anyhow::ensure!(!clauses.is_empty(), "fault spec has no clauses");
        Ok(FaultPlan { seed, clauses })
    }

    /// Make one draw at `site`: `Some(kind)` iff this draw fires. Sites
    /// not named in the plan never fire and cost one linear scan over the
    /// (handful of) clauses.
    pub fn fire(&self, site: &str) -> Option<FaultKind> {
        let c = self.clauses.iter().find(|c| c.site == site)?;
        let draw = c.draws.fetch_add(1, Ordering::Relaxed);
        let z = mix(self.seed ^ fnv1a(site), draw);
        // 53 uniform bits → [0, 1); fires iff below the clause probability.
        let u = (z >> 11) as f64 / (1u64 << 53) as f64;
        (u < c.prob).then_some(c.kind)
    }
}

/// splitmix64-style finalizer over (stream, index).
fn mix(stream: u64, index: u64) -> u64 {
    let mut z = stream ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

static PLAN: OnceLock<Option<FaultPlan>> = OnceLock::new();

fn plan() -> Option<&'static FaultPlan> {
    PLAN.get_or_init(|| match std::env::var("SLAY_FAULTS") {
        Ok(spec) if !spec.trim().is_empty() => match FaultPlan::parse(&spec) {
            Ok(p) => {
                crate::log_warn!("fault injection ARMED: SLAY_FAULTS={spec}");
                Some(p)
            }
            Err(e) => {
                crate::log_error!("ignoring malformed SLAY_FAULTS '{spec}': {e}");
                None
            }
        },
        _ => None,
    })
    .as_ref()
}

/// True iff a fault plan is armed for this process.
pub fn active() -> bool {
    plan().is_some()
}

/// Global draw at `site` against the process plan (never fires when
/// `SLAY_FAULTS` is unset — the production fast path is one branch).
pub fn fire(site: &str) -> Option<FaultKind> {
    plan()?.fire(site)
}

/// Convenience for panic-only sites: panics iff a draw at `site` fires
/// (any kind — a site that can only die treats io/corrupt as panic too).
pub fn maybe_panic(site: &str) {
    if fire(site).is_some() {
        panic!("injected fault at site '{site}'");
    }
}

/// Convenience for corrupt-capable byte sites: flips the last byte of
/// `buf` iff a `corrupt` draw at `site` fires. Returns true on injection.
pub fn corrupt_tail(site: &str, buf: &mut [u8]) -> bool {
    if fire(site) == Some(FaultKind::Corrupt) {
        if let Some(last) = buf.last_mut() {
            *last ^= 0xff;
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    // These tests exercise FaultPlan instances directly and never touch
    // the process-global plan: initializing the OnceLock from a test
    // would leak injected faults into every other test in the binary.

    #[test]
    fn spec_parses_clauses_and_seed() {
        let p = FaultPlan::parse("spill_write:io@0.02;frame_rx:corrupt@0.01;seed=9").unwrap();
        assert_eq!(p.seed, 9);
        assert_eq!(p.clauses.len(), 2);
        assert_eq!(p.clauses[0].site, "spill_write");
        assert_eq!(p.clauses[0].kind, FaultKind::Io);
        assert_eq!(p.clauses[1].kind, FaultKind::Corrupt);
        assert!((p.clauses[1].prob - 0.01).abs() < 1e-12);
    }

    #[test]
    fn malformed_specs_are_rejected() {
        for bad in [
            "",
            "justasite",
            "site:io",
            "site:@0.5",
            "site:frob@0.5",
            "site:io@1.5",
            "site:io@-0.1",
            "site:io@nan",
            ":io@0.5",
            "seed=xyz",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn draws_are_deterministic_per_index() {
        let spec = "decode:panic@0.2;seed=42";
        let a = FaultPlan::parse(spec).unwrap();
        let b = FaultPlan::parse(spec).unwrap();
        let fires_a: Vec<bool> = (0..4096).map(|_| a.fire("decode").is_some()).collect();
        let fires_b: Vec<bool> = (0..4096).map(|_| b.fire("decode").is_some()).collect();
        assert_eq!(fires_a, fires_b, "same (seed, site, index) must fire identically");
        let n = fires_a.iter().filter(|f| **f).count();
        // 4096 draws at p=0.2: the seeded hash should land in the right
        // ballpark (expected 819, very loose bounds).
        assert!((400..=1300).contains(&n), "fired {n}/4096 at p=0.2");
    }

    #[test]
    fn edge_probabilities_and_unknown_sites() {
        let p = FaultPlan::parse("never:io@0;always:panic@1;seed=3").unwrap();
        for _ in 0..256 {
            assert_eq!(p.fire("never"), None);
            assert_eq!(p.fire("always"), Some(FaultKind::Panic));
            assert_eq!(p.fire("unlisted_site"), None);
        }
    }

    #[test]
    fn sites_draw_independent_streams() {
        let p = FaultPlan::parse("a:io@0.5;b:io@0.5;seed=11").unwrap();
        let fa: Vec<bool> = (0..512).map(|_| p.fire("a").is_some()).collect();
        let fb: Vec<bool> = (0..512).map(|_| p.fire("b").is_some()).collect();
        assert_ne!(fa, fb, "distinct sites must not share a draw stream");
    }

    #[test]
    fn corrupt_tail_flips_exactly_on_corrupt() {
        let p = FaultPlan::parse("tx:corrupt@1;seed=1").unwrap();
        // Instance-level equivalent of corrupt_tail's logic.
        let mut buf = [1u8, 2, 3];
        if p.fire("tx") == Some(FaultKind::Corrupt) {
            *buf.last_mut().unwrap() ^= 0xff;
        }
        assert_eq!(buf, [1, 2, 3 ^ 0xff]);
    }
}
