//! Benchmark harness (criterion is not vendored offline).
//!
//! Provides warmed-up, repeated timing with mean/p50/p95 reporting, CSV and
//! JSON result emission under `results/`, and a tiny table printer that
//! formats rows the way the paper's tables do. Every file in `benches/`
//! uses this harness (`harness = false` in Cargo.toml).

use std::io::Write as _;
use std::time::{Duration, Instant};

/// Timing summary for one benchmark case.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub min_ms: f64,
}

/// Run `f` with `warmup` discarded iterations then `iters` timed ones.
/// Returns per-iteration statistics. `f` should return something cheap to
/// drop; use `std::hint::black_box` inside for anti-DCE.
pub fn time_fn(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    summarize(name, &samples)
}

/// Adaptive variant: keeps iterating until `budget` is spent (at least 3
/// iterations), so fast and slow cases share one call site.
pub fn time_budget(name: &str, budget: Duration, mut f: impl FnMut()) -> Timing {
    f(); // warmup
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < 3 || start.elapsed() < budget {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
        if samples.len() >= 10_000 {
            break;
        }
    }
    summarize(name, &samples)
}

fn summarize(name: &str, samples: &[f64]) -> Timing {
    use crate::math::stats::{mean, percentile};
    Timing {
        name: name.to_string(),
        iters: samples.len(),
        mean_ms: mean(samples),
        p50_ms: percentile(samples, 50.0),
        p95_ms: percentile(samples, 95.0),
        min_ms: samples.iter().copied().fold(f64::INFINITY, f64::min),
    }
}

/// Resolve the results directory (`SLAY_RESULTS` or `results/`), creating it.
pub fn results_dir() -> std::path::PathBuf {
    let dir = std::env::var("SLAY_RESULTS").unwrap_or_else(|_| "results".to_string());
    let p = std::path::PathBuf::from(dir);
    let _ = std::fs::create_dir_all(&p);
    p
}

/// Write a CSV file under `results/` with a header row.
pub fn write_csv(file: &str, header: &[&str], rows: &[Vec<String>]) -> std::io::Result<()> {
    let path = results_dir().join(file);
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    eprintln!("[benchkit] wrote {}", path.display());
    Ok(())
}

/// Write a JSON result file under `results/`.
pub fn write_json(file: &str, value: &crate::util::json::Json) -> std::io::Result<()> {
    let path = results_dir().join(file);
    std::fs::write(&path, value.to_pretty())?;
    eprintln!("[benchkit] wrote {}", path.display());
    Ok(())
}

/// One row of a machine-readable scaling record (`BENCH_*.json`): the
/// timing summary plus derived throughput for one (mechanism, engine, L)
/// cell. `fig2_scaling` emits these so the perf trajectory of the causal
/// engines is recorded per PR (ADR-003's before/after harness).
pub fn scaling_entry(
    mechanism: &str,
    engine: &str,
    l: usize,
    t: &Timing,
    toks_per_s: f64,
) -> crate::util::json::Json {
    use crate::util::json::Json;
    Json::obj(vec![
        ("mechanism", Json::Str(mechanism.to_string())),
        ("engine", Json::Str(engine.to_string())),
        ("l", Json::Num(l as f64)),
        ("iters", Json::Num(t.iters as f64)),
        ("mean_ms", Json::Num(t.mean_ms)),
        ("p50_ms", Json::Num(t.p50_ms)),
        ("p95_ms", Json::Num(t.p95_ms)),
        ("min_ms", Json::Num(t.min_ms)),
        ("toks_per_s", Json::Num(toks_per_s)),
    ])
}

/// Paper-style table printer: fixed-width columns, header rule.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row arity");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let ncol = self.header.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            w[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:<width$}  ", c, width = w[i]));
            }
            println!("{}", s.trim_end());
        };
        line(&self.header);
        println!("{}", "-".repeat(w.iter().sum::<usize>() + 2 * ncol));
        for row in &self.rows {
            line(row);
        }
    }

    /// Also dump as CSV.
    pub fn to_csv(&self, file: &str) -> std::io::Result<()> {
        let header: Vec<&str> = self.header.iter().map(|s| s.as_str()).collect();
        write_csv(file, &header, &self.rows)
    }
}

/// Format helpers shared by benches.
pub fn fmt_ms(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.1}")
    } else if x >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

pub fn fmt_sci(x: f64) -> String {
    format!("{x:.2e}")
}

/// Peak-RSS style estimate: bytes → MiB string.
pub fn fmt_mib(bytes: usize) -> String {
    format!("{:.1}", bytes as f64 / (1024.0 * 1024.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_counts_iters() {
        let t = time_fn("noop", 2, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(t.iters, 10);
        assert!(t.mean_ms >= 0.0 && t.mean_ms.is_finite());
        assert!(t.p95_ms >= t.p50_ms || (t.p95_ms - t.p50_ms).abs() < 1e-9);
    }

    #[test]
    fn time_budget_runs_at_least_three() {
        let t = time_budget("noop", Duration::from_millis(1), || {
            std::hint::black_box(0);
        });
        assert!(t.iters >= 3);
    }

    #[test]
    fn table_rejects_bad_arity() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            t.row(vec!["1".into()]);
        }));
        assert!(r.is_err());
    }

    #[test]
    fn scaling_entry_is_machine_readable() {
        let t = time_fn("noop", 0, 3, || {
            std::hint::black_box(0);
        });
        let e = scaling_entry("slay", "chunked", 128, &t, 1234.5);
        assert_eq!(e.get("mechanism").unwrap().as_str().unwrap(), "slay");
        assert_eq!(e.get("engine").unwrap().as_str().unwrap(), "chunked");
        assert_eq!(e.get("l").unwrap().as_usize().unwrap(), 128);
        assert!((e.get("toks_per_s").unwrap().as_f64().unwrap() - 1234.5).abs() < 1e-9);
        // round-trips through the JSON writer/parser
        let back = crate::util::json::Json::parse(&e.to_pretty()).unwrap();
        assert_eq!(back.get("l").unwrap().as_usize().unwrap(), 128);
    }

    #[test]
    fn csv_writes_to_results_dir() {
        let dir = std::env::temp_dir().join("slay_benchkit_test");
        std::env::set_var("SLAY_RESULTS", &dir);
        write_csv("t.csv", &["x", "y"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(dir.join("t.csv")).unwrap();
        assert_eq!(text, "x,y\n1,2\n");
        std::env::remove_var("SLAY_RESULTS");
    }
}
