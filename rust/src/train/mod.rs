//! Training driver: Rust owns the loop, the data and the checkpoints; the
//! gradient math is the AOT `train_step` artifact executed over PJRT.
//! Python never runs here.

use crate::runtime::checkpoint::Checkpoint;
use crate::runtime::executor::{Executable, TensorData};
use crate::runtime::Registry;
use std::rc::Rc;

/// Shapes the driver needs from the artifact's model config.
#[derive(Clone, Copy, Debug)]
pub struct TrainShapes {
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
}

/// A live training session over one `train_step_*` (or `cls_train_step_*`)
/// artifact. Holds the full optimizer state (params, m, v, step) as host
/// tensors between steps.
pub struct Trainer {
    step_exe: Rc<Executable>,
    /// params + adam m + adam v (+ step counter at the end).
    state: Vec<TensorData>,
    n_params: usize,
    pub shapes: TrainShapes,
    pub steps_done: usize,
    /// (step, loss) history.
    pub history: Vec<(usize, f32)>,
    /// Shape of the targets input (LM: [B, L] i32; cls: [B, n_labels] f32).
    targets_are_float: bool,
}

impl Trainer {
    /// Create a session: run the matching init artifact, zero the moments.
    pub fn new(
        reg: &Registry,
        step_artifact: &str,
        init_artifact: &str,
        seed: u32,
    ) -> anyhow::Result<Trainer> {
        let step_exe = reg.get(step_artifact)?;
        let init_exe = reg.get(init_artifact)?;
        let params = init_exe.run(&[TensorData::U32(vec![seed])])?;
        let n = step_exe.entry.param_names.len();
        anyhow::ensure!(
            params.len() == n,
            "init gave {} tensors, step wants {n} params",
            params.len()
        );
        let zeros: Vec<TensorData> = step_exe.entry.inputs[n..2 * n]
            .iter()
            .map(|s| TensorData::F32(vec![0.0; s.elements()]))
            .collect();
        let mut state = params;
        state.extend(zeros.iter().cloned()); // m
        state.extend(zeros); // v
        state.push(TensorData::F32(vec![0.0])); // step counter

        let batch = step_exe
            .entry
            .batch
            .ok_or_else(|| anyhow::anyhow!("artifact missing batch"))?;
        let seq_len = step_exe
            .entry
            .config_usize("seq_len")
            .ok_or_else(|| anyhow::anyhow!("artifact missing seq_len"))?;
        let vocab = step_exe.entry.config_usize("vocab").unwrap_or(0);
        let targets_are_float = matches!(
            step_exe.entry.inputs.last().map(|s| s.dtype),
            Some(crate::runtime::manifest::DType::F32)
        );
        Ok(Trainer {
            step_exe,
            state,
            n_params: n,
            shapes: TrainShapes { batch, seq_len, vocab },
            steps_done: 0,
            history: Vec::new(),
            targets_are_float,
        })
    }

    /// One optimizer step on an LM batch (`targets` i32, −1 = masked).
    pub fn step(&mut self, tokens: &[i32], targets: &[i32]) -> anyhow::Result<f32> {
        anyhow::ensure!(!self.targets_are_float, "this artifact wants float targets");
        self.step_impl(tokens, TensorData::I32(targets.to_vec()))
    }

    /// One optimizer step on a multi-label batch (`targets` multi-hot f32).
    pub fn step_multilabel(&mut self, tokens: &[i32], targets: &[f32]) -> anyhow::Result<f32> {
        anyhow::ensure!(self.targets_are_float, "this artifact wants int targets");
        self.step_impl(tokens, TensorData::F32(targets.to_vec()))
    }

    fn step_impl(&mut self, tokens: &[i32], targets: TensorData) -> anyhow::Result<f32> {
        let expect = self.shapes.batch * self.shapes.seq_len;
        anyhow::ensure!(
            tokens.len() == expect,
            "tokens: {} given, batch×seq = {expect}",
            tokens.len()
        );
        let mut inputs = self.state.clone();
        inputs.push(TensorData::I32(tokens.to_vec()));
        inputs.push(targets);
        let out = self.step_exe.run(&inputs)?;
        let loss = out.last().unwrap().scalar_f32()?;
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {}", self.steps_done);
        self.state = out[..out.len() - 1].to_vec();
        self.steps_done += 1;
        self.history.push((self.steps_done, loss));
        Ok(loss)
    }

    /// Current parameters (first n tensors of the state).
    pub fn params(&self) -> &[TensorData] {
        &self.state[..self.n_params]
    }

    pub fn param_names(&self) -> &[String] {
        &self.step_exe.entry.param_names
    }

    /// Run a forward/loss artifact with the current params.
    pub fn run_with_params(
        &self,
        exe: &Executable,
        extra: &[TensorData],
    ) -> anyhow::Result<Vec<TensorData>> {
        let mut inputs: Vec<TensorData> = self.params().to_vec();
        inputs.extend(extra.iter().cloned());
        exe.run(&inputs)
    }

    /// Save parameters to a checkpoint file.
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let shapes: Vec<Vec<usize>> = self.step_exe.entry.inputs[..self.n_params]
            .iter()
            .map(|s| s.shape.clone())
            .collect();
        let ck = Checkpoint::from_tensor_data(
            self.step_exe.entry.param_names.as_slice(),
            &shapes,
            self.params(),
        )?;
        ck.save(path)
    }

    /// Restore parameters from a checkpoint (moments reset).
    pub fn restore(&mut self, path: &std::path::Path) -> anyhow::Result<()> {
        let ck = Checkpoint::load(path)?;
        anyhow::ensure!(
            ck.tensors.len() == self.n_params,
            "checkpoint has {} tensors, model wants {}",
            ck.tensors.len(),
            self.n_params
        );
        for (i, (name, _, data)) in ck.tensors.iter().enumerate() {
            anyhow::ensure!(
                *name == self.step_exe.entry.param_names[i],
                "checkpoint order mismatch at {i}: {name}"
            );
            self.state[i] = TensorData::F32(data.clone());
        }
        Ok(())
    }

    /// Smoothed recent loss (mean of last `k` steps).
    pub fn recent_loss(&self, k: usize) -> f32 {
        let tail = &self.history[self.history.len().saturating_sub(k)..];
        if tail.is_empty() {
            f32::NAN
        } else {
            tail.iter().map(|(_, l)| l).sum::<f32>() / tail.len() as f32
        }
    }
}
