//! The `slay` command-line interface — leader entrypoint of the stack.
//!
//! ```text
//! slay serve     [--mechanism slay] [--workers N] [--seqs N] [--chunks N]
//! slay train     [--preset tiny] [--mechanism slay] [--steps N] [--ckpt path]
//! slay task      [--task copy] [--mechanism slay] [--steps N]
//! slay artifacts                      # list the AOT manifest
//! slay explore   [--what response|quadrature|denominator]
//! ```

use crate::config;
use crate::coordinator::request::AttendChunk;
use crate::coordinator::Coordinator;
use crate::data::corpus::{Corpus, CorpusConfig};
use crate::data::tasks::{Task, TaskGen};
use crate::math::linalg::Mat;
use crate::math::rng::Rng;
use crate::runtime::Registry;
use crate::train::Trainer;
use crate::util::cli::Args;

pub fn run(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::parse(&argv)?;
    match args.subcommand.as_str() {
        "serve" => serve(&args),
        "train" => train(&args),
        "task" => task(&args),
        "artifacts" => artifacts(&args),
        "explore" => explore(&args),
        "" | "help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            anyhow::bail!("unknown subcommand '{other}'")
        }
    }
}

fn print_help() {
    println!(
        "slay — Spherical Linearized Attention with Yat-Kernel (paper reproduction)\n\
         \n\
         USAGE: slay <subcommand> [flags]\n\
         \n\
         subcommands:\n\
           serve      run the serving coordinator on a synthetic workload\n\
           train      train an LM preset via the AOT train_step artifacts\n\
           task       train + eval one synthetic task (Table 3/8)\n\
           artifacts  list the AOT artifact manifest\n\
           explore    print kernel curves (Figs. 4-6) to stdout\n\
         \n\
         common flags: --mechanism slay|standard|yat|yat_spherical|favor|elu_linear|cosformer\n\
                       (parameterized specs work too: --mechanism slay:n_poly=16,d_prf=64\n\
                        or yat:eps=0.01 — serving supports every mechanism, quadratic ones\n\
                        run on a bounded rolling KV window)\n\
         serve flags:  --spill-dir dir   page idle session states to disk instead of\n\
                                         destroying them (faulted back on demand)\n\
                       --snapshot-root dir  allow the TCP op {{\"op\":\"snapshot\",\"dir\":name}}\n\
                                         to write named snapshots under this root\n\
                       --restore dir     resume a coordinator snapshot, with a possibly\n\
                                         different --workers count (resharding)\n\
                       --max-conns N     shed TCP connections beyond N with a JSON\n\
                                         error instead of spawning (default 1024)\n\
                       --prefix-cache-mb N  shared-prefix cache budget in MiB\n\
                                         (default 64; 0 disables the cache)\n\
                       --frontend F      threads | epoll | auto (default auto:\n\
                                         epoll reactor on linux x86_64/aarch64,\n\
                                         thread-per-connection elsewhere)\n\
                       --max-frame-mb N  cap on one wire message, binary frame\n\
                                         payload or JSON line (default 64)\n\
                       --max-pending-mb N   per-connection unflushed reply bytes\n\
                                         before reads pause (default 8)\n\
                       --max-pending-reqs N per-connection in-flight requests\n\
                                         before reads pause (default 64)\n\
                       --drain-timeout-ms N shutdown waits this long for in-flight\n\
                                         replies before closing (default 5000)\n\
                       --request-timeout-ms N per-request deadline: expired work\n\
                                         gets a deterministic timeout error\n\
                                         (default 0 = unbounded)\n\
                       --metrics-addr addr:port  also serve Prometheus text\n\
                                         exposition over HTTP GET /metrics\n\
         slay flags:   --eps --r-nodes --n-poly --d-prf --poly --fusion --seed"
    );
}

fn serve(args: &Args) -> anyhow::Result<()> {
    args.validate(&[
        "mechanism", "workers", "max-batch", "max-wait-us", "queue-cap", "d-head", "d-v",
        "seqs", "chunks", "chunk-len", "eps", "r-nodes", "n-poly", "d-prf", "poly",
        "fusion", "seed", "listen", "duration-s", "horizon", "window", "spill-dir",
        "restore", "snapshot-root", "max-conns", "prefix-cache-mb", "frontend",
        "max-frame-mb", "max-pending-mb", "max-pending-reqs", "drain-timeout-ms",
        "request-timeout-ms", "metrics-addr",
    ])?;
    let mut cfg = config::coordinator_from_args(args)?;

    // `--restore dir` resumes a coordinator snapshot (ADR-004): the
    // manifest pins the mechanism spec and geometry — they are snapshot
    // state, not flags — while topology flags (--workers, --max-batch, …)
    // still apply, which is exactly the reshard/migration path.
    // (Coordinator::restore re-reads and re-validates the manifest itself
    // so it stays safe for non-CLI callers; the duplicate startup read is
    // deliberate.)
    let restore_dir = args.get("restore").map(std::path::PathBuf::from);
    if let Some(dir) = &restore_dir {
        let manifest = crate::coordinator::persist::Manifest::load(dir)?;
        manifest.apply_to(&mut cfg)?;
        println!(
            "restoring {} sequences from {} (mechanism {})",
            manifest.seqs.len(),
            dir.display(),
            manifest.mechanism
        );
    }
    let start_coord = |cfg: crate::coordinator::CoordinatorConfig| match &restore_dir {
        Some(dir) => Coordinator::restore(cfg, dir),
        None => Coordinator::start(cfg),
    };

    // `--listen addr:port` exposes the coordinator over TCP (JSON lines +
    // binary frames, see docs/PROTOCOL.md) instead of the synthetic workload.
    if let Some(addr) = args.get("listen") {
        let duration = args.u64_or("duration-s", 0)?;
        let frontend = crate::net::Frontend::parse(&args.get_or("frontend", "auto"))?;
        let defaults = crate::net::NetOptions::default();
        let opts = crate::net::NetOptions {
            max_conns: args.usize_or("max-conns", defaults.max_conns)?,
            max_frame_bytes: args.usize_or("max-frame-mb", 64)? * 1024 * 1024,
            max_pending_bytes: args.usize_or("max-pending-mb", 8)? * 1024 * 1024,
            max_pending_reqs: args.usize_or("max-pending-reqs", defaults.max_pending_reqs)?,
            drain_timeout: std::time::Duration::from_millis(
                args.u64_or("drain-timeout-ms", 5000)?,
            ),
        };
        let coord = std::sync::Arc::new(start_coord(cfg)?);
        // `--metrics-addr addr:port` serves Prometheus text exposition over
        // plain HTTP (GET /metrics) alongside the coordinator protocol port.
        // The handle stops the listener on drop, so it lives with the server.
        let _metrics_http = match args.get("metrics-addr") {
            Some(maddr) => {
                let h = crate::obs::MetricsHttp::start(maddr, coord.metrics_handle())?;
                println!("metrics on http://{}/metrics (Prometheus text)", h.addr());
                Some(h)
            }
            None => None,
        };
        let server = crate::net::serve(frontend, addr, &coord, opts)?;
        println!(
            "listening on {} ({} front end; JSON lines + binary frames, see docs/PROTOCOL.md)",
            server.addr(),
            server.frontend_name()
        );
        if duration == 0 {
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        std::thread::sleep(std::time::Duration::from_secs(duration));
        server.shutdown_drain(std::time::Duration::from_millis(
            args.u64_or("drain-timeout-ms", 5000)?,
        ));
        return Ok(());
    }
    let n_seqs = args.usize_or("seqs", 16)?;
    let n_chunks = args.usize_or("chunks", 32)?;
    let chunk_len = args.usize_or("chunk-len", 64)?;
    let d_head = cfg.d_head;
    let d_v = cfg.d_v;

    let coord = start_coord(cfg)?;
    let t0 = std::time::Instant::now();
    let mut rng = Rng::new(7);
    let seqs: Vec<_> = (0..n_seqs)
        .map(|_| coord.create_sequence().unwrap())
        .collect();
    let mut done = 0usize;
    for round in 0..n_chunks {
        for &seq in &seqs {
            let n = if round == 0 { chunk_len } else { 1 }; // prefill then decode
            let chunk = AttendChunk {
                seq,
                q: Mat::randn(n, d_head, &mut rng),
                k: Mat::randn(n, d_head, &mut rng),
                v: Mat::randn(n, d_v, &mut rng),
            };
            coord.attend(chunk)?;
            done += n;
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!("served {done} tokens across {n_seqs} sequences in {dt:.3}s");
    println!("throughput: {:.0} tok/s", done as f64 / dt);
    println!("{}", m.to_json().to_pretty());
    coord.shutdown()
}

fn train(args: &Args) -> anyhow::Result<()> {
    args.validate(&["preset", "mechanism", "steps", "ckpt", "seed", "log-every"])?;
    let preset = args.get_or("preset", "tiny");
    let mech = args.get_or("mechanism", "slay");
    let steps = args.usize_or("steps", 100)?;
    let seed = args.u64_or("seed", 0)? as u32;
    let log_every = args.usize_or("log-every", 10)?;

    let reg = Registry::open_default()?;
    let mut tr = Trainer::new(
        &reg,
        &format!("train_step_{preset}_{mech}"),
        &format!("init_{preset}"),
        seed,
    )?;
    let corpus = Corpus::new(
        CorpusConfig { vocab: tr.shapes.vocab, ..Default::default() },
        42,
    );
    let mut rng = Rng::new(seed as u64 + 1);
    println!(
        "training {mech}/{preset}: batch={} seq={} vocab={}",
        tr.shapes.batch, tr.shapes.seq_len, tr.shapes.vocab
    );
    let t0 = std::time::Instant::now();
    for step in 1..=steps {
        let (tokens, targets) = corpus.lm_batch(tr.shapes.batch, tr.shapes.seq_len, &mut rng);
        let loss = tr.step(&tokens, &targets)?;
        if step % log_every == 0 || step == steps {
            println!(
                "step {step:>5}  loss {loss:.4}  ppl {:.2}  ({:.1} tok/s)",
                (loss as f64).exp(),
                (step * tr.shapes.batch * tr.shapes.seq_len) as f64
                    / t0.elapsed().as_secs_f64()
            );
        }
    }
    if let Some(path) = args.get("ckpt") {
        tr.save(std::path::Path::new(path))?;
        println!("checkpoint saved to {path}");
    }
    Ok(())
}

fn task(args: &Args) -> anyhow::Result<()> {
    args.validate(&["task", "mechanism", "steps", "seed"])?;
    let task_name = args.get_or("task", "copy");
    let mech = args.get_or("mechanism", "slay");
    let steps = args.usize_or("steps", 200)?;
    let seed = args.u64_or("seed", 0)?;
    let task = Task::from_name(&task_name)
        .ok_or_else(|| anyhow::anyhow!("unknown task '{task_name}'"))?;

    let reg = Registry::open_default()?;
    let (loss, acc) = train_eval_task(&reg, task, &mech, steps, seed)?;
    println!("task={task_name} mechanism={mech}: final loss {loss:.4}, answer accuracy {acc:.3}");
    Ok(())
}

/// Train one synthetic task and return (final loss, answer accuracy) —
/// shared by the CLI, Table 3/8 bench and the synthetic_tasks example.
pub fn train_eval_task(
    reg: &Registry,
    task: Task,
    mech: &str,
    steps: usize,
    seed: u64,
) -> anyhow::Result<(f32, f64)> {
    let mut tr = Trainer::new(
        reg,
        &format!("train_step_task_{mech}"),
        "init_task",
        seed as u32,
    )?;
    let gen = TaskGen::new(tr.shapes.vocab, tr.shapes.seq_len);
    let mut rng = Rng::new(seed * 7919 + 13);
    let mut loss = f32::NAN;
    for _ in 0..steps {
        let (tokens, targets) = gen.batch(task, tr.shapes.batch, &mut rng);
        loss = tr.step(&tokens, &targets)?;
    }
    // eval: accuracy on fresh batches via the lm_fwd artifact
    let fwd = reg.get(&format!("lm_fwd_task_{mech}"))?;
    let mut accs = Vec::new();
    for _ in 0..4 {
        let (tokens, targets) = gen.batch(task, tr.shapes.batch, &mut rng);
        let out = tr.run_with_params(&fwd, &[crate::runtime::executor::TensorData::I32(tokens)])?;
        let logits = out[0].as_f32()?;
        accs.push(crate::eval::token_accuracy(logits, tr.shapes.vocab, &targets));
    }
    Ok((loss, crate::math::stats::mean(&accs)))
}

fn artifacts(_args: &Args) -> anyhow::Result<()> {
    let reg = Registry::open_default()?;
    println!("{:<32} {:<14} {:>7} {:>8}", "name", "kind", "inputs", "outputs");
    for (name, e) in &reg.manifest.artifacts {
        println!(
            "{:<32} {:<14} {:>7} {:>8}",
            name,
            e.kind,
            e.inputs.len(),
            e.outputs.len()
        );
    }
    Ok(())
}

fn explore(args: &Args) -> anyhow::Result<()> {
    args.validate(&["what", "eps", "r-nodes"])?;
    let what = args.get_or("what", "response");
    let eps = args.f64_or("eps", 1e-3)? as f32;
    match what.as_str() {
        "response" => {
            println!("x,e_sph,softmax_exp");
            for i in 0..=40 {
                let x = -1.0 + 2.0 * i as f32 / 40.0;
                println!(
                    "{x:.3},{:.5},{:.5}",
                    crate::kernels::yat::e_sph(x, eps),
                    (x / (32f32).sqrt()).exp()
                );
            }
        }
        "quadrature" => {
            let r = args.usize_or("r-nodes", 8)?;
            let q = crate::math::quadrature::GaussLaguerre::scaled(r, 2.0 + eps as f64);
            println!("node,s_r,w_r");
            for i in 0..r {
                println!("{i},{:.6},{:.6}", q.nodes[i], q.weights[i]);
            }
        }
        "denominator" => {
            let mut rng = Rng::new(1);
            let q = Mat::randn(64, 16, &mut rng);
            let k = Mat::randn(64, 16, &mut rng);
            for name in ["slay", "favor", "elu_linear"] {
                let m = crate::kernels::config::Mechanism::parse(name)?;
                let op = crate::kernels::build(&m, 16, 64)?;
                let dens = op.denominators(q.view(), k.view(), false);
                let min = dens.iter().cloned().fold(f32::INFINITY, f32::min);
                println!("{name}: min denominator {min:.6}");
            }
        }
        other => anyhow::bail!("unknown --what '{other}'"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn help_runs() {
        run(vec![]).unwrap();
        run(vec!["help".into()]).unwrap();
    }

    #[test]
    fn unknown_subcommand_errors() {
        assert!(run(vec!["bogus".into()]).is_err());
    }

    #[test]
    fn explore_response_runs_without_artifacts() {
        run(vec!["explore".into(), "--what".into(), "response".into()]).unwrap();
        run(vec!["explore".into(), "--what".into(), "quadrature".into()]).unwrap();
        run(vec!["explore".into(), "--what".into(), "denominator".into()]).unwrap();
        assert!(run(vec!["explore".into(), "--what".into(), "bogus".into()]).is_err());
    }
}
