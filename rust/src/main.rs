//! `slay` CLI — leader entrypoint for the SLAY serving/training stack.

fn main() -> anyhow::Result<()> {
    slay::cli_main(std::env::args().skip(1).collect())
}
