//! The SLAY estimator: spherical constraint → Bernstein/Laplace integral →
//! Gauss–Laguerre quadrature → polynomial × exponential random features →
//! fusion → concatenation (§2.2–§2.4 of the paper).
//!
//! [`SlayFeatures`] maps token rows to the final feature matrix `Ψ(·)` used
//! by the linear-attention engine (Eq. 11). Query and key maps coincide for
//! every fusion except [`Fusion::LaplaceOnly`], which realizes the exact
//! Appendix-F identity `x²/(C−2x) = (C²/4)∫e^{−Cs}e^{2sx}ds − C/4 − x/2`
//! through asymmetric signed features.

use crate::kernels::config::{Fusion, SlayConfig};
#[cfg(test)]
use crate::kernels::config::PolyMethod;
use crate::kernels::features::poly::build_poly;
use crate::kernels::features::prf::Prf;
use crate::kernels::features::{kron_row, FeatureMap};
use crate::math::fft::circular_convolve;
use crate::math::linalg::{dot, normalize_rows_into, Mat, MatView, MatViewMut, Scratch};
use crate::math::quadrature::GaussLaguerre;
use crate::math::rng::Rng;

/// Feature maps that may differ between the query and key roles.
///
/// Inputs are strided [`MatView`]s (ADR-002): head column-blocks, chunk
/// row-ranges and single decode rows flow through without a gather copy.
/// Outputs are written through strided [`MatViewMut`]s with every
/// intermediate (normalized inputs, polynomial/PRF panels) drawn from the
/// caller's [`Scratch`] arena (ADR-003), so a warmed-up serving loop maps
/// features without touching the heap; `map_q`/`map_k` are the allocating
/// wrappers.
pub trait QKFeatures: Send + Sync {
    /// Final feature dimension m.
    fn dim(&self) -> usize;
    /// Query features into `out` (`x.rows() × dim`); `pos0` is the
    /// absolute position of row 0.
    fn map_q_into(&self, x: MatView, pos0: usize, scratch: &mut Scratch, out: MatViewMut);
    /// Key features into `out`.
    fn map_k_into(&self, x: MatView, pos0: usize, scratch: &mut Scratch, out: MatViewMut);
    /// Query features for a stacked block whose row `r` sits at its *own*
    /// absolute position `positions[r]` — the fused cross-session decode
    /// entry (ADR-005). The provided default maps row by row (correct for
    /// every implementation); implementations whose maps can batch rows at
    /// heterogeneous positions override it with one fused call.
    fn map_q_rows_into(
        &self,
        x: MatView,
        positions: &[usize],
        scratch: &mut Scratch,
        mut out: MatViewMut,
    ) {
        debug_assert_eq!(x.rows(), positions.len());
        let dim = self.dim();
        for r in 0..x.rows() {
            let orow = MatViewMut::new(out.row_mut(r), 1, dim);
            self.map_q_into(x.row_block(r, r + 1), positions[r], scratch, orow);
        }
    }
    /// Key features at per-row positions (see
    /// [`QKFeatures::map_q_rows_into`]).
    fn map_k_rows_into(
        &self,
        x: MatView,
        positions: &[usize],
        scratch: &mut Scratch,
        mut out: MatViewMut,
    ) {
        debug_assert_eq!(x.rows(), positions.len());
        let dim = self.dim();
        for r in 0..x.rows() {
            let orow = MatViewMut::new(out.row_mut(r), 1, dim);
            self.map_k_into(x.row_block(r, r + 1), positions[r], scratch, orow);
        }
    }
    /// Allocating wrapper over [`QKFeatures::map_q_into`].
    fn map_q(&self, x: MatView, pos0: usize) -> Mat {
        let mut out = Mat::zeros(x.rows(), self.dim());
        self.map_q_into(x, pos0, &mut Scratch::new(), out.view_mut());
        out
    }
    /// Allocating wrapper over [`QKFeatures::map_k_into`].
    fn map_k(&self, x: MatView, pos0: usize) -> Mat {
        let mut out = Mat::zeros(x.rows(), self.dim());
        self.map_k_into(x, pos0, &mut Scratch::new(), out.view_mut());
        out
    }
    /// Whether the induced score estimates are guaranteed nonnegative.
    fn positive(&self) -> bool;
}

/// Symmetric wrapper: same map for queries and keys.
pub struct SymMap {
    pub inner: Box<dyn FeatureMap>,
    pub positive: bool,
}

impl QKFeatures for SymMap {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn map_q_into(&self, x: MatView, pos0: usize, _scratch: &mut Scratch, out: MatViewMut) {
        self.inner.map_into(x, pos0, out);
    }

    fn map_k_into(&self, x: MatView, pos0: usize, _scratch: &mut Scratch, out: MatViewMut) {
        self.inner.map_into(x, pos0, out);
    }

    fn map_q_rows_into(
        &self,
        x: MatView,
        positions: &[usize],
        _scratch: &mut Scratch,
        out: MatViewMut,
    ) {
        self.inner.map_rows_into(x, positions, out);
    }

    fn map_k_rows_into(
        &self,
        x: MatView,
        positions: &[usize],
        _scratch: &mut Scratch,
        out: MatViewMut,
    ) {
        self.inner.map_rows_into(x, positions, out);
    }

    fn positive(&self) -> bool {
        self.positive
    }
}

/// Count-sketch fusion of the per-node tensor product (the operator `S` of
/// Eq. 10): `S(a ⊗ b) = IFFT(FFT(CS₁ a) · FFT(CS₂ b))`.
struct SketchFuser {
    d_t: usize,
    h1: Vec<usize>,
    s1: Vec<f32>,
    h2: Vec<usize>,
    s2: Vec<f32>,
}

impl SketchFuser {
    fn new(d_t: usize, d_a: usize, d_b: usize, rng: &mut Rng) -> Self {
        SketchFuser {
            d_t,
            h1: (0..d_a).map(|_| rng.below(d_t)).collect(),
            s1: rng.rademacher_vec(d_a),
            h2: (0..d_b).map(|_| rng.below(d_t)).collect(),
            s2: rng.rademacher_vec(d_b),
        }
    }

    fn fuse(&self, a: &[f32], b: &[f32], out: &mut [f32], scale: f32) {
        let mut ca = vec![0.0f64; self.d_t];
        for (i, &v) in a.iter().enumerate() {
            ca[self.h1[i]] += (self.s1[i] * v) as f64;
        }
        let mut cb = vec![0.0f64; self.d_t];
        for (i, &v) in b.iter().enumerate() {
            cb[self.h2[i]] += (self.s2[i] * v) as f64;
        }
        let conv = circular_convolve(&ca, &cb);
        for (o, v) in out.iter_mut().zip(conv.iter()) {
            *o = *v as f32 * scale;
        }
    }
}

/// One quadrature node's machinery.
struct Node {
    /// `s_r` (scaled Gauss–Laguerre node) — kept for diagnostics even
    /// though the Prf owns the working copy.
    #[allow(dead_code)]
    s: f64,
    /// `√w_r` folded into the features (so inner products carry `w_r`).
    sqrt_w: f32,
    prf: Prf,
    sketch: Option<SketchFuser>,
}

/// The full SLAY feature pipeline Ψ (Algorithm 1, lines 1–7).
pub struct SlayFeatures {
    pub cfg: SlayConfig,
    d: usize,
    poly: Box<dyn FeatureMap>,
    nodes: Vec<Node>,
    dim: usize,
    per_node: usize,
}

impl SlayFeatures {
    pub fn new(cfg: SlayConfig, d: usize) -> anyhow::Result<Self> {
        cfg.validate()?;
        let quad = GaussLaguerre::scaled(cfg.r_nodes, cfg.c());
        let poly = build_poly(cfg.poly, cfg.n_poly, d, cfg.nystrom_ridge, cfg.seed);
        let d_p = poly.dim();
        let mut rng = Rng::new(cfg.seed ^ 0x51AE_FEA7);
        let per_node = match cfg.fusion {
            Fusion::Explicit => d_p * cfg.d_prf,
            Fusion::Sketch { d_t } => d_t,
            Fusion::Hadamard => d_p,
            Fusion::LaplaceOnly => cfg.d_prf,
        };
        let mut nodes = Vec::with_capacity(cfg.r_nodes);
        for r in 0..cfg.r_nodes {
            let mut node_rng = rng.fork(r as u64 + 1);
            let prf = Prf::new(cfg.d_prf, d, quad.nodes[r], &mut node_rng);
            let sketch = match cfg.fusion {
                Fusion::Sketch { d_t } => {
                    Some(SketchFuser::new(d_t, d_p, cfg.d_prf, &mut node_rng))
                }
                _ => None,
            };
            nodes.push(Node {
                s: quad.nodes[r],
                sqrt_w: (quad.weights[r]).sqrt() as f32,
                prf,
                sketch,
            });
        }
        let dim = match cfg.fusion {
            // LaplaceOnly appends the affine-correction coordinates: one
            // constant and the d normalized input coords.
            Fusion::LaplaceOnly => per_node * cfg.r_nodes + 1 + d,
            _ => per_node * cfg.r_nodes,
        };
        Ok(SlayFeatures { cfg, d, poly, nodes, dim, per_node })
    }

    /// Scalar kernel estimate `⟨Ψ(q̂), Ψ(k̂)⟩` for single rows — Fig. 13's
    /// probe. Inputs are normalized internally.
    pub fn kernel_estimate(&self, q: &[f32], k: &[f32]) -> f32 {
        let qm = self.map_q(MatView::from_row(q), 0);
        let km = self.map_k(MatView::from_row(k), 0);
        dot(qm.row(0), km.row(0))
    }

    /// Shared forward for the symmetric fusions, writing into `out` with
    /// every intermediate (normalized inputs, polynomial panel, per-node
    /// PRF panel) recycled from `scratch`.
    fn map_shared_into(&self, x: MatView, scratch: &mut Scratch, mut out: MatViewMut) {
        let l = x.rows();
        let d = self.d;
        assert_eq!(x.cols(), d, "SlayFeatures: input dim");
        let mut xn_buf = scratch.take(l * d);
        normalize_rows_into(x, &mut xn_buf);
        let xn = MatView::new(&xn_buf, l, d);
        let d_p = self.poly.dim();
        let mut poly_buf = scratch.take(l * d_p); // L × D_p
        self.poly.map_into(xn, 0, MatViewMut::new(&mut poly_buf, l, d_p));
        let d_prf = self.cfg.d_prf;
        let mut prf_buf = scratch.take(l * d_prf); // L × D, reused per node
        for (ni, node) in self.nodes.iter().enumerate() {
            node.prf.map_into(xn, 0, MatViewMut::new(&mut prf_buf, l, d_prf));
            let off = ni * self.per_node;
            match self.cfg.fusion {
                Fusion::Explicit => {
                    // §Perf iteration: fold √w_r into the (L×D) PRF factor
                    // once instead of rescaling the (L×D_p·D) fused output.
                    for v in prf_buf.iter_mut() {
                        *v *= node.sqrt_w;
                    }
                    for r in 0..l {
                        let orow = &mut out.row_mut(r)[off..off + self.per_node];
                        kron_row(
                            &poly_buf[r * d_p..(r + 1) * d_p],
                            &prf_buf[r * d_prf..(r + 1) * d_prf],
                            orow,
                        );
                    }
                }
                Fusion::Hadamard => {
                    for r in 0..l {
                        let orow = &mut out.row_mut(r)[off..off + self.per_node];
                        let prow = &poly_buf[r * d_p..(r + 1) * d_p];
                        let frow = &prf_buf[r * d_prf..(r + 1) * d_prf];
                        for (c, o) in orow.iter_mut().enumerate() {
                            *o = prow[c] * frow[c] * node.sqrt_w;
                        }
                    }
                }
                Fusion::Sketch { .. } => {
                    let fuser = node.sketch.as_ref().unwrap();
                    for r in 0..l {
                        let orow = &mut out.row_mut(r)[off..off + self.per_node];
                        fuser.fuse(
                            &poly_buf[r * d_p..(r + 1) * d_p],
                            &prf_buf[r * d_prf..(r + 1) * d_prf],
                            orow,
                            node.sqrt_w,
                        );
                    }
                }
                Fusion::LaplaceOnly => unreachable!("handled in map_laplace_into"),
            }
        }
        scratch.put(prf_buf);
        scratch.put(poly_buf);
        scratch.put(xn_buf);
    }

    /// Laplace-only features with the Appendix-F affine correction.
    /// Query:  `[√w_r·(C/2)·φ_r(q̂) …, 1,  q̂]`
    /// Key:    `[√w_r·(C/2)·φ_r(k̂) …, −C/4, −k̂/2]`
    /// so that `Ψ(q)ᵀΨ(k) = (C²/4)Σ w_r φφ − C/4 − q̂ᵀk̂/2`.
    fn map_laplace_into(
        &self,
        x: MatView,
        is_query: bool,
        scratch: &mut Scratch,
        mut out: MatViewMut,
    ) {
        let l = x.rows();
        let d = self.d;
        assert_eq!(x.cols(), d, "SlayFeatures: input dim");
        let c = self.cfg.c() as f32;
        let mut xn_buf = scratch.take(l * d);
        normalize_rows_into(x, &mut xn_buf);
        let xn = MatView::new(&xn_buf, l, d);
        let d_prf = self.cfg.d_prf;
        let mut prf_buf = scratch.take(l * d_prf);
        for (ni, node) in self.nodes.iter().enumerate() {
            node.prf.map_into(xn, 0, MatViewMut::new(&mut prf_buf, l, d_prf));
            let off = ni * self.per_node;
            let scale = node.sqrt_w * c / 2.0;
            for r in 0..l {
                let orow = &mut out.row_mut(r)[off..off + self.per_node];
                for (o, &f) in orow.iter_mut().zip(&prf_buf[r * d_prf..(r + 1) * d_prf]) {
                    *o = f * scale;
                }
            }
        }
        let base = self.per_node * self.cfg.r_nodes;
        for r in 0..l {
            let xr = &xn_buf[r * d..(r + 1) * d];
            let orow = out.row_mut(r);
            if is_query {
                orow[base] = 1.0;
                orow[base + 1..base + 1 + d].copy_from_slice(xr);
            } else {
                orow[base] = -c / 4.0;
                for (o, &v) in orow[base + 1..base + 1 + d].iter_mut().zip(xr) {
                    *o = -0.5 * v;
                }
            }
        }
        scratch.put(prf_buf);
        scratch.put(xn_buf);
    }
}

impl QKFeatures for SlayFeatures {
    fn dim(&self) -> usize {
        self.dim
    }

    fn map_q_into(&self, x: MatView, _pos0: usize, scratch: &mut Scratch, out: MatViewMut) {
        match self.cfg.fusion {
            Fusion::LaplaceOnly => self.map_laplace_into(x, true, scratch, out),
            _ => self.map_shared_into(x, scratch, out),
        }
    }

    fn map_k_into(&self, x: MatView, _pos0: usize, scratch: &mut Scratch, out: MatViewMut) {
        match self.cfg.fusion {
            Fusion::LaplaceOnly => self.map_laplace_into(x, false, scratch, out),
            _ => self.map_shared_into(x, scratch, out),
        }
    }

    // The SLAY pipeline is position-independent (the spherical constraint
    // normalizes per row; no positional reweighting), so a stacked block of
    // rows from different sequences at different positions maps as one
    // batched call — the fused decode path (ADR-005) gets the
    // one-GEMM-per-block property for free.
    fn map_q_rows_into(
        &self,
        x: MatView,
        positions: &[usize],
        scratch: &mut Scratch,
        out: MatViewMut,
    ) {
        debug_assert_eq!(x.rows(), positions.len());
        self.map_q_into(x, 0, scratch, out);
    }

    fn map_k_rows_into(
        &self,
        x: MatView,
        positions: &[usize],
        scratch: &mut Scratch,
        out: MatViewMut,
    ) {
        debug_assert_eq!(x.rows(), positions.len());
        self.map_k_into(x, 0, scratch, out);
    }

    fn positive(&self) -> bool {
        self.cfg.positivity_guaranteed()
    }
}

/// Dense (quadratic) evaluation of the discretized SLAY target kernel
/// `Σ_r w_r x² e^{2 s_r x}` — the quadrature-only baseline of Fig. 13 and
/// the "what the features estimate" reference of Remark 1.
pub fn slay_target_kernel(x: f64, cfg: &SlayConfig) -> f64 {
    let quad = GaussLaguerre::scaled(cfg.r_nodes, cfg.c());
    quad.integrate(|s| x * x * (2.0 * s * x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::quadrature::e_sph_exact;
    use crate::math::stats::Welford;

    fn unit(rng: &mut Rng, d: usize) -> Vec<f32> {
        Mat::randn(1, d, rng).normalized_rows().data
    }

    #[test]
    fn dims_match_config() {
        let d = 8;
        for fusion in [
            Fusion::Explicit,
            Fusion::Sketch { d_t: 64 },
            Fusion::LaplaceOnly,
        ] {
            let cfg = SlayConfig { fusion, ..Default::default() };
            let f = SlayFeatures::new(cfg.clone(), d).unwrap();
            let want = match fusion {
                Fusion::LaplaceOnly => cfg.feature_dim(d) + 1 + d,
                _ => cfg.feature_dim(d),
            };
            assert_eq!(f.dim(), want, "{fusion:?}");
            let x = Mat::randn(5, d, &mut Rng::new(61));
            assert_eq!(f.map_q(x.view(), 0).cols, f.dim());
            assert_eq!(f.map_k(x.view(), 0).cols, f.dim());
        }
        // Hadamard requires matching dims
        let cfg = SlayConfig {
            fusion: Fusion::Hadamard,
            n_poly: 16,
            d_prf: 16,
            ..Default::default()
        };
        let f = SlayFeatures::new(cfg, d).unwrap();
        assert_eq!(f.dim(), 3 * 16);
    }

    #[test]
    fn explicit_fusion_with_exact_poly_estimates_kernel() {
        // With the exact poly map and many PRFs, ⟨Ψ(q),Ψ(k)⟩ ≈ target
        // quadrature kernel; averaged over seeds it converges (Remark 1).
        let mut rng = Rng::new(62);
        let d = 6;
        let q = unit(&mut rng, d);
        let k = unit(&mut rng, d);
        let x = dot(&q, &k) as f64;
        let base_cfg = SlayConfig {
            poly: PolyMethod::Exact,
            d_prf: 32,
            r_nodes: 6,
            ..Default::default()
        };
        let want = slay_target_kernel(x, &base_cfg);
        let mut w = Welford::default();
        for seed in 0..80 {
            let cfg = SlayConfig { seed, ..base_cfg.clone() };
            let f = SlayFeatures::new(cfg, d).unwrap();
            w.push(f.kernel_estimate(&q, &k) as f64);
        }
        let se = w.std() / (w.n as f64).sqrt();
        assert!(
            (w.mean() - want).abs() < 4.0 * se + 0.02 * want.abs().max(0.05),
            "mean={} want={want} se={se}",
            w.mean()
        );
    }

    #[test]
    fn positive_configs_yield_positive_estimates() {
        // App. G: anchor/exact poly + explicit fusion ⇒ nonnegative scores.
        let mut rng = Rng::new(63);
        let d = 8;
        for poly in [PolyMethod::Anchor, PolyMethod::Exact] {
            let cfg = SlayConfig { poly, ..Default::default() };
            let f = SlayFeatures::new(cfg, d).unwrap();
            for _ in 0..100 {
                let q = unit(&mut rng, d);
                let k = unit(&mut rng, d);
                let est = f.kernel_estimate(&q, &k);
                assert!(est >= 0.0, "{poly:?} gave {est}");
            }
        }
    }

    #[test]
    fn laplace_only_matches_exact_kernel_closely() {
        // The App-F identity is exact up to quadrature + PRF noise; with
        // generous feature counts the estimate lands near E_sph(x).
        let mut rng = Rng::new(64);
        let d = 8;
        let eps = 0.05; // milder ε keeps quadrature convergence fast
        let cfg = SlayConfig {
            eps,
            fusion: Fusion::LaplaceOnly,
            d_prf: 256,
            r_nodes: 24,
            ..Default::default()
        };
        let mut errs = Vec::new();
        for seed in 0..10 {
            let f = SlayFeatures::new(SlayConfig { seed, ..cfg.clone() }, d).unwrap();
            let q = unit(&mut rng, d);
            let k = unit(&mut rng, d);
            let x = dot(&q, &k) as f64;
            let want = e_sph_exact(x, eps);
            errs.push((f.kernel_estimate(&q, &k) as f64 - want).abs());
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean_err < 0.15, "mean err {mean_err} ({errs:?})");
    }

    #[test]
    fn hadamard_is_biased_but_positive() {
        let mut rng = Rng::new(65);
        let d = 8;
        let cfg = SlayConfig {
            fusion: Fusion::Hadamard,
            n_poly: 16,
            d_prf: 16,
            poly: PolyMethod::Anchor,
            ..Default::default()
        };
        let f = SlayFeatures::new(cfg, d).unwrap();
        for _ in 0..50 {
            let q = unit(&mut rng, d);
            let k = unit(&mut rng, d);
            assert!(f.kernel_estimate(&q, &k) >= 0.0);
        }
    }

    #[test]
    fn sketch_fusion_unbiased_for_explicit_product() {
        // The count-sketch fusion is unbiased for the explicit tensor
        // product, so averaged over the *joint* randomness both estimators
        // share one mean. Compare seed-ensemble means of the two fusions.
        let d = 6;
        let mut rng = Rng::new(66);
        let q = unit(&mut rng, d);
        let k = unit(&mut rng, d);
        let mut w_explicit = Welford::default();
        let mut w_sketch = Welford::default();
        for s in 0..300 {
            let e = SlayFeatures::new(SlayConfig { seed: s, ..Default::default() }, d).unwrap();
            w_explicit.push(e.kernel_estimate(&q, &k) as f64);
            let cfg = SlayConfig {
                fusion: Fusion::Sketch { d_t: 128 },
                seed: s,
                ..Default::default()
            };
            let f = SlayFeatures::new(cfg, d).unwrap();
            w_sketch.push(f.kernel_estimate(&q, &k) as f64);
        }
        let se = (w_explicit.var() / w_explicit.n as f64
            + w_sketch.var() / w_sketch.n as f64)
            .sqrt();
        assert!(
            (w_sketch.mean() - w_explicit.mean()).abs() < 4.0 * se + 1e-3,
            "sketch mean {} vs explicit mean {} (se {se})",
            w_sketch.mean(),
            w_explicit.mean()
        );
    }

    #[test]
    fn features_deterministic_given_seed() {
        let d = 8;
        let cfg = SlayConfig::default();
        let f1 = SlayFeatures::new(cfg.clone(), d).unwrap();
        let f2 = SlayFeatures::new(cfg, d).unwrap();
        let x = Mat::randn(3, d, &mut Rng::new(67));
        assert_eq!(f1.map_q(x.view(), 0).data, f2.map_q(x.view(), 0).data);
    }

    #[test]
    fn normalization_is_internal() {
        // Scaling the inputs must not change the features (spherical
        // constraint, Remark 3(ii)).
        let d = 8;
        let f = SlayFeatures::new(SlayConfig::default(), d).unwrap();
        let x = Mat::randn(4, d, &mut Rng::new(68));
        let x_scaled = x.map(|v| v * 7.5);
        let a = f.map_q(x.view(), 0);
        let b = f.map_q(x_scaled.view(), 0);
        for (p, q) in a.data.iter().zip(b.data.iter()) {
            assert!((p - q).abs() < 1e-4 * (1.0 + p.abs()));
        }
    }
}
