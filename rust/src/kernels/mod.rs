//! Attention mechanisms: the paper's SLAY estimator, its exact quadratic
//! counterparts (Yat, spherical Yat, softmax), and the linear baselines
//! (FAVOR+, ELU+1, cosformer).
//!
//! # The `AttentionBackend` API
//!
//! Every mechanism is served through one session-oriented interface:
//!
//! * [`build`] / [`build_with_window`] — factory: a [`Mechanism`] spec plus
//!   a head dimension yields a boxed [`AttentionBackend`].
//! * [`AttentionBackend::forward`] / [`AttentionBackend::forward_into`] —
//!   one-shot attention over a full sequence (benches, offline eval).
//! * [`AttentionBackend::new_state`] / [`AttentionBackend::prefill_into`] /
//!   [`AttentionBackend::decode_with`] — the serving session: an opaque
//!   [`AttnState`] absorbs key/value chunks and answers queries
//!   incrementally. For linear mechanisms the state is the paper's
//!   constant-size `(S = Ψ(K)ᵀV, z = Ψ(K)ᵀ1)` streaming pair (Eq. 11),
//!   streamed through the chunkwise-parallel causal engine (ADR-003);
//!   for quadratic mechanisms it is a bounded rolling KV window, so the
//!   coordinator can serve the exact softmax/Yat baselines for
//!   apples-to-apples comparisons with SLAY. The `_into`/`_with` forms
//!   take a per-worker [`Scratch`] arena and a caller-owned output, so a
//!   warmed-up serving loop performs zero heap allocations
//!   (`tests/alloc_discipline.rs`); [`AttentionBackend::prefill`] /
//!   [`AttentionBackend::decode`] are the allocating wrappers.
//! * [`MultiHeadAttention`] — per-head backends over packed `L × d_model`
//!   tensors with std-thread fan-out across heads.
//!
//! # Views (ADR-002)
//!
//! The whole surface is strided-view based: matrix inputs are
//! [`MatView`]s, single-token decode rows are plain `&[f32]`, and
//! [`AttentionBackend::forward_into`] writes through a [`MatViewMut`].
//! Consequences the callers rely on:
//!
//! * [`MultiHeadAttention::forward`] slices head column-blocks as views and
//!   each head writes its packed output block in place — no per-head
//!   gather/scatter copies;
//! * the decode path wraps caller buffers via
//!   [`MatView::from_row`](crate::math::linalg::MatView::from_row) — no
//!   per-token `to_vec`;
//! * the serving worker maps features over per-chunk sub-views of the
//!   arrival buffers at their true sequence positions.
//!
//! The concrete backends are sealed (private to this module): consumers
//! program against the trait and never match on mechanism internals.

pub mod config;
pub mod engine;
pub mod features;
pub mod slay;
pub mod yat;

use crate::math::linalg::{dot, Mat, MatView, MatViewMut, Scratch};
use config::Mechanism;
use engine::StreamingState;
use features::prf::{CosformerMap, EluPlusOne, FavorRelu};
use slay::{QKFeatures, SlayFeatures, SymMap};

/// Default rolling-window bound for quadratic sessions when the caller did
/// not provide a horizon (see [`build`]).
pub const DEFAULT_QUADRATIC_WINDOW: usize = 4096;

/// A constructed attention operator for one head dimension.
///
/// Implementations are sealed inside this module; consumers hold a
/// `Box<dyn AttentionBackend>` from [`build`] and use the trait surface
/// only. All methods take `&self` — a backend is shared freely across
/// worker threads (`Send + Sync`), with per-sequence mutability confined
/// to the [`AttnState`] handle.
pub trait AttentionBackend: Send + Sync {
    /// The mechanism this operator implements.
    fn mechanism(&self) -> &Mechanism;

    /// Denominator stabilizer δ (Eq. 11) in effect — flows from the
    /// mechanism config (e.g. [`config::SlayConfig::delta`]), not from the
    /// caller.
    fn delta(&self) -> f32;

    /// Feature dimension m for linear mechanisms, `None` for quadratic
    /// ones.
    fn feature_dim(&self) -> Option<usize>;

    /// Fresh per-sequence session state for value dimension `d_v`.
    fn new_state(&self, d_v: usize) -> AttnState;

    /// Absorb a chunk of (Q, K, V) rows into `state`, writing the causal
    /// attention outputs for the chunk's query rows through `out`
    /// (`q.rows() × d_v`, possibly strided). Positions continue from the
    /// tokens the state has already absorbed.
    ///
    /// This is the zero-allocation serving entry (ADR-003): feature rows,
    /// block scores and projections all come from `scratch`, so once the
    /// arena is warm a steady-state prefill chunk touches the heap only
    /// for whatever the *caller* allocates (guarded by
    /// `tests/alloc_discipline.rs`). Linear mechanisms stream through the
    /// chunkwise-parallel causal engine.
    fn prefill_into(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: MatView,
        k: MatView,
        v: MatView,
        out: MatViewMut,
    ) -> anyhow::Result<()>;

    /// Allocating convenience over [`AttentionBackend::prefill_into`]
    /// (fresh scratch, owned result).
    fn prefill(
        &self,
        state: &mut AttnState,
        q: MatView,
        k: MatView,
        v: MatView,
    ) -> anyhow::Result<Mat> {
        let mut y = Mat::zeros(q.rows(), v.cols());
        self.prefill_into(&mut Scratch::new(), state, q, k, v, y.view_mut())?;
        Ok(y)
    }

    /// Single-token decode step: absorb one (k, v) row and write the
    /// attention output for `q` into `out` (`d_v` floats). The row slices
    /// are borrowed as-is, and all internals come from `scratch` — the
    /// zero-allocation decode path (ADR-003).
    fn decode_with(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()>;

    /// Allocating convenience over [`AttentionBackend::decode_with`]
    /// (fresh scratch per call).
    fn decode(
        &self,
        state: &mut AttnState,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        self.decode_with(&mut Scratch::new(), state, q, k, v, out)
    }

    /// Full attention forward writing into `out` (`q.rows() × v.cols()`,
    /// possibly a strided block of a packed tensor): `out = attend(Q, K, V)`
    /// for one head. `pos0` is the absolute position of row 0 (matters for
    /// cosformer and for streaming continuation).
    fn forward_into(
        &self,
        q: MatView,
        k: MatView,
        v: MatView,
        causal: bool,
        pos0: usize,
        out: MatViewMut,
    );

    /// Allocating convenience over [`AttentionBackend::forward_into`].
    fn forward(&self, q: MatView, k: MatView, v: MatView, causal: bool, pos0: usize) -> Mat {
        let mut y = Mat::zeros(q.rows(), v.cols());
        self.forward_into(q, k, v, causal, pos0, y.view_mut());
        y
    }

    /// Nonnegative score matrix for the quadratic path (test/diagnostic
    /// accessor; the linear path never materializes it).
    fn score_matrix(&self, q: MatView, k: MatView) -> Option<Mat>;

    /// Denominator vector `Ψ(Q)(Ψ(K)ᵀ1)` (linear) or row sums (quadratic)
    /// — the quantity whose positivity Fig. 7/8 studies.
    fn denominators(&self, q: MatView, k: MatView, causal: bool) -> Vec<f32>;

    /// Map Q/K rows (a chunk view straight off the arrival buffer) to
    /// feature rows — the diagnostic/bench accessor to the linear
    /// mechanisms' feature decomposition. `pos0` is the sequence position
    /// of row 0. Returns `None` for quadratic mechanisms. (Serving no
    /// longer needs this hook: [`AttentionBackend::prefill_into`] maps
    /// internally through the worker's scratch arena.)
    fn map_qk(&self, q: MatView, k: MatView, pos0: usize) -> Option<(Mat, Mat)>;
}

/// Build an operator for head dimension `d`. `horizon` bounds the
/// positional reweighting of cosformer and (absent a dedicated window) the
/// rolling KV window of quadratic sessions (`0` selects
/// [`DEFAULT_QUADRATIC_WINDOW`] for the window).
pub fn build(
    mech: &Mechanism,
    d: usize,
    horizon: usize,
) -> anyhow::Result<Box<dyn AttentionBackend>> {
    build_with_window(mech, d, horizon, 0)
}

/// [`build`] with the quadratic KV-window bound decoupled from `horizon`:
/// `window` caps the rolling KV window (and therefore the bytes admission
/// control must budget per quadratic sequence), while `horizon` keeps its
/// positional meaning for cosformer. `window = 0` falls back to `horizon`,
/// then to [`DEFAULT_QUADRATIC_WINDOW`].
pub fn build_with_window(
    mech: &Mechanism,
    d: usize,
    horizon: usize,
    window: usize,
) -> anyhow::Result<Box<dyn AttentionBackend>> {
    Ok(match mech {
        Mechanism::Standard | Mechanism::Yat { .. } | Mechanism::YatSpherical { .. } => {
            let window = if window != 0 {
                window
            } else if horizon != 0 {
                horizon
            } else {
                DEFAULT_QUADRATIC_WINDOW
            };
            Box::new(QuadraticBackend { mech: mech.clone(), delta: 1e-6, d, window })
        }
        Mechanism::Slay(cfg) => {
            let delta = cfg.delta;
            let feats = SlayFeatures::new(cfg.clone(), d)?;
            Box::new(LinearBackend { mech: mech.clone(), maps: Box::new(feats), delta })
        }
        Mechanism::Favor { m_features, seed } => Box::new(LinearBackend {
            mech: mech.clone(),
            maps: Box::new(SymMap {
                inner: Box::new(FavorRelu::new(*m_features, d, *seed)),
                positive: true,
            }),
            delta: 1e-6,
        }),
        Mechanism::EluLinear => Box::new(LinearBackend {
            mech: mech.clone(),
            maps: Box::new(SymMap { inner: Box::new(EluPlusOne::new(d)), positive: true }),
            delta: 1e-6,
        }),
        Mechanism::Cosformer => Box::new(LinearBackend {
            mech: mech.clone(),
            maps: Box::new(SymMap {
                inner: Box::new(CosformerMap::new(d, horizon.max(1))),
                positive: true,
            }),
            delta: 1e-6,
        }),
    })
}

/// Opaque per-sequence session state handle.
///
/// For linear mechanisms this wraps the constant-size
/// [`StreamingState`] `(S, z)`; for quadratic mechanisms it wraps a
/// bounded rolling KV window. Callers observe only token counts and
/// memory accounting — the contents are owned by the backend that
/// created the state.
pub struct AttnState {
    inner: StateInner,
}

enum StateInner {
    Linear(StreamingState),
    Window(KvWindow),
}

impl AttnState {
    /// Tokens absorbed so far.
    pub fn len(&self) -> usize {
        match &self.inner {
            StateInner::Linear(s) => s.len,
            StateInner::Window(w) => w.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently held by the state.
    pub fn bytes(&self) -> usize {
        match &self.inner {
            StateInner::Linear(s) => s.bytes(),
            StateInner::Window(w) => w.bytes(),
        }
    }

    /// Upper bound on [`AttnState::bytes`] over the state's lifetime —
    /// what admission control must budget for. Constant-size linear
    /// states report their (already-final) size; rolling windows report
    /// the fully-populated window.
    pub fn capacity_bytes(&self) -> usize {
        match &self.inner {
            StateInner::Linear(s) => s.bytes(),
            StateInner::Window(w) => w.capacity_bytes(),
        }
    }

    fn linear_mut(&mut self) -> anyhow::Result<&mut StreamingState> {
        match &mut self.inner {
            StateInner::Linear(s) => Ok(s),
            StateInner::Window(_) => {
                anyhow::bail!("state mismatch: windowed state passed to a linear backend")
            }
        }
    }

    fn window_mut(&mut self) -> anyhow::Result<&mut KvWindow> {
        match &mut self.inner {
            StateInner::Window(w) => Ok(w),
            StateInner::Linear(_) => {
                anyhow::bail!("state mismatch: linear state passed to a quadratic backend")
            }
        }
    }
}

/// Bounded rolling KV window — the quadratic-session analog of the
/// streaming `(S, z)` pair. Keeps the most recent `cap` (key, value) rows;
/// older tokens fall out of the attention span (sliding-window semantics),
/// which is exactly the memory/fidelity trade the linear state avoids.
struct KvWindow {
    d_k: usize,
    d_v: usize,
    /// Maximum retained rows.
    cap: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Rows currently stored (≤ cap).
    rows: usize,
    /// Tokens absorbed over the session lifetime.
    len: usize,
}

impl KvWindow {
    fn new(d_k: usize, d_v: usize, cap: usize) -> Self {
        KvWindow { d_k, d_v, cap: cap.max(1), k: Vec::new(), v: Vec::new(), rows: 0, len: 0 }
    }

    /// Append a token; once full, cyclically overwrite the oldest slot
    /// (O(d) per token — attention sums over the window, so slot order is
    /// irrelevant and no front-shift is needed).
    fn push(&mut self, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.d_k);
        debug_assert_eq!(v_row.len(), self.d_v);
        if self.rows < self.cap {
            self.k.extend_from_slice(k_row);
            self.v.extend_from_slice(v_row);
            self.rows += 1;
        } else {
            let slot = self.len % self.cap;
            self.k[slot * self.d_k..(slot + 1) * self.d_k].copy_from_slice(k_row);
            self.v[slot * self.d_v..(slot + 1) * self.d_v].copy_from_slice(v_row);
        }
        self.len += 1;
    }

    fn key(&self, j: usize) -> &[f32] {
        &self.k[j * self.d_k..(j + 1) * self.d_k]
    }

    fn val(&self, j: usize) -> &[f32] {
        &self.v[j * self.d_v..(j + 1) * self.d_v]
    }

    fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * std::mem::size_of::<f32>()
    }

    fn capacity_bytes(&self) -> usize {
        self.cap * (self.d_k + self.d_v) * std::mem::size_of::<f32>()
    }
}

/// Linear mechanisms: feature maps + Eq. 11 engine.
struct LinearBackend {
    mech: Mechanism,
    maps: Box<dyn QKFeatures>,
    delta: f32,
}

impl LinearBackend {
    /// Stream pre-mapped feature rows through the state with the
    /// chunkwise-parallel causal engine (ADR-003), writing outputs
    /// through `out`.
    fn stream_mapped(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        phi_q: MatView,
        phi_k: MatView,
        v: MatView,
        out: MatViewMut,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            phi_q.rows() == v.rows() && phi_q.rows() == phi_k.rows(),
            "prefill: row mismatch phi_q={} phi_k={} v={}",
            phi_q.rows(),
            phi_k.rows(),
            v.rows()
        );
        let st = state.linear_mut()?;
        anyhow::ensure!(
            phi_q.cols() == st.m && v.cols() == st.d_v,
            "prefill: state shape (m={}, d_v={}) vs features m={}, values d_v={}",
            st.m,
            st.d_v,
            phi_q.cols(),
            v.cols()
        );
        anyhow::ensure!(
            out.rows() == v.rows() && out.cols() == v.cols(),
            "prefill: out is {}x{}, need {}x{}",
            out.rows(),
            out.cols(),
            v.rows(),
            v.cols()
        );
        if self.maps.positive() {
            st.prefill_chunked_into(
                phi_q,
                phi_k,
                v,
                self.delta,
                engine::causal_block(),
                scratch,
                out,
            );
        } else {
            // Signed-feature estimators (LaplaceOnly, RM/TS polys) can
            // cancel denominators to ~0, where the chunked engine's
            // summation reorder is amplified arbitrarily through
            // 1/(den+δ) — keep the per-token reference order for them
            // (ADR-003; matches the decode path token-for-token).
            let mut out = out;
            for r in 0..v.rows() {
                st.append(phi_k.row(r), v.row(r));
                st.query_into(phi_q.row(r), self.delta, out.row_mut(r));
            }
        }
        Ok(())
    }
}

impl AttentionBackend for LinearBackend {
    fn mechanism(&self) -> &Mechanism {
        &self.mech
    }

    fn delta(&self) -> f32 {
        self.delta
    }

    fn feature_dim(&self) -> Option<usize> {
        Some(self.maps.dim())
    }

    fn new_state(&self, d_v: usize) -> AttnState {
        AttnState { inner: StateInner::Linear(StreamingState::new(self.maps.dim(), d_v)) }
    }

    fn prefill_into(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: MatView,
        k: MatView,
        v: MatView,
        out: MatViewMut,
    ) -> anyhow::Result<()> {
        let pos0 = state.len();
        let l = q.rows();
        let m = self.maps.dim();
        let mut q_buf = scratch.take(l * m);
        let mut k_buf = scratch.take(k.rows() * m);
        self.maps.map_q_into(q, pos0, scratch, MatViewMut::new(&mut q_buf, l, m));
        self.maps.map_k_into(k, pos0, scratch, MatViewMut::new(&mut k_buf, k.rows(), m));
        let res = self.stream_mapped(
            scratch,
            state,
            MatView::new(&q_buf, l, m),
            MatView::new(&k_buf, k.rows(), m),
            v,
            out,
        );
        scratch.put(k_buf);
        scratch.put(q_buf);
        res
    }

    fn decode_with(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let pos0 = state.len();
        let m = self.maps.dim();
        let mut q_buf = scratch.take(m);
        let mut k_buf = scratch.take(m);
        self.maps
            .map_q_into(MatView::from_row(q), pos0, scratch, MatViewMut::new(&mut q_buf, 1, m));
        self.maps
            .map_k_into(MatView::from_row(k), pos0, scratch, MatViewMut::new(&mut k_buf, 1, m));
        let st = state.linear_mut()?;
        anyhow::ensure!(
            v.len() == st.d_v && out.len() == st.d_v,
            "decode: d_v mismatch (state {}, v {}, out {})",
            st.d_v,
            v.len(),
            out.len()
        );
        st.append(&k_buf, v);
        st.query_into(&q_buf, self.delta, out);
        scratch.put(k_buf);
        scratch.put(q_buf);
        Ok(())
    }

    fn forward_into(
        &self,
        q: MatView,
        k: MatView,
        v: MatView,
        causal: bool,
        pos0: usize,
        out: MatViewMut,
    ) {
        let phi_q = self.maps.map_q(q, pos0);
        let phi_k = self.maps.map_k(k, pos0);
        if causal && !self.maps.positive() {
            // Same signed-feature caveat as the prefill path: keep the
            // per-token summation order (ADR-003).
            engine::linear_attention_causal_into(phi_q.view(), phi_k.view(), v, self.delta, out);
        } else {
            engine::linear_attention_into(phi_q.view(), phi_k.view(), v, causal, self.delta, out);
        }
    }

    fn score_matrix(&self, _q: MatView, _k: MatView) -> Option<Mat> {
        None
    }

    fn denominators(&self, q: MatView, k: MatView, causal: bool) -> Vec<f32> {
        let phi_q = self.maps.map_q(q, 0);
        let phi_k = self.maps.map_k(k, 0);
        if causal {
            let mut z = vec![0.0f32; phi_k.cols];
            (0..phi_q.rows)
                .map(|i| {
                    engine::colsum_into(&phi_k, i, i + 1, &mut z);
                    dot(phi_q.row(i), &z)
                })
                .collect()
        } else {
            let z = engine::colsum(&phi_k);
            (0..phi_q.rows).map(|i| dot(phi_q.row(i), &z)).collect()
        }
    }

    fn map_qk(&self, q: MatView, k: MatView, pos0: usize) -> Option<(Mat, Mat)> {
        Some((self.maps.map_q(q, pos0), self.maps.map_k(k, pos0)))
    }
}

/// Quadratic mechanisms: exact L×L scores one-shot, rolling KV window in
/// sessions.
struct QuadraticBackend {
    mech: Mechanism,
    delta: f32,
    d: usize,
    window: usize,
}

impl QuadraticBackend {
    /// Scores of one raw query row against every key currently in the
    /// window, written into a reusable buffer — the streaming counterpart
    /// of [`AttentionBackend::score_matrix`]'s rows. Softmax scores are
    /// stabilized by the window-max, which cancels in the normalization up
    /// to the δ floor.
    fn window_scores_into(&self, q: &[f32], win: &KvWindow, scores: &mut Vec<f32>) {
        scores.clear();
        match &self.mech {
            Mechanism::Standard => {
                let scale = 1.0 / (self.d as f32).sqrt();
                scores.extend((0..win.rows).map(|j| dot(q, win.key(j)) * scale));
                let mx = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                for x in scores.iter_mut() {
                    *x = (*x - mx).exp();
                }
            }
            Mechanism::Yat { eps } => {
                scores.extend((0..win.rows).map(|j| yat::e_product(q, win.key(j), *eps as f32)));
            }
            Mechanism::YatSpherical { eps } => {
                let nq = dot(q, q).sqrt().max(1e-12);
                scores.extend((0..win.rows).map(|j| {
                    let kj = win.key(j);
                    let nk = dot(kj, kj).sqrt().max(1e-12);
                    yat::e_sph(dot(q, kj) / (nq * nk), *eps as f32)
                }));
            }
            _ => unreachable!("linear mechanism in quadratic backend"),
        }
    }

    /// One streamed token: push (k, v), then attend q over the window.
    /// `scores` is the caller's reusable buffer (scratch-recycled).
    fn step(
        &self,
        win: &mut KvWindow,
        scores: &mut Vec<f32>,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) {
        win.push(k, v);
        self.window_scores_into(q, win, scores);
        out.fill(0.0);
        let mut den = 0.0f32;
        for (j, &s) in scores.iter().enumerate() {
            den += s;
            if s != 0.0 {
                crate::math::linalg::axpy(s, win.val(j), out);
            }
        }
        let inv = 1.0 / (den + self.delta);
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
}

impl AttentionBackend for QuadraticBackend {
    fn mechanism(&self) -> &Mechanism {
        &self.mech
    }

    fn delta(&self) -> f32 {
        self.delta
    }

    fn feature_dim(&self) -> Option<usize> {
        None
    }

    fn new_state(&self, d_v: usize) -> AttnState {
        AttnState { inner: StateInner::Window(KvWindow::new(self.d, d_v, self.window)) }
    }

    fn prefill_into(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: MatView,
        k: MatView,
        v: MatView,
        mut out: MatViewMut,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            q.rows() == k.rows() && k.rows() == v.rows(),
            "prefill: row mismatch q={} k={} v={}",
            q.rows(),
            k.rows(),
            v.rows()
        );
        let win = state.window_mut()?;
        anyhow::ensure!(
            q.cols() == win.d_k && v.cols() == win.d_v,
            "prefill: state shape (d_k={}, d_v={}) vs q={}, v={}",
            win.d_k,
            win.d_v,
            q.cols(),
            v.cols()
        );
        anyhow::ensure!(
            out.rows() == v.rows() && out.cols() == v.cols(),
            "prefill: out is {}x{}, need {}x{}",
            out.rows(),
            out.cols(),
            v.rows(),
            v.cols()
        );
        // Length is managed by step(); taking at the post-chunk row count
        // guarantees the capacity up front so the in-loop extends never
        // reallocate.
        let mut scores = scratch.take((win.rows + v.rows()).min(win.cap));
        for r in 0..v.rows() {
            self.step(win, &mut scores, q.row(r), k.row(r), v.row(r), out.row_mut(r));
        }
        scratch.put(scores);
        Ok(())
    }

    fn decode_with(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let win = state.window_mut()?;
        anyhow::ensure!(
            q.len() == win.d_k && v.len() == win.d_v && out.len() == win.d_v,
            "decode: state shape (d_k={}, d_v={}) vs q={}, v={}",
            win.d_k,
            win.d_v,
            q.len(),
            v.len()
        );
        let mut scores = scratch.take((win.rows + 1).min(win.cap));
        self.step(win, &mut scores, q, k, v, out);
        scratch.put(scores);
        Ok(())
    }

    fn forward_into(
        &self,
        q: MatView,
        k: MatView,
        v: MatView,
        causal: bool,
        _pos0: usize,
        out: MatViewMut,
    ) {
        // Causal softmax stabilizes each row by its visible-prefix max —
        // the same quantity the streaming session computes — so one-shot
        // and prefill/decode outputs coincide even when a future logit
        // dominates the full row.
        let scores = match (&self.mech, causal) {
            (Mechanism::Standard, true) => yat::softmax_scores_causal(q, k),
            _ => self.score_matrix(q, k).expect("quadratic scores"),
        };
        engine::quadratic_attention_into(scores.view(), v, causal, self.delta, out);
    }

    fn score_matrix(&self, q: MatView, k: MatView) -> Option<Mat> {
        Some(match &self.mech {
            Mechanism::Standard => yat::softmax_scores(q, k),
            Mechanism::Yat { eps } => yat::yat_scores(q, k, *eps as f32),
            Mechanism::YatSpherical { eps } => yat::yat_spherical_scores(q, k, *eps as f32),
            _ => unreachable!("linear mechanism in quadratic backend"),
        })
    }

    fn denominators(&self, q: MatView, k: MatView, causal: bool) -> Vec<f32> {
        // Same stabilizer the causal forward/streaming paths divide by.
        let s = match (&self.mech, causal) {
            (Mechanism::Standard, true) => yat::softmax_scores_causal(q, k),
            _ => self.score_matrix(q, k).expect("quadratic scores"),
        };
        (0..s.rows)
            .map(|i| {
                let lim = if causal { (i + 1).min(s.cols) } else { s.cols };
                s.row(i)[..lim].iter().sum()
            })
            .collect()
    }

    fn map_qk(&self, _q: MatView, _k: MatView, _pos0: usize) -> Option<(Mat, Mat)> {
        None
    }
}

/// Multi-head attention over packed `L × d_model` tensors: owns one
/// backend per head, splits columns into `heads` equal blocks, fans the
/// head computations out across std threads, and reassembles the packed
/// output. Used by the isolation benches (Fig. 2 setup: d_model 256,
/// 8 heads).
///
/// Head slicing is zero-copy in both directions (ADR-002): each head reads
/// its Q/K/V column blocks as strided [`MatView`]s of the packed inputs and
/// writes its output block in place through
/// [`AttentionBackend::forward_into`] — no gather before fan-out, no
/// reassembly pass after join.
pub struct MultiHeadAttention {
    heads: Vec<Box<dyn AttentionBackend>>,
    d_model: usize,
    d_head: usize,
}

impl MultiHeadAttention {
    /// Build `n_heads` backends of head dimension `d_model / n_heads`.
    /// Heads share the mechanism config (and therefore its feature
    /// randomness — matching the single-operator setup of Fig. 2).
    pub fn new(
        mech: &Mechanism,
        d_model: usize,
        n_heads: usize,
        horizon: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(n_heads > 0, "need at least one head");
        anyhow::ensure!(
            d_model % n_heads == 0,
            "heads ({n_heads}) must divide d_model ({d_model})"
        );
        let d_head = d_model / n_heads;
        let mut heads = Vec::with_capacity(n_heads);
        for _ in 0..n_heads {
            heads.push(build(mech, d_head, horizon)?);
        }
        Ok(MultiHeadAttention { heads, d_model, d_head })
    }

    pub fn n_heads(&self) -> usize {
        self.heads.len()
    }

    pub fn d_head(&self) -> usize {
        self.d_head
    }

    /// Per-head feature dimension (`None` for quadratic mechanisms).
    pub fn feature_dim(&self) -> Option<usize> {
        self.heads[0].feature_dim()
    }

    /// Forward over packed `L × d_model` Q/K/V: each head attends over its
    /// column-block views on its own thread and writes its column block of
    /// the packed output in place.
    pub fn forward<'a>(
        &self,
        q: impl Into<MatView<'a>>,
        k: impl Into<MatView<'a>>,
        v: impl Into<MatView<'a>>,
        causal: bool,
    ) -> anyhow::Result<Mat> {
        let (q, k, v) = (q.into(), k.into(), v.into());
        anyhow::ensure!(
            q.cols() == self.d_model && k.cols() == self.d_model && v.cols() == self.d_model,
            "packed width must be d_model={} (got q={}, k={}, v={})",
            self.d_model,
            q.cols(),
            k.cols(),
            v.cols()
        );
        anyhow::ensure!(
            q.rows() == k.rows() && k.rows() == v.rows(),
            "row mismatch q={} k={} v={}",
            q.rows(),
            k.rows(),
            v.rows()
        );
        let dh = self.d_head;
        let mut out = Mat::zeros(q.rows(), self.d_model);
        std::thread::scope(|s| {
            let mut rest = out.view_mut();
            for (h, backend) in self.heads.iter().enumerate() {
                let (block, tail) = rest.split_cols_at(dh);
                rest = tail;
                let (lo, hi) = (h * dh, (h + 1) * dh);
                let (qh, kh, vh) = (q.col_block(lo, hi), k.col_block(lo, hi), v.col_block(lo, hi));
                s.spawn(move || backend.forward_into(qh, kh, vh, causal, 0, block));
            }
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::config::{Mechanism, SlayConfig};
    use crate::math::rng::Rng;

    fn qkv(l: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(l, d, &mut rng),
            Mat::randn(l, d, &mut rng),
            Mat::randn(l, d, &mut rng),
        )
    }

    fn all_mechanisms() -> Vec<Mechanism> {
        vec![
            Mechanism::Standard,
            Mechanism::Yat { eps: 1e-3 },
            Mechanism::YatSpherical { eps: 1e-3 },
            Mechanism::Slay(SlayConfig::default()),
            Mechanism::Favor { m_features: 32, seed: 1 },
            Mechanism::EluLinear,
            Mechanism::Cosformer,
        ]
    }

    #[test]
    fn all_mechanisms_produce_finite_outputs_both_masks() {
        let (q, k, v) = qkv(24, 16, 91);
        for mech in all_mechanisms() {
            let op = build(&mech, 16, 64).unwrap();
            for causal in [false, true] {
                let y = op.forward(q.view(), k.view(), v.view(), causal, 0);
                assert_eq!((y.rows, y.cols), (24, 16), "{}", mech.name());
                assert!(
                    y.data.iter().all(|x| x.is_finite()),
                    "{} causal={causal}",
                    mech.name()
                );
            }
        }
    }

    #[test]
    fn linear_flag_agrees_with_feature_dim() {
        for mech in all_mechanisms() {
            let op = build(&mech, 16, 64).unwrap();
            assert_eq!(mech.is_linear(), op.feature_dim().is_some(), "{}", mech.name());
        }
    }

    #[test]
    fn windowed_build_decouples_window_from_horizon() {
        // The dedicated window knob sizes the rolling KV window (and its
        // admission budget) independently of the cosformer horizon.
        let narrow = build_with_window(&Mechanism::Standard, 16, 131_072, 128).unwrap();
        let st = narrow.new_state(8);
        assert_eq!(st.capacity_bytes(), 128 * (16 + 8) * 4);
        // window = 0 falls back to horizon, then to the default
        let fallback = build_with_window(&Mechanism::Standard, 16, 256, 0).unwrap();
        assert_eq!(fallback.new_state(8).capacity_bytes(), 256 * (16 + 8) * 4);
        let default = build_with_window(&Mechanism::Standard, 16, 0, 0).unwrap();
        assert_eq!(
            default.new_state(8).capacity_bytes(),
            DEFAULT_QUADRATIC_WINDOW * (16 + 8) * 4
        );
    }

    #[test]
    fn softmax_forward_equals_classic_softmax_attention() {
        // exp-scores + rowsum normalization ≡ softmax(QKᵀ/√d)V exactly.
        let (q, k, v) = qkv(10, 8, 92);
        let op = build(&Mechanism::Standard, 8, 0).unwrap();
        let y = op.forward(q.view(), k.view(), v.view(), false, 0);
        let mut scores = crate::math::linalg::matmul_a_bt(&q, &k);
        scores.scale(1.0 / (8f32).sqrt());
        crate::math::linalg::softmax_rows(&mut scores);
        let want = crate::math::linalg::matmul(&scores, &v);
        for (a, b) in y.data.iter().zip(want.data.iter()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    /// Clustered token geometry: alignments q̂ᵀk̂ spread over [-1, 1] the way
    /// trained embeddings do (iid Gaussians concentrate near 0 at d=16 and
    /// make every estimator look flat).
    fn clustered_qkv(l: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let centers = Mat::randn(4, d, &mut rng).normalized_rows();
        let mut gen = |rng: &mut Rng| {
            Mat::from_fn(l, d, |r, c| {
                let ctr = centers.row(r % 4);
                ctr[c] + 0.3 * rng.normal_f32()
            })
        };
        let q = gen(&mut rng);
        let k = gen(&mut rng);
        let v = Mat::randn(l, d, &mut rng);
        (q, k, v)
    }

    #[test]
    fn slay_error_decreases_with_feature_budget() {
        // Fig. 14's phenomenon: attention-output error vs exact spherical
        // Yat shrinks as the PRF budget grows (seed-averaged).
        let (q, k, v) = clustered_qkv(48, 16, 93);
        let exact = build(&Mechanism::YatSpherical { eps: 1e-3 }, 16, 0)
            .unwrap()
            .forward(q.view(), k.view(), v.view(), false, 0);
        let mean_err = |d_prf: usize| {
            let mut errs = Vec::new();
            for seed in 0..4 {
                let cfg = SlayConfig { n_poly: 16, d_prf, r_nodes: 2, seed, ..Default::default() };
                let y = build(&Mechanism::Slay(cfg), 16, 0)
                    .unwrap()
                    .forward(q.view(), k.view(), v.view(), false, 0);
                errs.push(crate::math::stats::rel_l2(&y.data, &exact.data));
            }
            crate::math::stats::mean(&errs)
        };
        let small = mean_err(2);
        let large = mean_err(64);
        assert!(
            large < small,
            "budget 64 should beat budget 2: {large} vs {small}"
        );
        assert!(large < 0.9, "large-budget rel-l2 {large} out of range");
        // With the exact polynomial map the estimator reaches the paper's
        // reported fidelity band (Table 6 Large: anchor 0.494).
        let cfg = SlayConfig {
            poly: crate::kernels::config::PolyMethod::Exact,
            d_prf: 64,
            r_nodes: 3,
            ..Default::default()
        };
        let y = build(&Mechanism::Slay(cfg), 16, 0)
            .unwrap()
            .forward(q.view(), k.view(), v.view(), false, 0);
        let err_exact_poly = crate::math::stats::rel_l2(&y.data, &exact.data);
        assert!(err_exact_poly < 0.6, "exact-poly rel-l2 {err_exact_poly} (paper band ≈ 0.49)");
    }

    #[test]
    fn positive_mechanisms_have_positive_denominators() {
        let (q, k, _) = qkv(32, 16, 94);
        for mech in [
            Mechanism::Slay(SlayConfig::default()),
            Mechanism::Favor { m_features: 32, seed: 2 },
            Mechanism::EluLinear,
            Mechanism::YatSpherical { eps: 1e-3 },
        ] {
            let op = build(&mech, 16, 64).unwrap();
            let dens = op.denominators(q.view(), k.view(), false);
            assert!(
                dens.iter().all(|&d| d >= -1e-6),
                "{}: min den {:?}",
                mech.name(),
                dens.iter().cloned().fold(f32::INFINITY, f32::min)
            );
        }
    }

    #[test]
    fn signed_slay_variants_can_go_negative() {
        // Fig. 7: TensorSketch / RandomMaclaurin polynomial components can
        // produce negative denominators.
        use crate::kernels::config::PolyMethod;
        let (q, k, _) = qkv(64, 16, 95);
        let mut saw_negative = false;
        for seed in 0..20 {
            let cfg = SlayConfig {
                poly: PolyMethod::RandomMaclaurin,
                n_poly: 4,
                seed,
                ..Default::default()
            };
            let op = build(&Mechanism::Slay(cfg), 16, 0).unwrap();
            if op.denominators(q.view(), k.view(), false).iter().any(|&d| d < 0.0) {
                saw_negative = true;
                break;
            }
        }
        assert!(saw_negative, "RM-poly SLAY never produced a negative denominator");
    }

    #[test]
    fn causal_denominators_match_noncausal_on_last_row() {
        let (q, k, _) = qkv(12, 8, 98);
        for mech in [Mechanism::Slay(SlayConfig::default()), Mechanism::Standard] {
            let op = build(&mech, 8, 32).unwrap();
            let causal = op.denominators(q.view(), k.view(), true);
            let full = op.denominators(q.view(), k.view(), false);
            assert_eq!(causal.len(), 12);
            let (a, b) = (causal[11], full[11]);
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{}: {a} vs {b}", mech.name());
        }
    }

    #[test]
    fn multi_head_partitions_and_reassembles() {
        let (q, k, v) = qkv(12, 32, 96);
        let mha = MultiHeadAttention::new(&Mechanism::EluLinear, 32, 4, 0).unwrap();
        let y = mha.forward(&q, &k, &v, true).unwrap();
        assert_eq!((y.rows, y.cols), (12, 32));
        // head 0 output must equal single-head forward on the column-block
        // view — and be bit-identical to the same data sliced into an owned
        // contiguous Mat (the ADR-002 contract).
        let op = build(&Mechanism::EluLinear, 8, 0).unwrap();
        let y0 = op.forward(
            q.view().col_block(0, 8),
            k.view().col_block(0, 8),
            v.view().col_block(0, 8),
            true,
            0,
        );
        let slice = |m: &Mat| m.view().col_block(0, 8).to_mat();
        let y0_owned =
            op.forward(slice(&q).view(), slice(&k).view(), slice(&v).view(), true, 0);
        assert_eq!(y0.data, y0_owned.data, "view vs owned forward must be bit-identical");
        for r in 0..12 {
            for c in 0..8 {
                assert!((y.get(r, c) - y0.get(r, c)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn multi_head_rejects_bad_shapes() {
        assert!(MultiHeadAttention::new(&Mechanism::EluLinear, 30, 4, 0).is_err());
        assert!(MultiHeadAttention::new(&Mechanism::EluLinear, 32, 0, 0).is_err());
        let mha = MultiHeadAttention::new(&Mechanism::EluLinear, 32, 4, 0).unwrap();
        let (q, k, v) = qkv(6, 16, 1);
        assert!(mha.forward(&q, &k, &v, true).is_err());
    }

    #[test]
    fn causal_outputs_ignore_future_tokens() {
        // Perturbing token j > i must not change output row i.
        let (q, k, mut v) = qkv(10, 8, 97);
        for mech in all_mechanisms() {
            let op = build(&mech, 8, 32).unwrap();
            let y1 = op.forward(q.view(), k.view(), v.view(), true, 0);
            // perturb the last value row
            for c in 0..8 {
                let x = v.get(9, c) + 10.0;
                v.set(9, c, x);
            }
            let y2 = op.forward(q.view(), k.view(), v.view(), true, 0);
            for i in 0..9 {
                for c in 0..8 {
                    assert!(
                        (y1.get(i, c) - y2.get(i, c)).abs() < 1e-5,
                        "{} row {i} leaked future info",
                        mech.name()
                    );
                }
            }
            // restore
            for c in 0..8 {
                let x = v.get(9, c) - 10.0;
                v.set(9, c, x);
            }
        }
    }

    #[test]
    fn session_prefill_then_decode_matches_one_shot_forward() {
        // The core serving contract: streaming a sequence through an
        // AttnState (prefill chunk + per-token decode) reproduces the
        // one-shot causal forward for EVERY mechanism — linear streaming
        // states and windowed-quadratic sessions alike. Prefill chunks are
        // zero-copy row-block views of the full buffers.
        let l = 14;
        let (q, k, v) = qkv(l, 8, 90);
        for mech in all_mechanisms() {
            let op = build(&mech, 8, 64).unwrap();
            let want = op.forward(q.view(), k.view(), v.view(), true, 0);
            let mut state = op.new_state(8);
            let split = 9;
            let head = op
                .prefill(
                    &mut state,
                    q.view().row_block(0, split),
                    k.view().row_block(0, split),
                    v.view().row_block(0, split),
                )
                .unwrap();
            let mut got = head.data.clone();
            let mut out = vec![0.0f32; 8];
            for i in split..l {
                op.decode(&mut state, q.row(i), k.row(i), v.row(i), &mut out).unwrap();
                got.extend_from_slice(&out);
            }
            assert_eq!(state.len(), l);
            for (i, (a, b)) in got.iter().zip(want.data.iter()).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "{} elem {i}: {a} vs {b}",
                    mech.name()
                );
            }
        }
    }

    #[test]
    fn quadratic_window_slides_and_stays_bounded() {
        let op = build(&Mechanism::YatSpherical { eps: 1e-3 }, 8, 4).unwrap();
        let mut state = op.new_state(8);
        let cap_bytes = state.capacity_bytes();
        let (q, k, v) = qkv(32, 8, 89);
        let mut out = vec![0.0f32; 8];
        for i in 0..32 {
            op.decode(&mut state, q.row(i), k.row(i), v.row(i), &mut out).unwrap();
            assert!(out.iter().all(|x| x.is_finite()));
        }
        assert_eq!(state.len(), 32);
        assert!(state.bytes() <= cap_bytes, "window grew past its bound");
        // sliding semantics: with cap 4, the output at token 31 attends the
        // last 4 tokens only — recomputing on that suffix matches.
        let suffix = op.forward(
            q.view().row_block(28, 32),
            k.view().row_block(28, 32),
            v.view().row_block(28, 32),
            true,
            0,
        );
        for c in 0..8 {
            let want = suffix.get(3, c);
            assert!((out[c] - want).abs() < 1e-4 * (1.0 + want.abs()), "{} vs {want}", out[c]);
        }
    }

    #[test]
    fn signed_feature_configs_keep_per_token_causal_order() {
        // Signed estimators (here: RM-poly SLAY) route causal attention
        // through the per-token reference order — block reordering near a
        // cancelling denominator is amplified arbitrarily through
        // 1/(den+δ), so their outputs must stay bit-identical to the
        // per-token engine (ADR-003).
        use crate::kernels::config::PolyMethod;
        let cfg = SlayConfig { poly: PolyMethod::RandomMaclaurin, n_poly: 4, ..Default::default() };
        let op = build(&Mechanism::Slay(cfg), 8, 0).unwrap();
        let (q, k, v) = qkv(10, 8, 77);
        let (phi_q, phi_k) = op.map_qk(q.view(), k.view(), 0).unwrap();
        let want = engine::linear_attention_causal(&phi_q, &phi_k, &v, op.delta());
        let got = op.forward(q.view(), k.view(), v.view(), true, 0);
        assert_eq!(got.data, want.data, "signed-feature causal path must be per-token ordered");
        // and the session prefill takes the same order
        let mut state = op.new_state(8);
        let streamed = op.prefill(&mut state, q.view(), k.view(), v.view()).unwrap();
        assert_eq!(streamed.data, want.data);
    }

    #[test]
    fn scratch_session_bit_identical_to_allocating_session() {
        // The zero-alloc entries (prefill_into / decode_with) with a
        // long-lived reused arena must reproduce the allocating wrappers
        // exactly, for linear and quadratic backends alike.
        let l = 13;
        let (q, k, v) = qkv(l, 8, 99);
        for mech in all_mechanisms() {
            let op = build(&mech, 8, 64).unwrap();
            let mut scratch = Scratch::new();
            let mut s_a = op.new_state(8);
            let mut s_b = op.new_state(8);
            let split = 9;
            let head_a = op
                .prefill(
                    &mut s_a,
                    q.view().row_block(0, split),
                    k.view().row_block(0, split),
                    v.view().row_block(0, split),
                )
                .unwrap();
            let mut head_b = Mat::zeros(split, 8);
            op.prefill_into(
                &mut scratch,
                &mut s_b,
                q.view().row_block(0, split),
                k.view().row_block(0, split),
                v.view().row_block(0, split),
                head_b.view_mut(),
            )
            .unwrap();
            assert_eq!(head_a.data, head_b.data, "{}: prefill differs", mech.name());
            let mut out_a = vec![0.0f32; 8];
            let mut out_b = vec![0.0f32; 8];
            for i in split..l {
                op.decode(&mut s_a, q.row(i), k.row(i), v.row(i), &mut out_a).unwrap();
                op.decode_with(&mut scratch, &mut s_b, q.row(i), k.row(i), v.row(i), &mut out_b)
                    .unwrap();
                assert_eq!(out_a, out_b, "{}: decode token {i} differs", mech.name());
            }
            assert_eq!(s_b.len(), l);
        }
    }

    #[test]
    fn state_kind_mismatch_is_an_error_not_a_panic() {
        let lin = build(&Mechanism::EluLinear, 8, 0).unwrap();
        let quad = build(&Mechanism::Standard, 8, 0).unwrap();
        let (q, k, v) = qkv(4, 8, 88);
        let mut wrong = quad.new_state(8);
        assert!(lin.prefill(&mut wrong, q.view(), k.view(), v.view()).is_err());
        let mut wrong2 = lin.new_state(8);
        assert!(quad.prefill(&mut wrong2, q.view(), k.view(), v.view()).is_err());
    }
}
