//! Attention mechanisms: the paper's SLAY estimator, its exact quadratic
//! counterparts (Yat, spherical Yat, softmax), and the linear baselines
//! (FAVOR+, ELU+1, cosformer). [`Attention`] is the single dispatch point
//! used by the coordinator, examples and benches.

pub mod config;
pub mod engine;
pub mod features;
pub mod slay;
pub mod yat;

use crate::math::linalg::Mat;
use config::Mechanism;
use features::prf::{CosformerMap, EluPlusOne, FavorRelu};
use slay::{QKFeatures, SlayFeatures, SymMap};

/// A constructed attention operator for one head dimension.
pub enum Attention {
    /// Quadratic mechanisms: build the L×L nonnegative score matrix.
    Quadratic {
        mech: Mechanism,
        delta: f32,
    },
    /// Linear mechanisms: feature maps + Eq. 11 engine.
    Linear {
        mech: Mechanism,
        maps: Box<dyn QKFeatures>,
        delta: f32,
    },
}

impl Attention {
    /// Build an operator for head dimension `d`. `horizon` bounds the
    /// positional reweighting of cosformer (max supported length).
    pub fn build(mech: &Mechanism, d: usize, horizon: usize) -> anyhow::Result<Attention> {
        Ok(match mech {
            Mechanism::Standard | Mechanism::Yat { .. } | Mechanism::YatSpherical { .. } => {
                Attention::Quadratic { mech: mech.clone(), delta: 1e-6 }
            }
            Mechanism::Slay(cfg) => {
                let feats = SlayFeatures::new(cfg.clone(), d)?;
                Attention::Linear { mech: mech.clone(), maps: Box::new(feats), delta: cfg.delta }
            }
            Mechanism::Favor { m_features, seed } => Attention::Linear {
                mech: mech.clone(),
                maps: Box::new(SymMap {
                    inner: Box::new(FavorRelu::new(*m_features, d, *seed)),
                    positive: true,
                }),
                delta: 1e-6,
            },
            Mechanism::EluLinear => Attention::Linear {
                mech: mech.clone(),
                maps: Box::new(SymMap { inner: Box::new(EluPlusOne::new(d)), positive: true }),
                delta: 1e-6,
            },
            Mechanism::Cosformer => Attention::Linear {
                mech: mech.clone(),
                maps: Box::new(SymMap {
                    inner: Box::new(CosformerMap::new(d, horizon.max(1))),
                    positive: true,
                }),
                delta: 1e-6,
            },
        })
    }

    /// Feature dimension m for linear mechanisms, `None` for quadratic ones.
    pub fn feature_dim(&self) -> Option<usize> {
        match self {
            Attention::Quadratic { .. } => None,
            Attention::Linear { maps, .. } => Some(maps.dim()),
        }
    }

    /// The mechanism this operator implements.
    pub fn mechanism(&self) -> &Mechanism {
        match self {
            Attention::Quadratic { mech, .. } | Attention::Linear { mech, .. } => mech,
        }
    }

    /// Nonnegative score matrix for the quadratic path (test/diagnostic
    /// accessor; the linear path never materializes it).
    pub fn score_matrix(&self, q: &Mat, k: &Mat) -> Option<Mat> {
        match self {
            Attention::Quadratic { mech, .. } => Some(match mech {
                Mechanism::Standard => yat::softmax_scores(q, k),
                Mechanism::Yat { eps } => yat::yat_scores(q, k, *eps as f32),
                Mechanism::YatSpherical { eps } => yat::yat_spherical_scores(q, k, *eps as f32),
                _ => unreachable!(),
            }),
            Attention::Linear { .. } => None,
        }
    }

    /// Full attention forward: `Y = attend(Q, K, V)` for one head.
    /// `pos0` is the absolute position of row 0 (matters for cosformer and
    /// for streaming continuation).
    pub fn forward(&self, q: &Mat, k: &Mat, v: &Mat, causal: bool, pos0: usize) -> Mat {
        match self {
            Attention::Quadratic { delta, .. } => {
                let scores = self.score_matrix(q, k).expect("quadratic scores");
                engine::quadratic_attention(&scores, v, causal, *delta)
            }
            Attention::Linear { maps, delta, .. } => {
                let phi_q = maps.map_q(q, pos0);
                let phi_k = maps.map_k(k, pos0);
                engine::linear_attention(&phi_q, &phi_k, v, causal, *delta)
            }
        }
    }

    /// Denominator vector `Ψ(Q)(Ψ(K)ᵀ1)` (linear) or row sums (quadratic) —
    /// the quantity whose positivity Fig. 7/8 studies.
    pub fn denominators(&self, q: &Mat, k: &Mat, causal: bool) -> Vec<f32> {
        match self {
            Attention::Quadratic { .. } => {
                let s = self.score_matrix(q, k).unwrap();
                (0..s.rows)
                    .map(|i| {
                        let lim = if causal { i + 1 } else { s.cols };
                        s.row(i)[..lim].iter().sum()
                    })
                    .collect()
            }
            Attention::Linear { maps, .. } => {
                let phi_q = maps.map_q(q, 0);
                let phi_k = maps.map_k(k, 0);
                let mut z = vec![0.0f32; phi_k.cols];
                for r in 0..phi_k.rows {
                    for (zi, &x) in z.iter_mut().zip(phi_k.row(r)) {
                        *zi += x;
                    }
                }
                (0..phi_q.rows)
                    .map(|i| crate::math::linalg::dot(phi_q.row(i), &z))
                    .collect()
            }
        }
    }
}

/// Multi-head attention over packed `L × d_model` tensors: splits columns
/// into `heads` equal slices, runs `op` per head, concatenates. Used by the
/// isolation benches (Fig. 2 setup: d_model 256, 8 heads).
pub fn multi_head_forward(
    op: &Attention,
    q: &Mat,
    k: &Mat,
    v: &Mat,
    heads: usize,
    causal: bool,
) -> Mat {
    assert_eq!(q.cols % heads, 0, "d_model must divide heads");
    let dh = q.cols / heads;
    let mut out = Mat::zeros(q.rows, q.cols);
    for h in 0..heads {
        let slice = |m: &Mat| {
            let mut s = Mat::zeros(m.rows, dh);
            for r in 0..m.rows {
                s.row_mut(r).copy_from_slice(&m.row(r)[h * dh..(h + 1) * dh]);
            }
            s
        };
        let (qh, kh, vh) = (slice(q), slice(k), slice(v));
        let yh = op.forward(&qh, &kh, &vh, causal, 0);
        for r in 0..out.rows {
            out.row_mut(r)[h * dh..(h + 1) * dh].copy_from_slice(yh.row(r));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::config::{Mechanism, SlayConfig};
    use crate::math::rng::Rng;

    fn qkv(l: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(l, d, &mut rng),
            Mat::randn(l, d, &mut rng),
            Mat::randn(l, d, &mut rng),
        )
    }

    fn all_mechanisms() -> Vec<Mechanism> {
        vec![
            Mechanism::Standard,
            Mechanism::Yat { eps: 1e-3 },
            Mechanism::YatSpherical { eps: 1e-3 },
            Mechanism::Slay(SlayConfig::default()),
            Mechanism::Favor { m_features: 32, seed: 1 },
            Mechanism::EluLinear,
            Mechanism::Cosformer,
        ]
    }

    #[test]
    fn all_mechanisms_produce_finite_outputs_both_masks() {
        let (q, k, v) = qkv(24, 16, 91);
        for mech in all_mechanisms() {
            let op = Attention::build(&mech, 16, 64).unwrap();
            for causal in [false, true] {
                let y = op.forward(&q, &k, &v, causal, 0);
                assert_eq!((y.rows, y.cols), (24, 16), "{}", mech.name());
                assert!(
                    y.data.iter().all(|x| x.is_finite()),
                    "{} causal={causal}",
                    mech.name()
                );
            }
        }
    }

    #[test]
    fn linear_flag_agrees_with_feature_dim() {
        for mech in all_mechanisms() {
            let op = Attention::build(&mech, 16, 64).unwrap();
            assert_eq!(mech.is_linear(), op.feature_dim().is_some(), "{}", mech.name());
        }
    }

    #[test]
    fn softmax_forward_equals_classic_softmax_attention() {
        // exp-scores + rowsum normalization ≡ softmax(QKᵀ/√d)V exactly.
        let (q, k, v) = qkv(10, 8, 92);
        let op = Attention::build(&Mechanism::Standard, 8, 0).unwrap();
        let y = op.forward(&q, &k, &v, false, 0);
        let mut scores = crate::math::linalg::matmul_a_bt(&q, &k);
        scores.scale(1.0 / (8f32).sqrt());
        crate::math::linalg::softmax_rows(&mut scores);
        let want = crate::math::linalg::matmul(&scores, &v);
        for (a, b) in y.data.iter().zip(want.data.iter()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    /// Clustered token geometry: alignments q̂ᵀk̂ spread over [-1, 1] the way
    /// trained embeddings do (iid Gaussians concentrate near 0 at d=16 and
    /// make every estimator look flat).
    fn clustered_qkv(l: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let centers = Mat::randn(4, d, &mut rng).normalized_rows();
        let mut gen = |rng: &mut Rng| {
            Mat::from_fn(l, d, |r, c| {
                let ctr = centers.row(r % 4);
                ctr[c] + 0.3 * rng.normal_f32()
            })
        };
        let q = gen(&mut rng);
        let k = gen(&mut rng);
        let v = Mat::randn(l, d, &mut rng);
        (q, k, v)
    }

    #[test]
    fn slay_error_decreases_with_feature_budget() {
        // Fig. 14's phenomenon: attention-output error vs exact spherical
        // Yat shrinks as the PRF budget grows (seed-averaged).
        let (q, k, v) = clustered_qkv(48, 16, 93);
        let exact = Attention::build(&Mechanism::YatSpherical { eps: 1e-3 }, 16, 0)
            .unwrap()
            .forward(&q, &k, &v, false, 0);
        let mean_err = |d_prf: usize| {
            let mut errs = Vec::new();
            for seed in 0..4 {
                let cfg = SlayConfig { n_poly: 16, d_prf, r_nodes: 2, seed, ..Default::default() };
                let y = Attention::build(&Mechanism::Slay(cfg), 16, 0)
                    .unwrap()
                    .forward(&q, &k, &v, false, 0);
                errs.push(crate::math::stats::rel_l2(&y.data, &exact.data));
            }
            crate::math::stats::mean(&errs)
        };
        let small = mean_err(2);
        let large = mean_err(64);
        assert!(
            large < small,
            "budget 64 should beat budget 2: {large} vs {small}"
        );
        assert!(large < 0.9, "large-budget rel-l2 {large} out of range");
        // With the exact polynomial map the estimator reaches the paper's
        // reported fidelity band (Table 6 Large: anchor 0.494).
        let cfg = SlayConfig {
            poly: crate::kernels::config::PolyMethod::Exact,
            d_prf: 64,
            r_nodes: 3,
            ..Default::default()
        };
        let y = Attention::build(&Mechanism::Slay(cfg), 16, 0)
            .unwrap()
            .forward(&q, &k, &v, false, 0);
        let err_exact_poly = crate::math::stats::rel_l2(&y.data, &exact.data);
        assert!(err_exact_poly < 0.6, "exact-poly rel-l2 {err_exact_poly} (paper band ≈ 0.49)");
    }

    #[test]
    fn positive_mechanisms_have_positive_denominators() {
        let (q, k, _) = qkv(32, 16, 94);
        for mech in [
            Mechanism::Slay(SlayConfig::default()),
            Mechanism::Favor { m_features: 32, seed: 2 },
            Mechanism::EluLinear,
            Mechanism::YatSpherical { eps: 1e-3 },
        ] {
            let op = Attention::build(&mech, 16, 64).unwrap();
            let dens = op.denominators(&q, &k, false);
            assert!(
                dens.iter().all(|&d| d >= -1e-6),
                "{}: min den {:?}",
                mech.name(),
                dens.iter().cloned().fold(f32::INFINITY, f32::min)
            );
        }
    }

    #[test]
    fn signed_slay_variants_can_go_negative() {
        // Fig. 7: TensorSketch / RandomMaclaurin polynomial components can
        // produce negative denominators.
        use crate::kernels::config::PolyMethod;
        let (q, k, _) = qkv(64, 16, 95);
        let mut saw_negative = false;
        for seed in 0..20 {
            let cfg = SlayConfig {
                poly: PolyMethod::RandomMaclaurin,
                n_poly: 4,
                seed,
                ..Default::default()
            };
            let op = Attention::build(&Mechanism::Slay(cfg), 16, 0).unwrap();
            if op.denominators(&q, &k, false).iter().any(|&d| d < 0.0) {
                saw_negative = true;
                break;
            }
        }
        assert!(saw_negative, "RM-poly SLAY never produced a negative denominator");
    }

    #[test]
    fn multi_head_partitions_and_reassembles() {
        let (q, k, v) = qkv(12, 32, 96);
        let op = Attention::build(&Mechanism::EluLinear, 8, 0).unwrap();
        let y = multi_head_forward(&op, &q, &k, &v, 4, true);
        assert_eq!((y.rows, y.cols), (12, 32));
        // head 0 output must equal single-head forward on the slice
        let slice = |m: &Mat| {
            let mut s = Mat::zeros(m.rows, 8);
            for r in 0..m.rows {
                s.row_mut(r).copy_from_slice(&m.row(r)[..8]);
            }
            s
        };
        let y0 = op.forward(&slice(&q), &slice(&k), &slice(&v), true, 0);
        for r in 0..12 {
            for c in 0..8 {
                assert!((y.get(r, c) - y0.get(r, c)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn causal_outputs_ignore_future_tokens() {
        // Perturbing token j > i must not change output row i.
        let (q, k, mut v) = qkv(10, 8, 97);
        for mech in all_mechanisms() {
            let op = Attention::build(&mech, 8, 32).unwrap();
            let y1 = op.forward(&q, &k, &v, true, 0);
            // perturb the last value row
            for c in 0..8 {
                let x = v.get(9, c) + 10.0;
                v.set(9, c, x);
            }
            let y2 = op.forward(&q, &k, &v, true, 0);
            for i in 0..9 {
                for c in 0..8 {
                    assert!(
                        (y1.get(i, c) - y2.get(i, c)).abs() < 1e-5,
                        "{} row {i} leaked future info",
                        mech.name()
                    );
                }
            }
            // restore
            for c in 0..8 {
                let x = v.get(9, c) - 10.0;
                v.set(9, c, x);
            }
        }
    }
}
