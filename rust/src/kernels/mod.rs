//! Attention mechanisms: the paper's SLAY estimator, its exact quadratic
//! counterparts (Yat, spherical Yat, softmax), and the linear baselines
//! (FAVOR+, ELU+1, cosformer).
//!
//! # The `AttentionBackend` API
//!
//! Every mechanism is served through one session-oriented interface:
//!
//! * [`build`] / [`build_with_window`] — factory: a [`Mechanism`] spec plus
//!   a head dimension yields a boxed [`AttentionBackend`].
//! * [`AttentionBackend::forward`] / [`AttentionBackend::forward_into`] —
//!   one-shot attention over a full sequence (benches, offline eval).
//! * [`AttentionBackend::new_state`] / [`AttentionBackend::prefill_into`] /
//!   [`AttentionBackend::decode_with`] — the serving session: an opaque
//!   [`AttnState`] absorbs key/value chunks and answers queries
//!   incrementally. For linear mechanisms the state is the paper's
//!   constant-size `(S = Ψ(K)ᵀV, z = Ψ(K)ᵀ1)` streaming pair (Eq. 11),
//!   streamed through the chunkwise-parallel causal engine (ADR-003);
//!   for quadratic mechanisms it is a bounded rolling KV window, so the
//!   coordinator can serve the exact softmax/Yat baselines for
//!   apples-to-apples comparisons with SLAY. The `_into`/`_with` forms
//!   take a per-worker [`Scratch`] arena and a caller-owned output, so a
//!   warmed-up serving loop performs zero heap allocations
//!   (`tests/alloc_discipline.rs`); [`AttentionBackend::prefill`] /
//!   [`AttentionBackend::decode`] are the allocating wrappers.
//! * [`AttentionBackend::decode_batch_with`] — the fused cross-session
//!   decode step (ADR-005): B queued decode tokens from B different
//!   sequences at B different positions advance in one call — linear
//!   backends map the stacked block's features as one GEMM, quadratic
//!   backends fan the per-sequence window dots across threads —
//!   bit-identical to the sequential per-sequence loop (which is the
//!   provided default every backend starts from).
//! * [`MultiHeadAttention`] — per-head backends over packed `L × d_model`
//!   tensors with std-thread fan-out across heads.
//! * [`AttentionBackend::save_state`] / [`AttentionBackend::load_state`] —
//!   durable session persistence (ADR-004): a versioned, checksummed
//!   little-endian container for [`AttnState`] — the linear `(S, z, len)`
//!   triple or the quadratic rolling window `(K, V, aux, len, window)` —
//!   that round-trips bit-identically, powering the coordinator's
//!   spill tier and snapshot/restore.
//!
//! # Views (ADR-002)
//!
//! The whole surface is strided-view based: matrix inputs are
//! [`MatView`]s, single-token decode rows are plain `&[f32]`, and
//! [`AttentionBackend::forward_into`] writes through a [`MatViewMut`].
//! Consequences the callers rely on:
//!
//! * [`MultiHeadAttention::forward`] slices head column-blocks as views and
//!   each head writes its packed output block in place — no per-head
//!   gather/scatter copies;
//! * the decode path wraps caller buffers via
//!   [`MatView::from_row`](crate::math::linalg::MatView::from_row) — no
//!   per-token `to_vec`;
//! * the serving worker maps features over per-chunk sub-views of the
//!   arrival buffers at their true sequence positions.
//!
//! The concrete backends are sealed (private to this module): consumers
//! program against the trait and never match on mechanism internals.

pub mod config;
pub mod engine;
pub mod features;
pub mod slay;
pub mod yat;

use crate::math::linalg::{dot, num_threads, sq_dist, Mat, MatView, MatViewMut, Scratch, PAR_FLOPS};
use config::Mechanism;
use engine::StreamingState;
use features::prf::{CosformerMap, EluPlusOne, FavorRelu};
use slay::{QKFeatures, SlayFeatures, SymMap};
use std::io::{Read, Write};
use std::sync::Arc;

/// Default rolling-window bound for quadratic sessions when the caller did
/// not provide a horizon (see [`build`]).
pub const DEFAULT_QUADRATIC_WINDOW: usize = 4096;

/// A constructed attention operator for one head dimension.
///
/// Implementations are sealed inside this module; consumers hold a
/// `Box<dyn AttentionBackend>` from [`build`] and use the trait surface
/// only. All methods take `&self` — a backend is shared freely across
/// worker threads (`Send + Sync`), with per-sequence mutability confined
/// to the [`AttnState`] handle.
pub trait AttentionBackend: Send + Sync {
    /// The mechanism this operator implements.
    fn mechanism(&self) -> &Mechanism;

    /// Denominator stabilizer δ (Eq. 11) in effect — flows from the
    /// mechanism config (e.g. [`config::SlayConfig::delta`]), not from the
    /// caller.
    fn delta(&self) -> f32;

    /// Feature dimension m for linear mechanisms, `None` for quadratic
    /// ones.
    fn feature_dim(&self) -> Option<usize>;

    /// Fresh per-sequence session state for value dimension `d_v`.
    fn new_state(&self, d_v: usize) -> AttnState;

    /// Absorb a chunk of (Q, K, V) rows into `state`, writing the causal
    /// attention outputs for the chunk's query rows through `out`
    /// (`q.rows() × d_v`, possibly strided). Positions continue from the
    /// tokens the state has already absorbed.
    ///
    /// This is the zero-allocation serving entry (ADR-003): feature rows,
    /// block scores and projections all come from `scratch`, so once the
    /// arena is warm a steady-state prefill chunk touches the heap only
    /// for whatever the *caller* allocates (guarded by
    /// `tests/alloc_discipline.rs`). Linear mechanisms stream through the
    /// chunkwise-parallel causal engine.
    fn prefill_into(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: MatView,
        k: MatView,
        v: MatView,
        out: MatViewMut,
    ) -> anyhow::Result<()>;

    /// Allocating convenience over [`AttentionBackend::prefill_into`]
    /// (fresh scratch, owned result).
    fn prefill(
        &self,
        state: &mut AttnState,
        q: MatView,
        k: MatView,
        v: MatView,
    ) -> anyhow::Result<Mat> {
        let mut y = Mat::zeros(q.rows(), v.cols());
        self.prefill_into(&mut Scratch::new(), state, q, k, v, y.view_mut())?;
        Ok(y)
    }

    /// Single-token decode step: absorb one (k, v) row and write the
    /// attention output for `q` into `out` (`d_v` floats). The row slices
    /// are borrowed as-is, and all internals come from `scratch` — the
    /// zero-allocation decode path (ADR-003).
    fn decode_with(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()>;

    /// Allocating convenience over [`AttentionBackend::decode_with`]
    /// (fresh scratch per call).
    fn decode(
        &self,
        state: &mut AttnState,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        self.decode_with(&mut Scratch::new(), state, q, k, v, out)
    }

    /// Fused cross-session batched decode step (ADR-005): one call
    /// advances `B` *different* sequences by one token each — `states[i]`
    /// absorbs row `i` of the stacked `k`/`v` blocks and answers row `i`
    /// of `q`, writing its `d_v` outputs into row `i` of `out`. The `&mut`
    /// borrows make the states mutually distinct by construction (the
    /// coordinator obtains them through
    /// [`SequenceStore::get_many_mut`](crate::coordinator::state::SequenceStore::get_many_mut)),
    /// and each sequence sees exactly the per-token order
    /// [`AttentionBackend::decode_with`] would have given it, so the fused
    /// step is bit-identical to the sequential loop — including for the
    /// signed-feature configs of ADR-003, whose ordering caveat concerns
    /// summation order *within* one sequence, which fusion never touches.
    ///
    /// This provided default IS the sequential loop, so every backend is
    /// correct out of the box. The linear backend overrides it to map the
    /// whole stacked block's features in one batched call at per-row
    /// sequence positions (B matvecs → one GEMM + B cheap state ops);
    /// the quadratic backend fans the per-sequence window dots across the
    /// shared engine thread budget. Overriding implementations must
    /// validate the ENTIRE block before mutating any state — the worker's
    /// per-item fall-back relies on a rejected block leaving every
    /// sequence untouched. (The provided default loop stops at the first
    /// failing row instead; rows before it have already advanced.)
    fn decode_batch_with(
        &self,
        scratch: &mut Scratch,
        states: &mut [&mut AttnState],
        q: MatView,
        k: MatView,
        v: MatView,
        mut out: MatViewMut,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            states.len() == q.rows() && k.rows() == q.rows() && v.rows() == q.rows(),
            "decode_batch: row mismatch states={} q={} k={} v={}",
            states.len(),
            q.rows(),
            k.rows(),
            v.rows()
        );
        anyhow::ensure!(
            out.rows() == q.rows() && out.cols() == v.cols(),
            "decode_batch: out is {}x{}, need {}x{}",
            out.rows(),
            out.cols(),
            q.rows(),
            v.cols()
        );
        for (i, state) in states.iter_mut().enumerate() {
            self.decode_with(scratch, state, q.row(i), k.row(i), v.row(i), out.row_mut(i))?;
        }
        Ok(())
    }

    /// Full attention forward writing into `out` (`q.rows() × v.cols()`,
    /// possibly a strided block of a packed tensor): `out = attend(Q, K, V)`
    /// for one head. `pos0` is the absolute position of row 0 (matters for
    /// cosformer and for streaming continuation).
    fn forward_into(
        &self,
        q: MatView,
        k: MatView,
        v: MatView,
        causal: bool,
        pos0: usize,
        out: MatViewMut,
    );

    /// Allocating convenience over [`AttentionBackend::forward_into`].
    fn forward(&self, q: MatView, k: MatView, v: MatView, causal: bool, pos0: usize) -> Mat {
        let mut y = Mat::zeros(q.rows(), v.cols());
        self.forward_into(q, k, v, causal, pos0, y.view_mut());
        y
    }

    /// Nonnegative score matrix for the quadratic path (test/diagnostic
    /// accessor; the linear path never materializes it).
    fn score_matrix(&self, q: MatView, k: MatView) -> Option<Mat>;

    /// Denominator vector `Ψ(Q)(Ψ(K)ᵀ1)` (linear) or row sums (quadratic)
    /// — the quantity whose positivity Fig. 7/8 studies.
    fn denominators(&self, q: MatView, k: MatView, causal: bool) -> Vec<f32>;

    /// Map Q/K rows (a chunk view straight off the arrival buffer) to
    /// feature rows — the diagnostic/bench accessor to the linear
    /// mechanisms' feature decomposition. `pos0` is the sequence position
    /// of row 0. Returns `None` for quadratic mechanisms. (Serving no
    /// longer needs this hook: [`AttentionBackend::prefill_into`] maps
    /// internally through the worker's scratch arena.)
    fn map_qk(&self, q: MatView, k: MatView, pos0: usize) -> Option<(Mat, Mat)>;

    /// Check that `state` belongs to *this* backend: the mechanism
    /// identity tag stamped at `new_state` (FNV of the canonical registry
    /// spec, so even same-shape mechanisms are separated) plus the shape
    /// invariants (feature dim for linear mechanisms; key dim, window
    /// capacity and aux-cache layout for quadratic ones). A serialized
    /// state can never be resumed under the wrong operator —
    /// [`AttentionBackend::save_state`] / [`AttentionBackend::load_state`]
    /// call this on every boundary crossing.
    fn validate_state(&self, state: &AttnState) -> anyhow::Result<()>;

    /// Serialize `state` into the versioned session-state container
    /// (ADR-004; see [`AttnState::encode`] for the byte layout). The
    /// serving tiers built on this — the store's disk spill and the
    /// coordinator snapshot — rely on the container round-tripping
    /// bit-identically through [`AttentionBackend::load_state`].
    fn save_state(&self, state: &AttnState, w: &mut dyn Write) -> anyhow::Result<()> {
        self.validate_state(state)?;
        state.encode(w)
    }

    /// Inverse of [`AttentionBackend::save_state`]: decode one state from
    /// `r` (verifying magic/version/checksum) and validate it against this
    /// backend before handing it back.
    fn load_state(&self, r: &mut dyn Read) -> anyhow::Result<AttnState> {
        let state = AttnState::decode(r)?;
        self.validate_state(&state)?;
        Ok(state)
    }

    /// Clone `state` for session branching (ADR-006), after re-checking
    /// that it belongs to this backend — the fork analog of the
    /// save/load boundary validation. Linear states copy `(S, z)`
    /// outright; quadratic windows fork copy-on-write (see
    /// [`AttnState::fork`]).
    fn fork_state(&self, state: &AttnState) -> anyhow::Result<AttnState> {
        self.validate_state(state)?;
        Ok(state.fork())
    }
}

/// Build an operator for head dimension `d`. `horizon` bounds the
/// positional reweighting of cosformer and (absent a dedicated window) the
/// rolling KV window of quadratic sessions (`0` selects
/// [`DEFAULT_QUADRATIC_WINDOW`] for the window).
pub fn build(
    mech: &Mechanism,
    d: usize,
    horizon: usize,
) -> anyhow::Result<Box<dyn AttentionBackend>> {
    build_with_window(mech, d, horizon, 0)
}

/// [`build`] with the quadratic KV-window bound decoupled from `horizon`:
/// `window` caps the rolling KV window (and therefore the bytes admission
/// control must budget per quadratic sequence), while `horizon` keeps its
/// positional meaning for cosformer. `window = 0` falls back to `horizon`,
/// then to [`DEFAULT_QUADRATIC_WINDOW`].
pub fn build_with_window(
    mech: &Mechanism,
    d: usize,
    horizon: usize,
    window: usize,
) -> anyhow::Result<Box<dyn AttentionBackend>> {
    let tag = state_mech_tag(mech);
    Ok(match mech {
        Mechanism::Standard | Mechanism::Yat { .. } | Mechanism::YatSpherical { .. } => {
            let window = if window != 0 {
                window
            } else if horizon != 0 {
                horizon
            } else {
                DEFAULT_QUADRATIC_WINDOW
            };
            Box::new(QuadraticBackend { mech: mech.clone(), delta: 1e-6, d, window, tag })
        }
        Mechanism::Slay(cfg) => {
            let delta = cfg.delta;
            let feats = SlayFeatures::new(cfg.clone(), d)?;
            Box::new(LinearBackend { mech: mech.clone(), maps: Box::new(feats), delta, tag })
        }
        Mechanism::Favor { m_features, seed } => Box::new(LinearBackend {
            mech: mech.clone(),
            maps: Box::new(SymMap {
                inner: Box::new(FavorRelu::new(*m_features, d, *seed)),
                positive: true,
            }),
            delta: 1e-6,
            tag,
        }),
        Mechanism::EluLinear => Box::new(LinearBackend {
            mech: mech.clone(),
            maps: Box::new(SymMap { inner: Box::new(EluPlusOne::new(d)), positive: true }),
            delta: 1e-6,
            tag,
        }),
        Mechanism::Cosformer => Box::new(LinearBackend {
            mech: mech.clone(),
            maps: Box::new(SymMap {
                inner: Box::new(CosformerMap::new(d, horizon.max(1))),
                positive: true,
            }),
            delta: 1e-6,
            tag,
        }),
    })
}

/// Opaque per-sequence session state handle.
///
/// For linear mechanisms this wraps the constant-size
/// [`StreamingState`] `(S, z)`; for quadratic mechanisms it wraps a
/// bounded rolling KV window. Callers observe only token counts and
/// memory accounting — the contents are owned by the backend that
/// created the state.
pub struct AttnState {
    inner: StateInner,
    /// FNV-1a of the creating mechanism's canonical registry spec —
    /// serialized with the state and re-checked at load, so a state can
    /// never resume under a different operator even when the shapes
    /// coincide (e.g. two windowed mechanisms with equal d_k/window).
    mech_tag: u64,
}

enum StateInner {
    Linear(StreamingState),
    Window(KvWindow),
}

impl AttnState {
    /// Tokens absorbed so far.
    pub fn len(&self) -> usize {
        match &self.inner {
            StateInner::Linear(s) => s.len,
            StateInner::Window(w) => w.len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Bytes currently held by the state.
    pub fn bytes(&self) -> usize {
        match &self.inner {
            StateInner::Linear(s) => s.bytes(),
            StateInner::Window(w) => w.bytes(),
        }
    }

    /// Upper bound on [`AttnState::bytes`] over the state's lifetime —
    /// what admission control must budget for. Constant-size linear
    /// states report their (already-final) size; rolling windows report
    /// the fully-populated window.
    pub fn capacity_bytes(&self) -> usize {
        match &self.inner {
            StateInner::Linear(s) => s.bytes(),
            StateInner::Window(w) => w.capacity_bytes(),
        }
    }

    /// Independent copy of this session state for branching (ADR-006).
    ///
    /// Linear states copy the constant-size `(S, z)` pair outright —
    /// O(m·d_v) regardless of how many tokens the session absorbed.
    /// Quadratic window states share their page table copy-on-write: the
    /// fork costs O(pages) refcount bumps, and either side's first write
    /// to a page copies just that page, so siblings can never observe
    /// each other's mutations. The mechanism identity tag travels with
    /// the fork; prefer [`AttentionBackend::fork_state`], which re-checks
    /// it against the serving backend.
    pub fn fork(&self) -> AttnState {
        let inner = match &self.inner {
            StateInner::Linear(s) => StateInner::Linear(s.clone()),
            StateInner::Window(w) => StateInner::Window(w.fork()),
        };
        AttnState { inner, mech_tag: self.mech_tag }
    }

    /// Mechanism identity tag stamped at creation (FNV-1a of the
    /// canonical registry spec) — lets cache tiers guard entries against
    /// mechanism/geometry mismatch without holding a backend.
    pub fn mech_tag(&self) -> u64 {
        self.mech_tag
    }

    fn linear_mut(&mut self) -> anyhow::Result<&mut StreamingState> {
        match &mut self.inner {
            StateInner::Linear(s) => Ok(s),
            StateInner::Window(_) => {
                anyhow::bail!("state mismatch: windowed state passed to a linear backend")
            }
        }
    }

    fn window_mut(&mut self) -> anyhow::Result<&mut KvWindow> {
        match &mut self.inner {
            StateInner::Window(w) => Ok(w),
            StateInner::Linear(_) => {
                anyhow::bail!("state mismatch: linear state passed to a quadratic backend")
            }
        }
    }

    /// Append the codec payload (everything the checksum covers) to `p`,
    /// little-endian.
    fn put_payload(&self, p: &mut Vec<u8>) {
        put_u64(p, self.mech_tag);
        match &self.inner {
            StateInner::Linear(s) => {
                put_u32(p, STATE_KIND_LINEAR);
                put_u32(p, s.m as u32);
                put_u32(p, s.d_v as u32);
                put_u64(p, s.len as u64);
                put_f32s(p, &s.s);
                put_f32s(p, &s.z);
            }
            StateInner::Window(w) => {
                put_u32(p, STATE_KIND_WINDOW);
                put_u32(p, w.d_k as u32);
                put_u32(p, w.d_v as u32);
                put_u32(p, w.cap as u32);
                put_u32(p, w.aux_dim as u32);
                put_u32(p, w.rows as u32);
                put_u64(p, w.len as u64);
                // Pages fill in slot order, so streaming each buffer
                // page-by-page reproduces the contiguous row-major layout
                // the pre-paging codec wrote — byte-identical on the wire.
                for pg in &w.pages {
                    put_f32s(p, &pg.k);
                }
                for pg in &w.pages {
                    put_f32s(p, &pg.v);
                }
                for pg in &w.pages {
                    put_f32s(p, &pg.aux);
                }
            }
        }
    }

    /// Serialize into one exactly-sized buffer — the versioned
    /// little-endian session-state container (ADR-004), in the spirit of
    /// the `.slayckpt` parameter container:
    ///
    /// ```text
    /// magic   b"SLAYSTAT"                              8 bytes
    /// version u32                                      4
    /// payload_len u64                                  8
    /// payload: mech_tag u64 | kind u32 (0 linear | 1 window), then
    ///   linear: m u32 | d_v u32 | len u64 | S f32×m·d_v | z f32×m
    ///   window: d_k u32 | d_v u32 | cap u32 | aux_dim u32 | rows u32 |
    ///           len u64 | K f32×rows·d_k | V f32×rows·d_v |
    ///           aux f32×rows·aux_dim
    /// checksum u64 (FNV-1a over payload)               8
    /// ```
    ///
    /// The window payload stores keys in their *serving* form (pre-scaled
    /// softmax keys, unit-normalized spherical-Yat keys) plus the per-slot
    /// aux scalars cached at push time, so a decoded state resumes
    /// bit-identically with no mechanism-specific rehydration. Mechanism
    /// and shape validation live in [`AttentionBackend::save_state`] /
    /// [`AttentionBackend::load_state`] — prefer those entries; the store's
    /// spill tier uses the raw codec only on states it already owns.
    pub fn encode_to_vec(&self) -> Vec<u8> {
        let mut v = Vec::with_capacity(self.encoded_bytes());
        v.extend_from_slice(STATE_MAGIC);
        v.extend_from_slice(&STATE_VERSION.to_le_bytes());
        let plen = (self.encoded_bytes() - 28) as u64;
        v.extend_from_slice(&plen.to_le_bytes());
        self.put_payload(&mut v);
        let hash = fnv1a64(&v[20..]);
        v.extend_from_slice(&hash.to_le_bytes());
        debug_assert_eq!(v.len(), self.encoded_bytes());
        v
    }

    /// [`AttnState::encode_to_vec`] written through an arbitrary writer.
    pub fn encode(&self, w: &mut dyn Write) -> anyhow::Result<()> {
        w.write_all(&self.encode_to_vec())?;
        Ok(())
    }

    /// Bytes [`AttnState::encode`] writes for this state right now
    /// (framing + payload) — what the spill/snapshot tiers account.
    /// Computed arithmetically from the shape (no trial serialization);
    /// pinned against the actual encoding by the codec round-trip tests.
    pub fn encoded_bytes(&self) -> usize {
        let payload = match &self.inner {
            // mech_tag 8 + kind 4 + m 4 + d_v 4 + len 8, then S and z
            StateInner::Linear(s) => 28 + 4 * (s.s.len() + s.z.len()),
            // mech_tag 8 + kind 4 + d_k/d_v/cap/aux_dim/rows 4 each +
            // len 8, then K/V/aux
            StateInner::Window(w) => 40 + 4 * w.rows * (w.d_k + w.d_v + w.aux_dim),
        };
        // magic 8 + version 4 + payload_len 8 + checksum 8
        28 + payload
    }

    /// Verify that `bytes` is one complete, checksum-valid serialized
    /// state *without* materializing it — the cheap integrity probe the
    /// spill→snapshot promotion uses (self-written files can only be
    /// corrupt, not adversarial; full shape validation happens at
    /// [`AttentionBackend::load_state`]).
    pub fn verify_encoded(bytes: &[u8]) -> anyhow::Result<()> {
        anyhow::ensure!(bytes.len() >= 28, "truncated state container");
        anyhow::ensure!(
            &bytes[..8] == STATE_MAGIC,
            "not a serialized attention state (bad magic)"
        );
        let version = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]);
        anyhow::ensure!(version == STATE_VERSION, "unsupported state version {version}");
        let plen = u64::from_le_bytes(bytes[12..20].try_into().unwrap()) as usize;
        anyhow::ensure!(plen < (1 << 34), "implausible state payload ({plen} bytes)");
        anyhow::ensure!(
            bytes.len() == 28 + plen,
            "state container length mismatch ({} bytes, framed for {})",
            bytes.len(),
            28 + plen
        );
        let payload = &bytes[20..20 + plen];
        let want = u64::from_le_bytes(bytes[20 + plen..28 + plen].try_into().unwrap());
        anyhow::ensure!(fnv1a64(payload) == want, "state checksum mismatch");
        Ok(())
    }

    /// Decode one state written by [`AttnState::encode`], verifying magic,
    /// version, payload checksum and internal shape invariants.
    pub fn decode(r: &mut dyn Read) -> anyhow::Result<AttnState> {
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == STATE_MAGIC, "not a serialized attention state (bad magic)");
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let version = u32::from_le_bytes(b4);
        anyhow::ensure!(version == STATE_VERSION, "unsupported state version {version}");
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let plen = u64::from_le_bytes(b8) as usize;
        anyhow::ensure!(plen >= 4 && plen < (1 << 34), "implausible state payload ({plen} bytes)");
        let mut payload = vec![0u8; plen];
        r.read_exact(&mut payload)?;
        r.read_exact(&mut b8)?;
        let want = u64::from_le_bytes(b8);
        let got = fnv1a64(&payload);
        anyhow::ensure!(
            got == want,
            "state checksum mismatch (corrupt spill/snapshot file): {got:#018x} != {want:#018x}"
        );
        let mut p = PayloadReader { buf: &payload, pos: 0 };
        let mech_tag = p.u64()?;
        let kind = p.u32()?;
        let inner = match kind {
            STATE_KIND_LINEAR => {
                let m = p.u32()? as usize;
                let d_v = p.u32()? as usize;
                let len = p.u64()? as usize;
                anyhow::ensure!(
                    (1..(1 << 24)).contains(&m) && (1..(1 << 24)).contains(&d_v),
                    "implausible linear state shape m={m} d_v={d_v}"
                );
                let s = p.f32s(m * d_v)?;
                let z = p.f32s(m)?;
                StateInner::Linear(StreamingState { m, d_v, s, z, len })
            }
            STATE_KIND_WINDOW => {
                let d_k = p.u32()? as usize;
                let d_v = p.u32()? as usize;
                let cap = p.u32()? as usize;
                let aux_dim = p.u32()? as usize;
                let rows = p.u32()? as usize;
                let len = p.u64()? as usize;
                anyhow::ensure!(
                    (1..(1 << 24)).contains(&d_k) && (1..(1 << 24)).contains(&d_v),
                    "implausible window shape d_k={d_k} d_v={d_v}"
                );
                anyhow::ensure!(aux_dim <= 8, "implausible aux dim {aux_dim}");
                anyhow::ensure!(
                    cap >= 1 && rows == len.min(cap),
                    "implausible window occupancy (rows={rows}, cap={cap}, len={len})"
                );
                let k = p.f32s(rows * d_k)?;
                let v = p.f32s(rows * d_v)?;
                let aux = p.f32s(rows * aux_dim)?;
                StateInner::Window(KvWindow::from_flat(
                    d_k, d_v, cap, aux_dim, &k, &v, &aux, rows, len,
                ))
            }
            other => anyhow::bail!("unknown state kind {other}"),
        };
        anyhow::ensure!(p.done(), "trailing bytes in state payload");
        Ok(AttnState { inner, mech_tag })
    }
}

// ---- session-state codec plumbing (ADR-004) -------------------------------

/// Magic prefix of a serialized [`AttnState`].
pub const STATE_MAGIC: &[u8; 8] = b"SLAYSTAT";
/// Container version of the session-state codec.
pub const STATE_VERSION: u32 = 1;

const STATE_KIND_LINEAR: u32 = 0;
const STATE_KIND_WINDOW: u32 = 1;

/// Mechanism identity tag carried by serialized states: FNV-1a of the
/// canonical registry spec ([`Mechanism`]'s `Display`), so any parameter
/// difference — feature seeds included — yields a distinct tag.
fn state_mech_tag(mech: &Mechanism) -> u64 {
    fnv1a64(mech.to_string().as_bytes())
}

/// FNV-1a 64-bit over `bytes` — the codec's dependency-free payload
/// checksum (guards spill/snapshot files against truncation and bit rot).
/// `pub(crate)`: the wire frame codec ([`crate::net::frame`]) shares this
/// primitive so both serialization tiers fail integrity checks identically.
pub(crate) fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_f32s(buf: &mut Vec<u8>, xs: &[f32]) {
    buf.reserve(xs.len() * 4);
    for &x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
}

/// Sequential little-endian reader over a checksum-verified payload slice.
struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.buf.len() - self.pos,
            "truncated state payload ({} bytes, need {} more at {})",
            self.buf.len(),
            n,
            self.pos
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> anyhow::Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32s(&mut self, n: usize) -> anyhow::Result<Vec<f32>> {
        let b = self.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("overflow"))?)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

/// Rows per copy-on-write window page (ADR-006). Small enough that the
/// write-time copy after a fork touches a bounded slab; large enough that
/// the per-row `j / PAGE_ROWS` indirection is noise next to the d-dim dot
/// products the window scores perform per row.
const PAGE_ROWS: usize = 64;

/// One fixed-span slab of window rows: up to [`PAGE_ROWS`] rows of key,
/// value and aux storage, each contiguous row-major. Pages are shared
/// between forked sessions behind an [`Arc`]; any mutation goes through
/// `Arc::make_mut`, which clones the page iff it is shared — classic
/// copy-on-write, so siblings never observe each other's writes.
#[derive(Clone)]
struct WindowPage {
    k: Vec<f32>,
    v: Vec<f32>,
    aux: Vec<f32>,
}

impl WindowPage {
    fn empty() -> Self {
        WindowPage { k: Vec::new(), v: Vec::new(), aux: Vec::new() }
    }
}

/// Bounded rolling KV window — the quadratic-session analog of the
/// streaming `(S, z)` pair. Keeps the most recent `cap` (key, value) rows;
/// older tokens fall out of the attention span (sliding-window semantics),
/// which is exactly the memory/fidelity trade the linear state avoids.
///
/// Storage is an `Arc`-shared page table ([`WindowPage`], ADR-006): a fork
/// clones only the `Vec<Arc<..>>` spine — O(pages) refcount bumps — and
/// pages are copied lazily at first write on either side. The serialized
/// form (ADR-004) is unchanged: the codec writes rows contiguously, so
/// paged and pre-paging containers are byte-identical on the wire.
struct KvWindow {
    d_k: usize,
    d_v: usize,
    /// Maximum retained rows.
    cap: usize,
    /// Per-slot derived scalars cached at push time (mechanism-defined:
    /// ‖k‖² for the raw Yat baseline; 0 for mechanisms that fold their
    /// per-key work into the stored key row itself).
    aux_dim: usize,
    /// Page `p` holds rows `p·PAGE_ROWS ..` (the last page may be
    /// partial); pages fill in slot order, so occupancy per page is
    /// derivable from `rows` alone.
    pages: Vec<Arc<WindowPage>>,
    /// Rows currently stored (≤ cap).
    rows: usize,
    /// Tokens absorbed over the session lifetime.
    len: usize,
}

impl KvWindow {
    fn new(d_k: usize, d_v: usize, cap: usize, aux_dim: usize) -> Self {
        KvWindow { d_k, d_v, cap: cap.max(1), aux_dim, pages: Vec::new(), rows: 0, len: 0 }
    }

    /// Rebuild a window from the codec's contiguous row-major buffers,
    /// chunking them into pages.
    fn from_flat(
        d_k: usize,
        d_v: usize,
        cap: usize,
        aux_dim: usize,
        k: &[f32],
        v: &[f32],
        aux: &[f32],
        rows: usize,
        len: usize,
    ) -> Self {
        let mut pages = Vec::with_capacity(rows.div_ceil(PAGE_ROWS));
        let mut p0 = 0;
        while p0 < rows {
            let p1 = (p0 + PAGE_ROWS).min(rows);
            pages.push(Arc::new(WindowPage {
                k: k[p0 * d_k..p1 * d_k].to_vec(),
                v: v[p0 * d_v..p1 * d_v].to_vec(),
                aux: aux[p0 * aux_dim..p1 * aux_dim].to_vec(),
            }));
            p0 = p1;
        }
        KvWindow { d_k, d_v, cap: cap.max(1), aux_dim, pages, rows, len }
    }

    /// Copy-on-write clone: shares every page with `self` (O(pages)
    /// refcount bumps); the first write on either side copies only the
    /// page it touches.
    fn fork(&self) -> Self {
        KvWindow {
            d_k: self.d_k,
            d_v: self.d_v,
            cap: self.cap,
            aux_dim: self.aux_dim,
            pages: self.pages.clone(),
            rows: self.rows,
            len: self.len,
        }
    }

    /// Do the page buffers agree with the declared shape? (The paged
    /// analog of the old flat-buffer length check in `validate_state`.)
    fn stored_shape_ok(&self) -> bool {
        if self.pages.len() != self.rows.div_ceil(PAGE_ROWS) {
            return false;
        }
        self.pages.iter().enumerate().all(|(i, p)| {
            let span = (self.rows - i * PAGE_ROWS).min(PAGE_ROWS);
            p.k.len() == span * self.d_k
                && p.v.len() == span * self.d_v
                && p.aux.len() == span * self.aux_dim
        })
    }

    /// Pages currently shared with a fork sibling (diagnostic for the COW
    /// tests: a freshly forked pair shares everything; writes peel pages
    /// off one by one).
    #[cfg(test)]
    fn shared_pages(&self) -> usize {
        self.pages.iter().filter(|p| Arc::strong_count(p) > 1).count()
    }

    /// Append a token; once full, cyclically overwrite the oldest slot
    /// (O(d) per token — attention sums over the window, so slot order is
    /// irrelevant and no front-shift is needed). Returns the slot written
    /// so the caller can finalize the stored key and aux scalars in place.
    fn push(&mut self, k_row: &[f32], v_row: &[f32]) -> usize {
        debug_assert_eq!(k_row.len(), self.d_k);
        debug_assert_eq!(v_row.len(), self.d_v);
        let slot = if self.rows < self.cap {
            let (pi, r) = (self.rows / PAGE_ROWS, self.rows % PAGE_ROWS);
            if r == 0 {
                self.pages.push(Arc::new(WindowPage::empty()));
            }
            let aux_dim = self.aux_dim;
            let page = Arc::make_mut(&mut self.pages[pi]);
            page.k.extend_from_slice(k_row);
            page.v.extend_from_slice(v_row);
            page.aux.resize(page.aux.len() + aux_dim, 0.0);
            self.rows += 1;
            self.rows - 1
        } else {
            let slot = self.len % self.cap;
            let (pi, r) = (slot / PAGE_ROWS, slot % PAGE_ROWS);
            let (d_k, d_v) = (self.d_k, self.d_v);
            let page = Arc::make_mut(&mut self.pages[pi]);
            page.k[r * d_k..(r + 1) * d_k].copy_from_slice(k_row);
            page.v[r * d_v..(r + 1) * d_v].copy_from_slice(v_row);
            slot
        };
        self.len += 1;
        slot
    }

    fn key(&self, j: usize) -> &[f32] {
        let (pi, r) = (j / PAGE_ROWS, j % PAGE_ROWS);
        &self.pages[pi].k[r * self.d_k..(r + 1) * self.d_k]
    }

    fn key_mut(&mut self, j: usize) -> &mut [f32] {
        let (pi, r) = (j / PAGE_ROWS, j % PAGE_ROWS);
        let d_k = self.d_k;
        let page = Arc::make_mut(&mut self.pages[pi]);
        &mut page.k[r * d_k..(r + 1) * d_k]
    }

    fn val(&self, j: usize) -> &[f32] {
        let (pi, r) = (j / PAGE_ROWS, j % PAGE_ROWS);
        &self.pages[pi].v[r * self.d_v..(r + 1) * self.d_v]
    }

    fn aux(&self, j: usize) -> &[f32] {
        let (pi, r) = (j / PAGE_ROWS, j % PAGE_ROWS);
        &self.pages[pi].aux[r * self.aux_dim..(r + 1) * self.aux_dim]
    }

    fn aux_mut(&mut self, j: usize) -> &mut [f32] {
        let (pi, r) = (j / PAGE_ROWS, j % PAGE_ROWS);
        let aux_dim = self.aux_dim;
        let page = Arc::make_mut(&mut self.pages[pi]);
        &mut page.aux[r * aux_dim..(r + 1) * aux_dim]
    }

    fn bytes(&self) -> usize {
        self.rows * (self.d_k + self.d_v + self.aux_dim) * std::mem::size_of::<f32>()
    }

    fn capacity_bytes(&self) -> usize {
        self.cap * (self.d_k + self.d_v + self.aux_dim) * std::mem::size_of::<f32>()
    }
}

/// Linear mechanisms: feature maps + Eq. 11 engine.
struct LinearBackend {
    mech: Mechanism,
    maps: Box<dyn QKFeatures>,
    delta: f32,
    /// Mechanism identity tag stamped into every state this backend
    /// creates (see [`state_mech_tag`]).
    tag: u64,
}

impl LinearBackend {
    /// Stream pre-mapped feature rows through the state with the
    /// chunkwise-parallel causal engine (ADR-003), writing outputs
    /// through `out`.
    fn stream_mapped(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        phi_q: MatView,
        phi_k: MatView,
        v: MatView,
        out: MatViewMut,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            phi_q.rows() == v.rows() && phi_q.rows() == phi_k.rows(),
            "prefill: row mismatch phi_q={} phi_k={} v={}",
            phi_q.rows(),
            phi_k.rows(),
            v.rows()
        );
        let st = state.linear_mut()?;
        anyhow::ensure!(
            phi_q.cols() == st.m && v.cols() == st.d_v,
            "prefill: state shape (m={}, d_v={}) vs features m={}, values d_v={}",
            st.m,
            st.d_v,
            phi_q.cols(),
            v.cols()
        );
        anyhow::ensure!(
            out.rows() == v.rows() && out.cols() == v.cols(),
            "prefill: out is {}x{}, need {}x{}",
            out.rows(),
            out.cols(),
            v.rows(),
            v.cols()
        );
        if self.maps.positive() {
            st.prefill_chunked_into(
                phi_q,
                phi_k,
                v,
                self.delta,
                engine::causal_block(),
                scratch,
                out,
            );
        } else {
            // Signed-feature estimators (LaplaceOnly, RM/TS polys) can
            // cancel denominators to ~0, where the chunked engine's
            // summation reorder is amplified arbitrarily through
            // 1/(den+δ) — keep the per-token reference order for them
            // (ADR-003; matches the decode path token-for-token).
            let mut out = out;
            for r in 0..v.rows() {
                st.append(phi_k.row(r), v.row(r));
                st.query_into(phi_q.row(r), self.delta, out.row_mut(r));
            }
        }
        Ok(())
    }
}

impl AttentionBackend for LinearBackend {
    fn mechanism(&self) -> &Mechanism {
        &self.mech
    }

    fn delta(&self) -> f32 {
        self.delta
    }

    fn feature_dim(&self) -> Option<usize> {
        Some(self.maps.dim())
    }

    fn new_state(&self, d_v: usize) -> AttnState {
        AttnState {
            inner: StateInner::Linear(StreamingState::new(self.maps.dim(), d_v)),
            mech_tag: self.tag,
        }
    }

    fn prefill_into(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: MatView,
        k: MatView,
        v: MatView,
        out: MatViewMut,
    ) -> anyhow::Result<()> {
        let pos0 = state.len();
        let l = q.rows();
        let m = self.maps.dim();
        let mut q_buf = scratch.take(l * m);
        let mut k_buf = scratch.take(k.rows() * m);
        self.maps.map_q_into(q, pos0, scratch, MatViewMut::new(&mut q_buf, l, m));
        self.maps.map_k_into(k, pos0, scratch, MatViewMut::new(&mut k_buf, k.rows(), m));
        let res = self.stream_mapped(
            scratch,
            state,
            MatView::new(&q_buf, l, m),
            MatView::new(&k_buf, k.rows(), m),
            v,
            out,
        );
        scratch.put(k_buf);
        scratch.put(q_buf);
        res
    }

    fn decode_with(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let pos0 = state.len();
        let m = self.maps.dim();
        let mut q_buf = scratch.take(m);
        let mut k_buf = scratch.take(m);
        self.maps
            .map_q_into(MatView::from_row(q), pos0, scratch, MatViewMut::new(&mut q_buf, 1, m));
        self.maps
            .map_k_into(MatView::from_row(k), pos0, scratch, MatViewMut::new(&mut k_buf, 1, m));
        let st = state.linear_mut()?;
        anyhow::ensure!(
            v.len() == st.d_v && out.len() == st.d_v,
            "decode: d_v mismatch (state {}, v {}, out {})",
            st.d_v,
            v.len(),
            out.len()
        );
        st.append(&k_buf, v);
        st.query_into(&q_buf, self.delta, out);
        scratch.put(k_buf);
        scratch.put(q_buf);
        Ok(())
    }

    fn decode_batch_with(
        &self,
        scratch: &mut Scratch,
        states: &mut [&mut AttnState],
        q: MatView,
        k: MatView,
        v: MatView,
        mut out: MatViewMut,
    ) -> anyhow::Result<()> {
        let b = q.rows();
        anyhow::ensure!(
            states.len() == b && k.rows() == b && v.rows() == b,
            "decode_batch: row mismatch states={} q={} k={} v={}",
            states.len(),
            b,
            k.rows(),
            v.rows()
        );
        anyhow::ensure!(
            out.rows() == b && out.cols() == v.cols(),
            "decode_batch: out is {}x{}, need {}x{}",
            out.rows(),
            out.cols(),
            b,
            v.cols()
        );
        let m = self.maps.dim();
        // Validate every state up front — the feature mapping is shared
        // across the block, so no state may be mutated until the whole
        // block is known to be well-formed — and collect each row's own
        // sequence position while at it.
        let mut pos = scratch.take_idx(b);
        for (i, state) in states.iter().enumerate() {
            match &state.inner {
                StateInner::Linear(s) => {
                    anyhow::ensure!(
                        s.m == m && s.d_v == v.cols(),
                        "decode_batch: state {i} shape (m={}, d_v={}) vs features m={m}, \
                         values d_v={}",
                        s.m,
                        s.d_v,
                        v.cols()
                    );
                }
                StateInner::Window(_) => {
                    anyhow::bail!("state mismatch: windowed state passed to a linear backend")
                }
            }
            pos[i] = state.len();
        }
        // One batched feature map over the whole stacked block, row i at
        // sequence i's own position — the B×d · d×m GEMM that replaces B
        // separate matvecs — then per-sequence state ops off the shared
        // feature rows: the rank-1 update S_i += φ(k_i)ᵀv_i and the
        // φ(q_i)·S_i read. Sequences are disjoint, so block order cannot
        // perturb any sequence's summation order — the ADR-003
        // signed-feature caveat (order WITHIN a sequence) is untouched by
        // fusion — and because every map kernel is row-independent, any
        // row-chunking of the block is bit-identical to the single-row
        // maps the sequential path runs.
        let mut q_buf = scratch.take(b * m);
        let mut k_buf = scratch.take(b * m);
        let d_v = v.cols();
        // Cross-session parallelism (the win the per-item loop can never
        // have): row-chunks of the block — feature sub-GEMMs plus their
        // sequences' state ops — fan out across the shared engine thread
        // budget when the block is worth a spawn.
        let guard = engine::FanoutGuard::register();
        let flops = b * m * (2 * q.cols() + 2 * d_v);
        let nt = (num_threads() / guard.active())
            .max(1)
            .min(b)
            .min((flops / PAR_FLOPS).max(1));
        if nt == 1 {
            self.maps
                .map_q_rows_into(q, &pos, scratch, MatViewMut::new(&mut q_buf, b, m));
            self.maps
                .map_k_rows_into(k, &pos, scratch, MatViewMut::new(&mut k_buf, b, m));
            for (i, state) in states.iter_mut().enumerate() {
                let st = state.linear_mut().expect("validated above");
                st.append(&k_buf[i * m..(i + 1) * m], v.row(i));
                st.query_into(&q_buf[i * m..(i + 1) * m], self.delta, out.row_mut(i));
            }
        } else {
            // Threaded runs allocate O(threads) bookkeeping per fan-out
            // (spawns + per-thread map intermediates), never per token —
            // the ADR-003 caveat; the zero-alloc guarantee is stated for
            // the single-threaded path above.
            let per = b.div_ceil(nt);
            let maps = &self.maps;
            let delta = self.delta;
            let pos_all: &[usize] = &pos;
            std::thread::scope(|s| {
                let mut states_rest: &mut [&mut AttnState] = states;
                let mut out_rest = out;
                let mut qb_rest: &mut [f32] = &mut q_buf;
                let mut kb_rest: &mut [f32] = &mut k_buf;
                let mut i0 = 0;
                while i0 < b {
                    let take = per.min(b - i0);
                    let (st_chunk, st_tail) = states_rest.split_at_mut(take);
                    states_rest = st_tail;
                    let (out_chunk, out_tail) = out_rest.split_rows_at(take);
                    out_rest = out_tail;
                    let (qb, qb_tail) = qb_rest.split_at_mut(take * m);
                    qb_rest = qb_tail;
                    let (kb, kb_tail) = kb_rest.split_at_mut(take * m);
                    kb_rest = kb_tail;
                    let start = i0;
                    s.spawn(move || {
                        let mut local = Scratch::new();
                        let p = &pos_all[start..start + take];
                        let q_rows = q.row_block(start, start + take);
                        let k_rows = k.row_block(start, start + take);
                        let qb_view = MatViewMut::new(&mut *qb, take, m);
                        maps.map_q_rows_into(q_rows, p, &mut local, qb_view);
                        let kb_view = MatViewMut::new(&mut *kb, take, m);
                        maps.map_k_rows_into(k_rows, p, &mut local, kb_view);
                        let mut out_chunk = out_chunk;
                        for (j, state) in st_chunk.iter_mut().enumerate() {
                            let st = state.linear_mut().expect("validated above");
                            st.append(&kb[j * m..(j + 1) * m], v.row(start + j));
                            st.query_into(&qb[j * m..(j + 1) * m], delta, out_chunk.row_mut(j));
                        }
                    });
                    i0 += take;
                }
            });
        }
        scratch.put(k_buf);
        scratch.put(q_buf);
        scratch.put_idx(pos);
        Ok(())
    }

    fn forward_into(
        &self,
        q: MatView,
        k: MatView,
        v: MatView,
        causal: bool,
        pos0: usize,
        out: MatViewMut,
    ) {
        let phi_q = self.maps.map_q(q, pos0);
        let phi_k = self.maps.map_k(k, pos0);
        if causal && !self.maps.positive() {
            // Same signed-feature caveat as the prefill path: keep the
            // per-token summation order (ADR-003).
            engine::linear_attention_causal_into(phi_q.view(), phi_k.view(), v, self.delta, out);
        } else {
            engine::linear_attention_into(phi_q.view(), phi_k.view(), v, causal, self.delta, out);
        }
    }

    fn score_matrix(&self, _q: MatView, _k: MatView) -> Option<Mat> {
        None
    }

    fn denominators(&self, q: MatView, k: MatView, causal: bool) -> Vec<f32> {
        let phi_q = self.maps.map_q(q, 0);
        let phi_k = self.maps.map_k(k, 0);
        if causal {
            let mut z = vec![0.0f32; phi_k.cols];
            (0..phi_q.rows)
                .map(|i| {
                    engine::colsum_into(&phi_k, i, i + 1, &mut z);
                    dot(phi_q.row(i), &z)
                })
                .collect()
        } else {
            let z = engine::colsum(&phi_k);
            (0..phi_q.rows).map(|i| dot(phi_q.row(i), &z)).collect()
        }
    }

    fn map_qk(&self, q: MatView, k: MatView, pos0: usize) -> Option<(Mat, Mat)> {
        Some((self.maps.map_q(q, pos0), self.maps.map_k(k, pos0)))
    }

    fn validate_state(&self, state: &AttnState) -> anyhow::Result<()> {
        anyhow::ensure!(
            state.mech_tag == self.tag,
            "state was produced by a different mechanism than '{}' (identity tag mismatch)",
            self.mech
        );
        match &state.inner {
            StateInner::Linear(s) => {
                anyhow::ensure!(
                    s.m == self.maps.dim(),
                    "state feature dim {} != backend feature dim {}",
                    s.m,
                    self.maps.dim()
                );
                anyhow::ensure!(
                    s.s.len() == s.m * s.d_v && s.z.len() == s.m,
                    "linear state buffers inconsistent with shape (m={}, d_v={})",
                    s.m,
                    s.d_v
                );
                Ok(())
            }
            StateInner::Window(_) => {
                anyhow::bail!("state mismatch: windowed state offered to a linear backend")
            }
        }
    }
}

/// Quadratic mechanisms: exact L×L scores one-shot, rolling KV window in
/// sessions.
struct QuadraticBackend {
    mech: Mechanism,
    delta: f32,
    d: usize,
    window: usize,
    /// Mechanism identity tag stamped into every state this backend
    /// creates (see [`state_mech_tag`]).
    tag: u64,
}

impl QuadraticBackend {
    /// Width of the per-slot aux cache ([`KvWindow`]): the raw Yat
    /// baseline keeps ‖k‖² so decode can expand
    /// `‖q−k‖² = ‖q‖² + ‖k‖² − 2qᵀk` without re-touching the key row;
    /// the other mechanisms fold their per-key work into the stored key.
    fn aux_dim(&self) -> usize {
        match &self.mech {
            Mechanism::Yat { .. } => 1,
            _ => 0,
        }
    }

    /// Finalize a just-pushed slot: do the key's reusable per-row work
    /// once at absorption — softmax pre-scales by 1/√d, spherical Yat
    /// normalizes onto the unit sphere, raw Yat caches ‖k‖² — so per-token
    /// scoring costs exactly one dot product per window row (the resolved
    /// ROADMAP decode-recompute item; ADR-004).
    fn prep_slot(&self, win: &mut KvWindow, slot: usize) {
        match &self.mech {
            Mechanism::Standard => {
                let scale = 1.0 / (self.d as f32).sqrt();
                for x in win.key_mut(slot) {
                    *x *= scale;
                }
            }
            Mechanism::Yat { .. } => {
                let kj = win.key(slot);
                let kk = dot(kj, kj);
                win.aux_mut(slot)[0] = kk;
            }
            Mechanism::YatSpherical { .. } => {
                let kj = win.key(slot);
                let inv = 1.0 / dot(kj, kj).sqrt().max(1e-12);
                for x in win.key_mut(slot) {
                    *x *= inv;
                }
            }
            _ => unreachable!("linear mechanism in quadratic backend"),
        }
    }

    /// Scores of one raw query row against every key currently in the
    /// window, written into a reusable buffer — the streaming counterpart
    /// of [`AttentionBackend::score_matrix`]'s rows, reading the per-slot
    /// work cached by [`QuadraticBackend::prep_slot`]. Softmax scores are
    /// stabilized by the window-max, which cancels in the normalization up
    /// to the δ floor.
    fn window_scores_into(&self, q: &[f32], win: &KvWindow, scores: &mut Vec<f32>) {
        scores.clear();
        match &self.mech {
            Mechanism::Standard => {
                // stored keys are pre-scaled by 1/√d, so the dot IS the logit
                scores.extend((0..win.rows).map(|j| dot(q, win.key(j))));
                let mx = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                (crate::math::simd::kernels().exp_affine_scale)(scores, 1.0, -mx, 1.0);
            }
            Mechanism::Yat { eps } => {
                let eps = *eps as f32;
                let qq = dot(q, q);
                scores.extend((0..win.rows).map(|j| {
                    let kj = win.key(j);
                    let kk = win.aux(j)[0];
                    let a = dot(q, kj);
                    let mut d2 = qq + kk - 2.0 * a;
                    if d2 < 1e-3 * (qq + kk) {
                        // Cancellation regime (q ≈ k): the norm expansion
                        // loses the distance to rounding right where a
                        // small ε amplifies it — recompute directly (the
                        // key row is already hot from the dot).
                        d2 = sq_dist(q, kj);
                    }
                    a * a / (d2 + eps)
                }));
            }
            Mechanism::YatSpherical { eps } => {
                // stored keys are unit-normalized; normalize q's side once
                let inv_nq = 1.0 / dot(q, q).sqrt().max(1e-12);
                scores.extend(
                    (0..win.rows).map(|j| yat::e_sph(dot(q, win.key(j)) * inv_nq, *eps as f32)),
                );
            }
            _ => unreachable!("linear mechanism in quadratic backend"),
        }
    }

    /// One streamed token: push (k, v), then attend q over the window.
    /// `scores` is the caller's reusable buffer (scratch-recycled).
    fn step(
        &self,
        win: &mut KvWindow,
        scores: &mut Vec<f32>,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) {
        let slot = win.push(k, v);
        self.prep_slot(win, slot);
        self.window_scores_into(q, win, scores);
        out.fill(0.0);
        let mut den = 0.0f32;
        for (j, &s) in scores.iter().enumerate() {
            den += s;
            if s != 0.0 {
                crate::math::linalg::axpy(s, win.val(j), out);
            }
        }
        let inv = 1.0 / (den + self.delta);
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
}

impl AttentionBackend for QuadraticBackend {
    fn mechanism(&self) -> &Mechanism {
        &self.mech
    }

    fn delta(&self) -> f32 {
        self.delta
    }

    fn feature_dim(&self) -> Option<usize> {
        None
    }

    fn new_state(&self, d_v: usize) -> AttnState {
        AttnState {
            inner: StateInner::Window(KvWindow::new(self.d, d_v, self.window, self.aux_dim())),
            mech_tag: self.tag,
        }
    }

    fn prefill_into(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: MatView,
        k: MatView,
        v: MatView,
        mut out: MatViewMut,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            q.rows() == k.rows() && k.rows() == v.rows(),
            "prefill: row mismatch q={} k={} v={}",
            q.rows(),
            k.rows(),
            v.rows()
        );
        let win = state.window_mut()?;
        anyhow::ensure!(
            q.cols() == win.d_k && v.cols() == win.d_v,
            "prefill: state shape (d_k={}, d_v={}) vs q={}, v={}",
            win.d_k,
            win.d_v,
            q.cols(),
            v.cols()
        );
        anyhow::ensure!(
            out.rows() == v.rows() && out.cols() == v.cols(),
            "prefill: out is {}x{}, need {}x{}",
            out.rows(),
            out.cols(),
            v.rows(),
            v.cols()
        );
        // Length is managed by step(); taking at the post-chunk row count
        // guarantees the capacity up front so the in-loop extends never
        // reallocate.
        let mut scores = scratch.take((win.rows + v.rows()).min(win.cap));
        for r in 0..v.rows() {
            self.step(win, &mut scores, q.row(r), k.row(r), v.row(r), out.row_mut(r));
        }
        scratch.put(scores);
        Ok(())
    }

    fn decode_with(
        &self,
        scratch: &mut Scratch,
        state: &mut AttnState,
        q: &[f32],
        k: &[f32],
        v: &[f32],
        out: &mut [f32],
    ) -> anyhow::Result<()> {
        let win = state.window_mut()?;
        anyhow::ensure!(
            q.len() == win.d_k && v.len() == win.d_v && out.len() == win.d_v,
            "decode: state shape (d_k={}, d_v={}) vs q={}, v={}",
            win.d_k,
            win.d_v,
            q.len(),
            v.len()
        );
        let mut scores = scratch.take((win.rows + 1).min(win.cap));
        self.step(win, &mut scores, q, k, v, out);
        scratch.put(scores);
        Ok(())
    }

    fn decode_batch_with(
        &self,
        scratch: &mut Scratch,
        states: &mut [&mut AttnState],
        q: MatView,
        k: MatView,
        v: MatView,
        mut out: MatViewMut,
    ) -> anyhow::Result<()> {
        let b = q.rows();
        anyhow::ensure!(
            states.len() == b && k.rows() == b && v.rows() == b,
            "decode_batch: row mismatch states={} q={} k={} v={}",
            states.len(),
            b,
            k.rows(),
            v.rows()
        );
        anyhow::ensure!(
            out.rows() == b && out.cols() == v.cols(),
            "decode_batch: out is {}x{}, need {}x{}",
            out.rows(),
            out.cols(),
            b,
            v.cols()
        );
        // Validate every window up front (no state mutated until the whole
        // block is well-formed) and size the widest score buffer any
        // sequence needs after absorbing its token; tally the dots to
        // decide whether fanning out is worth a spawn.
        let mut max_scores = 1usize;
        let mut flops = 0usize;
        for (i, state) in states.iter().enumerate() {
            match &state.inner {
                StateInner::Window(w) => {
                    anyhow::ensure!(
                        w.d_k == q.cols() && w.d_v == v.cols(),
                        "decode_batch: state {i} shape (d_k={}, d_v={}) vs q={}, v={}",
                        w.d_k,
                        w.d_v,
                        q.cols(),
                        v.cols()
                    );
                    let rows = (w.rows + 1).min(w.cap);
                    max_scores = max_scores.max(rows);
                    flops += rows * (w.d_k + w.d_v);
                }
                StateInner::Linear(_) => {
                    anyhow::bail!("state mismatch: linear state passed to a quadratic backend")
                }
            }
        }
        // Per-sequence window attention is embarrassingly parallel across
        // the block — disjoint states, disjoint output rows — so the
        // per-sequence dots fan out across the shared engine thread budget
        // (concurrent fan-outs split num_threads, like every engine path).
        let guard = engine::FanoutGuard::register();
        let nt = (num_threads() / guard.active())
            .max(1)
            .min(b)
            .min((flops / PAR_FLOPS).max(1));
        if nt == 1 {
            let mut scores = scratch.take(max_scores);
            for (i, state) in states.iter_mut().enumerate() {
                let win = state.window_mut().expect("validated above");
                self.step(win, &mut scores, q.row(i), k.row(i), v.row(i), out.row_mut(i));
            }
            scratch.put(scores);
            return Ok(());
        }
        let per = b.div_ceil(nt);
        let mut bufs: Vec<Vec<f32>> = (0..nt).map(|_| scratch.take(max_scores)).collect();
        std::thread::scope(|s| {
            let mut states_rest: &mut [&mut AttnState] = states;
            let mut out_rest = out;
            let mut buf_rest: &mut [Vec<f32>] = &mut bufs;
            let mut i0 = 0;
            while i0 < b {
                let take = per.min(b - i0);
                let (st_chunk, st_tail) = states_rest.split_at_mut(take);
                states_rest = st_tail;
                let (out_chunk, out_tail) = out_rest.split_rows_at(take);
                out_rest = out_tail;
                let (scores, buf_tail) =
                    buf_rest.split_first_mut().expect("one score buffer per thread chunk");
                buf_rest = buf_tail;
                let start = i0;
                s.spawn(move || {
                    let mut out_chunk = out_chunk;
                    for (j, state) in st_chunk.iter_mut().enumerate() {
                        let win = state.window_mut().expect("validated above");
                        self.step(
                            win,
                            scores,
                            q.row(start + j),
                            k.row(start + j),
                            v.row(start + j),
                            out_chunk.row_mut(j),
                        );
                    }
                });
                i0 += take;
            }
        });
        for buf in bufs {
            scratch.put(buf);
        }
        Ok(())
    }

    fn forward_into(
        &self,
        q: MatView,
        k: MatView,
        v: MatView,
        causal: bool,
        _pos0: usize,
        out: MatViewMut,
    ) {
        // Causal softmax stabilizes each row by its visible-prefix max —
        // the same quantity the streaming session computes — so one-shot
        // and prefill/decode outputs coincide even when a future logit
        // dominates the full row.
        let scores = match (&self.mech, causal) {
            (Mechanism::Standard, true) => yat::softmax_scores_causal(q, k),
            _ => self.score_matrix(q, k).expect("quadratic scores"),
        };
        engine::quadratic_attention_into(scores.view(), v, causal, self.delta, out);
    }

    fn score_matrix(&self, q: MatView, k: MatView) -> Option<Mat> {
        Some(match &self.mech {
            Mechanism::Standard => yat::softmax_scores(q, k),
            Mechanism::Yat { eps } => yat::yat_scores(q, k, *eps as f32),
            Mechanism::YatSpherical { eps } => yat::yat_spherical_scores(q, k, *eps as f32),
            _ => unreachable!("linear mechanism in quadratic backend"),
        })
    }

    fn denominators(&self, q: MatView, k: MatView, causal: bool) -> Vec<f32> {
        // Same stabilizer the causal forward/streaming paths divide by.
        let s = match (&self.mech, causal) {
            (Mechanism::Standard, true) => yat::softmax_scores_causal(q, k),
            _ => self.score_matrix(q, k).expect("quadratic scores"),
        };
        (0..s.rows)
            .map(|i| {
                let lim = if causal { (i + 1).min(s.cols) } else { s.cols };
                s.row(i)[..lim].iter().sum()
            })
            .collect()
    }

    fn map_qk(&self, _q: MatView, _k: MatView, _pos0: usize) -> Option<(Mat, Mat)> {
        None
    }

    fn validate_state(&self, state: &AttnState) -> anyhow::Result<()> {
        anyhow::ensure!(
            state.mech_tag == self.tag,
            "state was produced by a different mechanism than '{}' (identity tag mismatch)",
            self.mech
        );
        match &state.inner {
            StateInner::Window(w) => {
                anyhow::ensure!(
                    w.d_k == self.d,
                    "state key dim {} != backend head dim {}",
                    w.d_k,
                    self.d
                );
                anyhow::ensure!(
                    w.cap == self.window.max(1),
                    "state window capacity {} != backend window {}",
                    w.cap,
                    self.window
                );
                anyhow::ensure!(
                    w.aux_dim == self.aux_dim(),
                    "state aux layout {} != mechanism's {} (different quadratic family?)",
                    w.aux_dim,
                    self.aux_dim()
                );
                anyhow::ensure!(
                    w.stored_shape_ok(),
                    "window state page buffers inconsistent with shape"
                );
                Ok(())
            }
            StateInner::Linear(_) => {
                anyhow::bail!("state mismatch: linear state offered to a quadratic backend")
            }
        }
    }
}

/// Multi-head attention over packed `L × d_model` tensors: owns one
/// backend per head, splits columns into `heads` equal blocks, fans the
/// head computations out across std threads, and reassembles the packed
/// output. Used by the isolation benches (Fig. 2 setup: d_model 256,
/// 8 heads).
///
/// Head slicing is zero-copy in both directions (ADR-002): each head reads
/// its Q/K/V column blocks as strided [`MatView`]s of the packed inputs and
/// writes its output block in place through
/// [`AttentionBackend::forward_into`] — no gather before fan-out, no
/// reassembly pass after join.
pub struct MultiHeadAttention {
    heads: Vec<Box<dyn AttentionBackend>>,
    d_model: usize,
    d_head: usize,
}

impl MultiHeadAttention {
    /// Build `n_heads` backends of head dimension `d_model / n_heads`.
    /// Heads share the mechanism config (and therefore its feature
    /// randomness — matching the single-operator setup of Fig. 2).
    pub fn new(
        mech: &Mechanism,
        d_model: usize,
        n_heads: usize,
        horizon: usize,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(n_heads > 0, "need at least one head");
        anyhow::ensure!(
            d_model % n_heads == 0,
            "heads ({n_heads}) must divide d_model ({d_model})"
        );
        let d_head = d_model / n_heads;
        let mut heads = Vec::with_capacity(n_heads);
        for _ in 0..n_heads {
            heads.push(build(mech, d_head, horizon)?);
        }
        Ok(MultiHeadAttention { heads, d_model, d_head })
    }

    pub fn n_heads(&self) -> usize {
        self.heads.len()
    }

    pub fn d_head(&self) -> usize {
        self.d_head
    }

    /// Per-head feature dimension (`None` for quadratic mechanisms).
    pub fn feature_dim(&self) -> Option<usize> {
        self.heads[0].feature_dim()
    }

    /// Forward over packed `L × d_model` Q/K/V: each head attends over its
    /// column-block views on its own thread and writes its column block of
    /// the packed output in place.
    pub fn forward<'a>(
        &self,
        q: impl Into<MatView<'a>>,
        k: impl Into<MatView<'a>>,
        v: impl Into<MatView<'a>>,
        causal: bool,
    ) -> anyhow::Result<Mat> {
        let (q, k, v) = (q.into(), k.into(), v.into());
        anyhow::ensure!(
            q.cols() == self.d_model && k.cols() == self.d_model && v.cols() == self.d_model,
            "packed width must be d_model={} (got q={}, k={}, v={})",
            self.d_model,
            q.cols(),
            k.cols(),
            v.cols()
        );
        anyhow::ensure!(
            q.rows() == k.rows() && k.rows() == v.rows(),
            "row mismatch q={} k={} v={}",
            q.rows(),
            k.rows(),
            v.rows()
        );
        let dh = self.d_head;
        let mut out = Mat::zeros(q.rows(), self.d_model);
        std::thread::scope(|s| {
            let mut rest = out.view_mut();
            for (h, backend) in self.heads.iter().enumerate() {
                let (block, tail) = rest.split_cols_at(dh);
                rest = tail;
                let (lo, hi) = (h * dh, (h + 1) * dh);
                let (qh, kh, vh) = (q.col_block(lo, hi), k.col_block(lo, hi), v.col_block(lo, hi));
                s.spawn(move || backend.forward_into(qh, kh, vh, causal, 0, block));
            }
        });
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::config::{Mechanism, SlayConfig};
    use crate::math::rng::Rng;

    fn qkv(l: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        (
            Mat::randn(l, d, &mut rng),
            Mat::randn(l, d, &mut rng),
            Mat::randn(l, d, &mut rng),
        )
    }

    fn all_mechanisms() -> Vec<Mechanism> {
        vec![
            Mechanism::Standard,
            Mechanism::Yat { eps: 1e-3 },
            Mechanism::YatSpherical { eps: 1e-3 },
            Mechanism::Slay(SlayConfig::default()),
            Mechanism::Favor { m_features: 32, seed: 1 },
            Mechanism::EluLinear,
            Mechanism::Cosformer,
        ]
    }

    #[test]
    fn all_mechanisms_produce_finite_outputs_both_masks() {
        let (q, k, v) = qkv(24, 16, 91);
        for mech in all_mechanisms() {
            let op = build(&mech, 16, 64).unwrap();
            for causal in [false, true] {
                let y = op.forward(q.view(), k.view(), v.view(), causal, 0);
                assert_eq!((y.rows, y.cols), (24, 16), "{}", mech.name());
                assert!(
                    y.data.iter().all(|x| x.is_finite()),
                    "{} causal={causal}",
                    mech.name()
                );
            }
        }
    }

    #[test]
    fn linear_flag_agrees_with_feature_dim() {
        for mech in all_mechanisms() {
            let op = build(&mech, 16, 64).unwrap();
            assert_eq!(mech.is_linear(), op.feature_dim().is_some(), "{}", mech.name());
        }
    }

    #[test]
    fn windowed_build_decouples_window_from_horizon() {
        // The dedicated window knob sizes the rolling KV window (and its
        // admission budget) independently of the cosformer horizon.
        let narrow = build_with_window(&Mechanism::Standard, 16, 131_072, 128).unwrap();
        let st = narrow.new_state(8);
        assert_eq!(st.capacity_bytes(), 128 * (16 + 8) * 4);
        // window = 0 falls back to horizon, then to the default
        let fallback = build_with_window(&Mechanism::Standard, 16, 256, 0).unwrap();
        assert_eq!(fallback.new_state(8).capacity_bytes(), 256 * (16 + 8) * 4);
        let default = build_with_window(&Mechanism::Standard, 16, 0, 0).unwrap();
        assert_eq!(
            default.new_state(8).capacity_bytes(),
            DEFAULT_QUADRATIC_WINDOW * (16 + 8) * 4
        );
    }

    #[test]
    fn softmax_forward_equals_classic_softmax_attention() {
        // exp-scores + rowsum normalization ≡ softmax(QKᵀ/√d)V exactly.
        let (q, k, v) = qkv(10, 8, 92);
        let op = build(&Mechanism::Standard, 8, 0).unwrap();
        let y = op.forward(q.view(), k.view(), v.view(), false, 0);
        let mut scores = crate::math::linalg::matmul_a_bt(&q, &k);
        scores.scale(1.0 / (8f32).sqrt());
        crate::math::linalg::softmax_rows(&mut scores);
        let want = crate::math::linalg::matmul(&scores, &v);
        for (a, b) in y.data.iter().zip(want.data.iter()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    /// Clustered token geometry: alignments q̂ᵀk̂ spread over [-1, 1] the way
    /// trained embeddings do (iid Gaussians concentrate near 0 at d=16 and
    /// make every estimator look flat).
    fn clustered_qkv(l: usize, d: usize, seed: u64) -> (Mat, Mat, Mat) {
        let mut rng = Rng::new(seed);
        let centers = Mat::randn(4, d, &mut rng).normalized_rows();
        let mut gen = |rng: &mut Rng| {
            Mat::from_fn(l, d, |r, c| {
                let ctr = centers.row(r % 4);
                ctr[c] + 0.3 * rng.normal_f32()
            })
        };
        let q = gen(&mut rng);
        let k = gen(&mut rng);
        let v = Mat::randn(l, d, &mut rng);
        (q, k, v)
    }

    #[test]
    fn slay_error_decreases_with_feature_budget() {
        // Fig. 14's phenomenon: attention-output error vs exact spherical
        // Yat shrinks as the PRF budget grows (seed-averaged).
        let (q, k, v) = clustered_qkv(48, 16, 93);
        let exact = build(&Mechanism::YatSpherical { eps: 1e-3 }, 16, 0)
            .unwrap()
            .forward(q.view(), k.view(), v.view(), false, 0);
        let mean_err = |d_prf: usize| {
            let mut errs = Vec::new();
            for seed in 0..4 {
                let cfg = SlayConfig { n_poly: 16, d_prf, r_nodes: 2, seed, ..Default::default() };
                let y = build(&Mechanism::Slay(cfg), 16, 0)
                    .unwrap()
                    .forward(q.view(), k.view(), v.view(), false, 0);
                errs.push(crate::math::stats::rel_l2(&y.data, &exact.data));
            }
            crate::math::stats::mean(&errs)
        };
        let small = mean_err(2);
        let large = mean_err(64);
        assert!(
            large < small,
            "budget 64 should beat budget 2: {large} vs {small}"
        );
        assert!(large < 0.9, "large-budget rel-l2 {large} out of range");
        // With the exact polynomial map the estimator reaches the paper's
        // reported fidelity band (Table 6 Large: anchor 0.494).
        let cfg = SlayConfig {
            poly: crate::kernels::config::PolyMethod::Exact,
            d_prf: 64,
            r_nodes: 3,
            ..Default::default()
        };
        let y = build(&Mechanism::Slay(cfg), 16, 0)
            .unwrap()
            .forward(q.view(), k.view(), v.view(), false, 0);
        let err_exact_poly = crate::math::stats::rel_l2(&y.data, &exact.data);
        assert!(err_exact_poly < 0.6, "exact-poly rel-l2 {err_exact_poly} (paper band ≈ 0.49)");
    }

    #[test]
    fn positive_mechanisms_have_positive_denominators() {
        let (q, k, _) = qkv(32, 16, 94);
        for mech in [
            Mechanism::Slay(SlayConfig::default()),
            Mechanism::Favor { m_features: 32, seed: 2 },
            Mechanism::EluLinear,
            Mechanism::YatSpherical { eps: 1e-3 },
        ] {
            let op = build(&mech, 16, 64).unwrap();
            let dens = op.denominators(q.view(), k.view(), false);
            assert!(
                dens.iter().all(|&d| d >= -1e-6),
                "{}: min den {:?}",
                mech.name(),
                dens.iter().cloned().fold(f32::INFINITY, f32::min)
            );
        }
    }

    #[test]
    fn signed_slay_variants_can_go_negative() {
        // Fig. 7: TensorSketch / RandomMaclaurin polynomial components can
        // produce negative denominators.
        use crate::kernels::config::PolyMethod;
        let (q, k, _) = qkv(64, 16, 95);
        let mut saw_negative = false;
        for seed in 0..20 {
            let cfg = SlayConfig {
                poly: PolyMethod::RandomMaclaurin,
                n_poly: 4,
                seed,
                ..Default::default()
            };
            let op = build(&Mechanism::Slay(cfg), 16, 0).unwrap();
            if op.denominators(q.view(), k.view(), false).iter().any(|&d| d < 0.0) {
                saw_negative = true;
                break;
            }
        }
        assert!(saw_negative, "RM-poly SLAY never produced a negative denominator");
    }

    #[test]
    fn causal_denominators_match_noncausal_on_last_row() {
        let (q, k, _) = qkv(12, 8, 98);
        for mech in [Mechanism::Slay(SlayConfig::default()), Mechanism::Standard] {
            let op = build(&mech, 8, 32).unwrap();
            let causal = op.denominators(q.view(), k.view(), true);
            let full = op.denominators(q.view(), k.view(), false);
            assert_eq!(causal.len(), 12);
            let (a, b) = (causal[11], full[11]);
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{}: {a} vs {b}", mech.name());
        }
    }

    #[test]
    fn multi_head_partitions_and_reassembles() {
        let (q, k, v) = qkv(12, 32, 96);
        let mha = MultiHeadAttention::new(&Mechanism::EluLinear, 32, 4, 0).unwrap();
        let y = mha.forward(&q, &k, &v, true).unwrap();
        assert_eq!((y.rows, y.cols), (12, 32));
        // head 0 output must equal single-head forward on the column-block
        // view — and be bit-identical to the same data sliced into an owned
        // contiguous Mat (the ADR-002 contract).
        let op = build(&Mechanism::EluLinear, 8, 0).unwrap();
        let y0 = op.forward(
            q.view().col_block(0, 8),
            k.view().col_block(0, 8),
            v.view().col_block(0, 8),
            true,
            0,
        );
        let slice = |m: &Mat| m.view().col_block(0, 8).to_mat();
        let y0_owned =
            op.forward(slice(&q).view(), slice(&k).view(), slice(&v).view(), true, 0);
        assert_eq!(y0.data, y0_owned.data, "view vs owned forward must be bit-identical");
        for r in 0..12 {
            for c in 0..8 {
                assert!((y.get(r, c) - y0.get(r, c)).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn multi_head_rejects_bad_shapes() {
        assert!(MultiHeadAttention::new(&Mechanism::EluLinear, 30, 4, 0).is_err());
        assert!(MultiHeadAttention::new(&Mechanism::EluLinear, 32, 0, 0).is_err());
        let mha = MultiHeadAttention::new(&Mechanism::EluLinear, 32, 4, 0).unwrap();
        let (q, k, v) = qkv(6, 16, 1);
        assert!(mha.forward(&q, &k, &v, true).is_err());
    }

    #[test]
    fn causal_outputs_ignore_future_tokens() {
        // Perturbing token j > i must not change output row i.
        let (q, k, mut v) = qkv(10, 8, 97);
        for mech in all_mechanisms() {
            let op = build(&mech, 8, 32).unwrap();
            let y1 = op.forward(q.view(), k.view(), v.view(), true, 0);
            // perturb the last value row
            for c in 0..8 {
                let x = v.get(9, c) + 10.0;
                v.set(9, c, x);
            }
            let y2 = op.forward(q.view(), k.view(), v.view(), true, 0);
            for i in 0..9 {
                for c in 0..8 {
                    assert!(
                        (y1.get(i, c) - y2.get(i, c)).abs() < 1e-5,
                        "{} row {i} leaked future info",
                        mech.name()
                    );
                }
            }
            // restore
            for c in 0..8 {
                let x = v.get(9, c) - 10.0;
                v.set(9, c, x);
            }
        }
    }

    #[test]
    fn session_prefill_then_decode_matches_one_shot_forward() {
        // The core serving contract: streaming a sequence through an
        // AttnState (prefill chunk + per-token decode) reproduces the
        // one-shot causal forward for EVERY mechanism — linear streaming
        // states and windowed-quadratic sessions alike. Prefill chunks are
        // zero-copy row-block views of the full buffers.
        let l = 14;
        let (q, k, v) = qkv(l, 8, 90);
        for mech in all_mechanisms() {
            let op = build(&mech, 8, 64).unwrap();
            let want = op.forward(q.view(), k.view(), v.view(), true, 0);
            let mut state = op.new_state(8);
            let split = 9;
            let head = op
                .prefill(
                    &mut state,
                    q.view().row_block(0, split),
                    k.view().row_block(0, split),
                    v.view().row_block(0, split),
                )
                .unwrap();
            let mut got = head.data.clone();
            let mut out = vec![0.0f32; 8];
            for i in split..l {
                op.decode(&mut state, q.row(i), k.row(i), v.row(i), &mut out).unwrap();
                got.extend_from_slice(&out);
            }
            assert_eq!(state.len(), l);
            for (i, (a, b)) in got.iter().zip(want.data.iter()).enumerate() {
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                    "{} elem {i}: {a} vs {b}",
                    mech.name()
                );
            }
        }
    }

    #[test]
    fn quadratic_window_slides_and_stays_bounded() {
        let op = build(&Mechanism::YatSpherical { eps: 1e-3 }, 8, 4).unwrap();
        let mut state = op.new_state(8);
        let cap_bytes = state.capacity_bytes();
        let (q, k, v) = qkv(32, 8, 89);
        let mut out = vec![0.0f32; 8];
        for i in 0..32 {
            op.decode(&mut state, q.row(i), k.row(i), v.row(i), &mut out).unwrap();
            assert!(out.iter().all(|x| x.is_finite()));
        }
        assert_eq!(state.len(), 32);
        assert!(state.bytes() <= cap_bytes, "window grew past its bound");
        // sliding semantics: with cap 4, the output at token 31 attends the
        // last 4 tokens only — recomputing on that suffix matches.
        let suffix = op.forward(
            q.view().row_block(28, 32),
            k.view().row_block(28, 32),
            v.view().row_block(28, 32),
            true,
            0,
        );
        for c in 0..8 {
            let want = suffix.get(3, c);
            assert!((out[c] - want).abs() < 1e-4 * (1.0 + want.abs()), "{} vs {want}", out[c]);
        }
    }

    #[test]
    fn signed_feature_configs_keep_per_token_causal_order() {
        // Signed estimators (here: RM-poly SLAY) route causal attention
        // through the per-token reference order — block reordering near a
        // cancelling denominator is amplified arbitrarily through
        // 1/(den+δ), so their outputs must stay bit-identical to the
        // per-token engine (ADR-003).
        use crate::kernels::config::PolyMethod;
        let cfg = SlayConfig { poly: PolyMethod::RandomMaclaurin, n_poly: 4, ..Default::default() };
        let op = build(&Mechanism::Slay(cfg), 8, 0).unwrap();
        let (q, k, v) = qkv(10, 8, 77);
        let (phi_q, phi_k) = op.map_qk(q.view(), k.view(), 0).unwrap();
        let want = engine::linear_attention_causal(&phi_q, &phi_k, &v, op.delta());
        let got = op.forward(q.view(), k.view(), v.view(), true, 0);
        assert_eq!(got.data, want.data, "signed-feature causal path must be per-token ordered");
        // and the session prefill takes the same order
        let mut state = op.new_state(8);
        let streamed = op.prefill(&mut state, q.view(), k.view(), v.view()).unwrap();
        assert_eq!(streamed.data, want.data);
    }

    #[test]
    fn scratch_session_bit_identical_to_allocating_session() {
        // The zero-alloc entries (prefill_into / decode_with) with a
        // long-lived reused arena must reproduce the allocating wrappers
        // exactly, for linear and quadratic backends alike.
        let l = 13;
        let (q, k, v) = qkv(l, 8, 99);
        for mech in all_mechanisms() {
            let op = build(&mech, 8, 64).unwrap();
            let mut scratch = Scratch::new();
            let mut s_a = op.new_state(8);
            let mut s_b = op.new_state(8);
            let split = 9;
            let head_a = op
                .prefill(
                    &mut s_a,
                    q.view().row_block(0, split),
                    k.view().row_block(0, split),
                    v.view().row_block(0, split),
                )
                .unwrap();
            let mut head_b = Mat::zeros(split, 8);
            op.prefill_into(
                &mut scratch,
                &mut s_b,
                q.view().row_block(0, split),
                k.view().row_block(0, split),
                v.view().row_block(0, split),
                head_b.view_mut(),
            )
            .unwrap();
            assert_eq!(head_a.data, head_b.data, "{}: prefill differs", mech.name());
            let mut out_a = vec![0.0f32; 8];
            let mut out_b = vec![0.0f32; 8];
            for i in split..l {
                op.decode(&mut s_a, q.row(i), k.row(i), v.row(i), &mut out_a).unwrap();
                op.decode_with(&mut scratch, &mut s_b, q.row(i), k.row(i), v.row(i), &mut out_b)
                    .unwrap();
                assert_eq!(out_a, out_b, "{}: decode token {i} differs", mech.name());
            }
            assert_eq!(s_b.len(), l);
        }
    }

    #[test]
    fn fused_decode_threaded_blocks_bit_identical_to_sequential() {
        // Blocks big enough to cross the fan-out flops threshold must stay
        // bit-identical to the sequential loop: row-chunked feature maps
        // are row-independent and per-sequence state ops are disjoint, so
        // thread count can never show up in the bits.
        let b = 64;
        let mut rng = Rng::new(121);
        let mut scratch = Scratch::new();
        // linear: SLAY at d_v = 16 → ~1.6M MACs per block, over threshold
        let op = build(&Mechanism::Slay(SlayConfig::default()), 16, 0).unwrap();
        let q = Mat::randn(b, 16, &mut rng);
        let k = Mat::randn(b, 16, &mut rng);
        let v = Mat::randn(b, 16, &mut rng);
        let mut seq_states: Vec<AttnState> = (0..b).map(|_| op.new_state(16)).collect();
        let mut fused_states: Vec<AttnState> = (0..b).map(|_| op.new_state(16)).collect();
        let mut want = Mat::zeros(b, 16);
        for i in 0..b {
            op.decode_with(
                &mut scratch,
                &mut seq_states[i],
                q.row(i),
                k.row(i),
                v.row(i),
                want.row_mut(i),
            )
            .unwrap();
        }
        let mut got = Mat::zeros(b, 16);
        let mut refs: Vec<&mut AttnState> = fused_states.iter_mut().collect();
        op.decode_batch_with(&mut scratch, &mut refs, q.view(), k.view(), v.view(), got.view_mut())
            .unwrap();
        assert_eq!(got.data, want.data, "threaded linear block diverged");
        // quadratic: saturated 256-row windows → ~0.5M dots per block
        let opq = build_with_window(&Mechanism::Standard, 16, 0, 256).unwrap();
        let fill = Mat::randn(300, 16, &mut rng);
        let mut seq_q: Vec<AttnState> = (0..b).map(|_| opq.new_state(16)).collect();
        let mut fused_q: Vec<AttnState> = (0..b).map(|_| opq.new_state(16)).collect();
        for i in 0..b {
            opq.prefill(&mut seq_q[i], fill.view(), fill.view(), fill.view()).unwrap();
            opq.prefill(&mut fused_q[i], fill.view(), fill.view(), fill.view()).unwrap();
        }
        let mut want_q = Mat::zeros(b, 16);
        for i in 0..b {
            opq.decode_with(
                &mut scratch,
                &mut seq_q[i],
                q.row(i),
                k.row(i),
                v.row(i),
                want_q.row_mut(i),
            )
            .unwrap();
        }
        let mut got_q = Mat::zeros(b, 16);
        let mut refs_q: Vec<&mut AttnState> = fused_q.iter_mut().collect();
        opq.decode_batch_with(
            &mut scratch,
            &mut refs_q,
            q.view(),
            k.view(),
            v.view(),
            got_q.view_mut(),
        )
        .unwrap();
        assert_eq!(got_q.data, want_q.data, "threaded quadratic block diverged");
    }

    #[test]
    fn fused_decode_block_rejects_mismatches_without_mutation() {
        // decode_batch_with validates the WHOLE block before touching any
        // state (the worker's fall-back path relies on it: a rejected
        // block must leave every sequence exactly where it was).
        let lin = build(&Mechanism::EluLinear, 8, 0).unwrap();
        let quad = build(&Mechanism::Standard, 8, 16).unwrap();
        let mut scratch = Scratch::new();
        let (q, k, v) = qkv(2, 8, 120);
        let mut s_lin = lin.new_state(8);
        let mut s_win = quad.new_state(8);
        {
            // mixed state kinds in one block → error on both backends
            let mut refs: Vec<&mut AttnState> = vec![&mut s_lin, &mut s_win];
            let mut out = Mat::zeros(2, 8);
            assert!(lin
                .decode_batch_with(
                    &mut scratch,
                    &mut refs,
                    q.view(),
                    k.view(),
                    v.view(),
                    out.view_mut()
                )
                .is_err());
        }
        {
            let mut refs: Vec<&mut AttnState> = vec![&mut s_lin, &mut s_win];
            let mut out = Mat::zeros(2, 8);
            assert!(quad
                .decode_batch_with(
                    &mut scratch,
                    &mut refs,
                    q.view(),
                    k.view(),
                    v.view(),
                    out.view_mut()
                )
                .is_err());
        }
        assert_eq!(s_lin.len(), 0, "no state mutated by a rejected block");
        assert_eq!(s_win.len(), 0, "no state mutated by a rejected block");
        // row-count mismatch (1 state, 2 rows)
        let mut refs: Vec<&mut AttnState> = vec![&mut s_lin];
        let mut out = Mat::zeros(2, 8);
        assert!(lin
            .decode_batch_with(
                &mut scratch,
                &mut refs,
                q.view(),
                k.view(),
                v.view(),
                out.view_mut()
            )
            .is_err());
        assert_eq!(s_lin.len(), 0);
    }

    #[test]
    fn state_kind_mismatch_is_an_error_not_a_panic() {
        let lin = build(&Mechanism::EluLinear, 8, 0).unwrap();
        let quad = build(&Mechanism::Standard, 8, 0).unwrap();
        let (q, k, v) = qkv(4, 8, 88);
        let mut wrong = quad.new_state(8);
        assert!(lin.prefill(&mut wrong, q.view(), k.view(), v.view()).is_err());
        let mut wrong2 = lin.new_state(8);
        assert!(quad.prefill(&mut wrong2, q.view(), k.view(), v.view()).is_err());
    }

    /// Prefill `split` of `l` tokens into two states, serialize one,
    /// reload it, then decode the remaining tokens on both — every output
    /// must be bit-identical (the ADR-004 round-trip contract).
    fn assert_state_roundtrip(op: &dyn AttentionBackend, l: usize, split: usize, seed: u64) {
        let name = op.mechanism().name();
        let (q, k, v) = qkv(l, 8, seed);
        let mut live = op.new_state(8);
        let mut source = op.new_state(8);
        let y_live = op
            .prefill(
                &mut live,
                q.view().row_block(0, split),
                k.view().row_block(0, split),
                v.view().row_block(0, split),
            )
            .unwrap();
        let y_source = op
            .prefill(
                &mut source,
                q.view().row_block(0, split),
                k.view().row_block(0, split),
                v.view().row_block(0, split),
            )
            .unwrap();
        assert_eq!(y_live.data, y_source.data, "{name}: prefill nondeterministic");
        let mut bytes = Vec::new();
        op.save_state(&source, &mut bytes).unwrap();
        assert_eq!(bytes.len(), source.encoded_bytes(), "{name}: encoded_bytes mismatch");
        let mut r: &[u8] = &bytes;
        let mut restored = op.load_state(&mut r).unwrap();
        assert_eq!(restored.len(), live.len(), "{name}: len lost in round-trip");
        assert_eq!(restored.bytes(), live.bytes(), "{name}: bytes lost in round-trip");
        let mut out_a = vec![0.0f32; 8];
        let mut out_b = vec![0.0f32; 8];
        for i in split..l {
            op.decode(&mut live, q.row(i), k.row(i), v.row(i), &mut out_a).unwrap();
            op.decode(&mut restored, q.row(i), k.row(i), v.row(i), &mut out_b).unwrap();
            assert_eq!(out_a, out_b, "{name}: decode token {i} diverged after reload");
        }
    }

    #[test]
    fn state_codec_round_trips_bit_identically() {
        for mech in all_mechanisms() {
            let op = build(&mech, 8, 64).unwrap();
            assert_state_roundtrip(op.as_ref(), 12, 7, 101);
        }
        // quadratic windows that wrapped (rows == cap < len) round-trip too
        for mech in [
            Mechanism::Standard,
            Mechanism::Yat { eps: 1e-3 },
            Mechanism::YatSpherical { eps: 1e-3 },
        ] {
            let op = build_with_window(&mech, 8, 64, 5).unwrap();
            assert_state_roundtrip(op.as_ref(), 14, 9, 102);
        }
        // empty states round-trip as well
        let op = build(&Mechanism::EluLinear, 8, 0).unwrap();
        let fresh = op.new_state(8);
        let mut bytes = Vec::new();
        op.save_state(&fresh, &mut bytes).unwrap();
        let mut r: &[u8] = &bytes;
        assert_eq!(op.load_state(&mut r).unwrap().len(), 0);
    }

    #[test]
    fn state_codec_rejects_corruption_and_wrong_backend() {
        let lin = build(&Mechanism::EluLinear, 8, 0).unwrap();
        let quad = build(&Mechanism::Standard, 8, 16).unwrap();
        let (q, k, v) = qkv(4, 8, 103);
        let mut st = lin.new_state(8);
        lin.prefill(&mut st, q.view(), k.view(), v.view()).unwrap();
        let mut bytes = Vec::new();
        lin.save_state(&st, &mut bytes).unwrap();
        // a flipped payload byte trips the checksum
        let mut bad = bytes.clone();
        let mid = 20 + (bad.len() - 28) / 2;
        bad[mid] ^= 0x40;
        let mut r: &[u8] = &bad;
        assert!(AttnState::decode(&mut r).is_err());
        // truncation is an error, not a partial state
        let mut r: &[u8] = &bytes[..bytes.len() - 3];
        assert!(AttnState::decode(&mut r).is_err());
        // wrong mechanism family is refused at load
        let mut r: &[u8] = &bytes;
        assert!(quad.load_state(&mut r).is_err());
        // a different linear mechanism is refused too (identity tag)
        let other = build(&Mechanism::Favor { m_features: 32, seed: 1 }, 8, 0).unwrap();
        let mut r: &[u8] = &bytes;
        assert!(other.load_state(&mut r).is_err());
        // even a SAME-SHAPE different mechanism is separated by the tag:
        // Standard and YatSpherical windows share (d_k, cap, aux_dim) but
        // store keys in different serving forms
        let sph = build(&Mechanism::YatSpherical { eps: 1e-3 }, 8, 16).unwrap();
        let mut wq = quad.new_state(8);
        quad.prefill(&mut wq, q.view(), k.view(), v.view()).unwrap();
        let mut qbytes = Vec::new();
        quad.save_state(&wq, &mut qbytes).unwrap();
        let mut r: &[u8] = &qbytes;
        assert!(sph.load_state(&mut r).is_err(), "tag must separate same-shape mechanisms");
        // garbage magic
        let mut r: &[u8] = b"NOTASTATE-------";
        assert!(AttnState::decode(&mut r).is_err());
        // saving a foreign state is refused before any bytes are written
        let mut sink = Vec::new();
        assert!(quad.save_state(&st, &mut sink).is_err());
        assert!(sink.is_empty());
    }

    #[test]
    fn yat_window_cached_scores_match_direct_e_product() {
        // The rolling window caches ‖k‖² per slot and expands the distance
        // per token (‖q−k‖² = ‖q‖² + ‖k‖² − 2qᵀk); it must agree with the
        // direct sq_dist form the one-shot path uses.
        let op = build(&Mechanism::Yat { eps: 1e-3 }, 8, 16).unwrap();
        let (q, k, v) = qkv(10, 8, 104);
        let want = op.forward(q.view(), k.view(), v.view(), true, 0);
        let mut st = op.new_state(8);
        let mut out = vec![0.0f32; 8];
        for i in 0..10 {
            op.decode(&mut st, q.row(i), k.row(i), v.row(i), &mut out).unwrap();
        }
        for (c, (a, b)) in out.iter().zip(want.row(9)).enumerate() {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "col {c}: {a} vs {b}");
        }
    }
}
