//! The Yat-kernel (E-product) and its spherical form — scalar functions,
//! score matrices, and the closed-form derivative used by Fig. 6 and the
//! gradient-stability tests (Prop. 4).

use crate::math::linalg::{dot, sq_dist, Mat, MatView};

/// Exact E-product on raw (unnormalized) vectors (Eq. 1):
/// `E(q,k) = (qᵀk)² / (‖q−k‖² + ε)`.
#[inline]
pub fn e_product(q: &[f32], k: &[f32], eps: f32) -> f32 {
    let a = dot(q, k);
    a * a / (sq_dist(q, k) + eps)
}

/// Spherical E-product as a function of the alignment `x = q̂ᵀk̂` (Eq. 5):
/// `E_sph(x) = x² / (C − 2x)`, `C = 2 + ε`.
#[inline]
pub fn e_sph(x: f32, eps: f32) -> f32 {
    let c = 2.0 + eps;
    x * x / (c - 2.0 * x)
}

/// Derivative `f'(x) = 2x(C − x)/(C − 2x)²` (proof of Prop. 3/4).
#[inline]
pub fn e_sph_deriv(x: f32, eps: f32) -> f32 {
    let c = 2.0 + eps;
    let den = c - 2.0 * x;
    2.0 * x * (c - x) / (den * den)
}

/// Upper bound `1/ε` on `E_sph` over the sphere (Prop. 3).
#[inline]
pub fn e_sph_bound(eps: f32) -> f32 {
    1.0 / eps
}

/// Score matrix of the exact Yat attention on raw rows: `S[i][j] = E(q_i, k_j)`.
/// Accepts owned matrices (`&Mat`) or strided views.
pub fn yat_scores<'a, 'b>(q: impl Into<MatView<'a>>, k: impl Into<MatView<'b>>, eps: f32) -> Mat {
    let (q, k) = (q.into(), k.into());
    assert_eq!(q.cols(), k.cols());
    let mut s = Mat::zeros(q.rows(), k.rows());
    for i in 0..q.rows() {
        let qi = q.row(i);
        let row = s.row_mut(i);
        for (j, rj) in row.iter_mut().enumerate() {
            *rj = e_product(qi, k.row(j), eps);
        }
    }
    s
}

/// Score matrix of the spherical Yat attention. Inputs are normalized
/// internally (Eq. 2) — pass raw Q/K.
pub fn yat_spherical_scores<'a, 'b>(
    q: impl Into<MatView<'a>>,
    k: impl Into<MatView<'b>>,
    eps: f32,
) -> Mat {
    let qn = q.into().normalized_rows();
    let kn = k.into().normalized_rows();
    let mut s = crate::math::linalg::matmul_a_bt(&qn, &kn); // x = q̂ᵀk̂
    for x in s.data.iter_mut() {
        *x = e_sph(*x, eps);
    }
    s
}

/// Softmax attention scores `exp(qᵀk/√d)` (row-normalization happens in the
/// engine; exp(·)/rowsum ≡ softmax exactly).
pub fn softmax_scores<'a, 'b>(q: impl Into<MatView<'a>>, k: impl Into<MatView<'b>>) -> Mat {
    let q = q.into();
    let scale = 1.0 / (q.cols() as f32).sqrt();
    let mut s = crate::math::linalg::matmul_a_bt(q, k);
    // stabilized per-row: subtract row max before exp (cancels in the ratio)
    let exp = crate::math::simd::kernels().exp_affine_scale;
    for i in 0..s.rows {
        let row = s.row_mut(i);
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) * scale;
        exp(row, scale, -mx, 1.0);
    }
    s
}

/// Causal variant of [`softmax_scores`]: row `i` is stabilized by the max
/// over its *visible* prefix `j ≤ i` only. With the full-row max, a
/// dominant future logit can underflow every visible score and let the
/// engine's δ floor zero the row; the prefix max is also exactly what a
/// streaming session computes, so one-shot and prefill/decode paths agree.
/// Entries `j > i` are still exponentiated (against the prefix max) but the
/// causal engine never reads them.
pub fn softmax_scores_causal<'a, 'b>(
    q: impl Into<MatView<'a>>,
    k: impl Into<MatView<'b>>,
) -> Mat {
    let q = q.into();
    let scale = 1.0 / (q.cols() as f32).sqrt();
    let mut s = crate::math::linalg::matmul_a_bt(q, k);
    let exp = crate::math::simd::kernels().exp_affine_scale;
    for i in 0..s.rows {
        let row = s.row_mut(i);
        let lim = (i + 1).min(row.len());
        let mx = row[..lim].iter().copied().fold(f32::NEG_INFINITY, f32::max) * scale;
        exp(row, scale, -mx, 1.0);
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    #[test]
    fn e_product_matches_expanded_formula() {
        let q = [0.5f32, -1.0, 2.0];
        let k = [1.0f32, 0.25, -0.5];
        let eps = 1e-3;
        let qk = q.iter().zip(&k).map(|(a, b)| a * b).sum::<f32>();
        let d2 = q.iter().zip(&k).map(|(a, b)| (a - b) * (a - b)).sum::<f32>();
        assert!((e_product(&q, &k, eps) - qk * qk / (d2 + eps)).abs() < 1e-6);
    }

    #[test]
    fn spherical_form_agrees_with_e_product_on_unit_vectors() {
        // On the sphere, E(q̂,k̂) = x²/((2+ε)−2x) exactly (Eq. 3-5).
        let mut rng = Rng::new(31);
        let eps = 1e-3f32;
        for _ in 0..50 {
            let q = Mat::randn(1, 16, &mut rng).normalized_rows();
            let k = Mat::randn(1, 16, &mut rng).normalized_rows();
            let x = dot(q.row(0), k.row(0));
            let direct = e_product(q.row(0), k.row(0), eps);
            let sph = e_sph(x, eps);
            assert!(
                (direct - sph).abs() < 1e-4 * (1.0 + sph.abs()),
                "direct={direct} sph={sph}"
            );
        }
    }

    #[test]
    fn bound_prop3_holds_and_is_attained() {
        let eps = 1e-2f32;
        for i in 0..=1000 {
            let x = -1.0 + 2.0 * i as f32 / 1000.0;
            let v = e_sph(x, eps);
            assert!(v >= 0.0);
            assert!(v <= e_sph_bound(eps) * (1.0 + 1e-5));
        }
        assert!((e_sph(1.0, eps) - 1.0 / eps).abs() < 1e-2);
    }

    #[test]
    fn deriv_matches_finite_difference() {
        let eps = 1e-2f32;
        for &x in &[-0.9f32, -0.3, 0.0, 0.4, 0.8] {
            let h = 1e-3f32;
            let fd = (e_sph(x + h, eps) - e_sph(x - h, eps)) / (2.0 * h);
            let an = e_sph_deriv(x, eps);
            assert!((fd - an).abs() < 1e-2 * (1.0 + an.abs()), "x={x}: fd={fd} an={an}");
        }
    }

    #[test]
    fn gradient_bound_prop4() {
        // |f'(x)| ≤ C_ε on [-1,1]; with ε=1e-2 the max is ~2·1·3/ε² bounded.
        let eps = 0.1f32;
        let c = 2.0 + eps;
        let bound = 2.0 * (c + 1.0) / (eps * eps); // crude uniform bound
        for i in 0..=1000 {
            let x = -1.0 + 2.0 * i as f32 / 1000.0;
            assert!(e_sph_deriv(x, eps).abs() <= bound);
        }
    }

    #[test]
    fn score_matrices_shapes_and_positivity() {
        let mut rng = Rng::new(32);
        let q = Mat::randn(5, 8, &mut rng);
        let k = Mat::randn(7, 8, &mut rng);
        for s in [
            yat_scores(&q, &k, 1e-3),
            yat_spherical_scores(&q, &k, 1e-3),
            softmax_scores(&q, &k),
        ] {
            assert_eq!((s.rows, s.cols), (5, 7));
            assert!(s.data.iter().all(|&x| x >= 0.0 && x.is_finite()));
        }
    }

    #[test]
    fn causal_scores_survive_dominant_future_logit() {
        // A future key with a huge logit must not underflow the visible
        // prefix of earlier rows (the full-row max would).
        let d = 4;
        let mut q = Mat::zeros(3, d);
        let mut k = Mat::zeros(3, d);
        for c in 0..d {
            q.set(0, c, 1.0);
            k.set(0, c, 1.0);
            k.set(2, c, 40.0); // future key dominates row 0's logits
        }
        let s = softmax_scores_causal(&q, &k);
        // row 0's visible score (j=0) stabilizes to exp(0) = 1
        assert!((s.get(0, 0) - 1.0).abs() < 1e-6);
        let full = softmax_scores(&q, &k);
        // the full-row max underflows the same entry
        assert!(full.get(0, 0) < 1e-20);
    }

    #[test]
    fn spherical_scores_rotation_invariant() {
        // Remark 3(i): E_sph(Rq, Rk) = E_sph(q, k). Use a Givens rotation.
        let mut rng = Rng::new(33);
        let q = Mat::randn(4, 6, &mut rng);
        let k = Mat::randn(4, 6, &mut rng);
        let theta = 0.77f32;
        let rot = |m: &Mat| {
            let mut r = m.clone();
            for i in 0..m.rows {
                let a = m.get(i, 0);
                let b = m.get(i, 3);
                r.set(i, 0, theta.cos() * a - theta.sin() * b);
                r.set(i, 3, theta.sin() * a + theta.cos() * b);
            }
            r
        };
        let s1 = yat_spherical_scores(&q, &k, 1e-3);
        let s2 = yat_spherical_scores(&rot(&q), &rot(&k), 1e-3);
        for (a, b) in s1.data.iter().zip(s2.data.iter()) {
            assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn not_sign_flip_invariant() {
        // Remark 3: the full kernel is NOT invariant under q̂ ↦ −q̂.
        let q = Mat::from_vec(1, 2, vec![1.0, 0.0]);
        let k = Mat::from_vec(1, 2, vec![0.8, 0.6]);
        let nq = q.map(|x| -x);
        let a = yat_spherical_scores(&q, &k, 1e-3).get(0, 0);
        let b = yat_spherical_scores(&nq, &k, 1e-3).get(0, 0);
        assert!((a - b).abs() > 1e-3);
    }

    #[test]
    fn psd_on_sphere_theorem2() {
        // Sampled Gram matrices of E_sph on unit vectors must be PSD.
        let mut rng = Rng::new(34);
        for trial in 0..5 {
            let pts = Mat::randn(10, 4 + trial, &mut rng).normalized_rows();
            let gram = yat_spherical_scores(&pts, &pts, 1e-2);
            // symmetrize tiny float asymmetry before the eig
            let mut sym = gram.clone();
            for r in 0..10 {
                for c in 0..10 {
                    sym.set(r, c, 0.5 * (gram.get(r, c) + gram.get(c, r)));
                }
            }
            let min = crate::math::eigen::min_eigenvalue(&sym);
            assert!(min > -1e-3, "trial {trial}: min eig {min}");
        }
    }
}
