//! Attention engines.
//!
//! * [`quadratic_attention`] — materializes the L×L score matrix
//!   (reference / baseline path; kernel normalization over the allowed
//!   region).
//! * [`linear_attention`] — the Eq. 11 reordering `Ψ(Q)(Ψ(K)ᵀV)` with
//!   row-wise kernel normalization, non-causal (two contractions) and
//!   causal (running prefix state) variants. The L×L matrix is never
//!   formed.
//! * [`StreamingState`] — the linear-attention analog of a KV-cache:
//!   per-sequence `(S = Ψ(K)ᵀV ∈ R^{m×d_v}, z = Ψ(K)ᵀ1 ∈ R^m)`, used by the
//!   coordinator's decode path.
//!
//! Every engine takes strided [`MatView`]s (ADR-002) and has an `_into`
//! variant writing through a [`MatViewMut`], so callers can stream head
//! column-blocks or chunk row-ranges in and pack outputs in place without
//! intermediate copies.

use crate::math::linalg::{axpy, dot, matmul_at_b, matmul_into, Mat, MatView, MatViewMut};

/// Column sums of rows `r0..r1` of `m`, accumulated into `z` (`z += Σ_r m[r]`).
/// This is the `Ψ(K)ᵀ1` contraction of Eq. 11 — the single definition used
/// by the non-causal engine, [`StreamingState::extend`] and the backend
/// denominator diagnostics.
pub fn colsum_into<'a>(m: impl Into<MatView<'a>>, r0: usize, r1: usize, z: &mut [f32]) {
    let m = m.into();
    debug_assert!(r1 <= m.rows() && z.len() == m.cols());
    for r in r0..r1 {
        for (zi, &x) in z.iter_mut().zip(m.row(r)) {
            *zi += x;
        }
    }
}

/// `Ψ(K)ᵀ1` — column sums of `m` over all rows.
pub fn colsum<'a>(m: impl Into<MatView<'a>>) -> Vec<f32> {
    let m = m.into();
    let mut z = vec![0.0f32; m.cols()];
    colsum_into(m, 0, m.rows(), &mut z);
    z
}

/// Kernel-normalized quadratic attention: `Y_i = Σ_j S_ij V_j / (Σ_j S_ij + δ)`
/// with `j ≤ i` under causal masking. `scores` must be nonnegative for the
/// normalization to be meaningful (softmax scores arrive pre-exponentiated).
pub fn quadratic_attention<'a, 'b>(
    scores: impl Into<MatView<'a>>,
    v: impl Into<MatView<'b>>,
    causal: bool,
    delta: f32,
) -> Mat {
    let (scores, v) = (scores.into(), v.into());
    let mut out = Mat::zeros(scores.rows(), v.cols());
    quadratic_attention_into(scores, v, causal, delta, out.view_mut());
    out
}

/// [`quadratic_attention`] writing through a (possibly strided) output view.
pub fn quadratic_attention_into(
    scores: MatView,
    v: MatView,
    causal: bool,
    delta: f32,
    mut out: MatViewMut,
) {
    assert_eq!(scores.cols(), v.rows(), "scores/V mismatch");
    assert_eq!(
        (out.rows(), out.cols()),
        (scores.rows(), v.cols()),
        "quadratic_attention_into: bad output shape"
    );
    for i in 0..scores.rows() {
        let limit = if causal { (i + 1).min(scores.cols()) } else { scores.cols() };
        let srow = &scores.row(i)[..limit];
        let orow = out.row_mut(i);
        orow.fill(0.0);
        let mut den = 0.0f32;
        for (j, &s) in srow.iter().enumerate() {
            den += s;
            if s != 0.0 {
                axpy(s, v.row(j), orow);
            }
        }
        let inv = 1.0 / (den + delta);
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
}

/// Non-causal linear attention (Eq. 11):
/// `Y = Ψ(Q)(Ψ(K)ᵀV) / (Ψ(Q)(Ψ(K)ᵀ1) + δ)` — O(L·m·d_v).
pub fn linear_attention_noncausal<'a, 'b, 'c>(
    phi_q: impl Into<MatView<'a>>,
    phi_k: impl Into<MatView<'b>>,
    v: impl Into<MatView<'c>>,
    delta: f32,
) -> Mat {
    let (phi_q, phi_k, v) = (phi_q.into(), phi_k.into(), v.into());
    let mut y = Mat::zeros(phi_q.rows(), v.cols());
    linear_attention_noncausal_into(phi_q, phi_k, v, delta, y.view_mut());
    y
}

/// [`linear_attention_noncausal`] writing through an output view.
pub fn linear_attention_noncausal_into(
    phi_q: MatView,
    phi_k: MatView,
    v: MatView,
    delta: f32,
    mut out: MatViewMut,
) {
    assert_eq!(phi_q.cols(), phi_k.cols());
    assert_eq!(phi_k.rows(), v.rows());
    let s = matmul_at_b(phi_k, v); // m × d_v
    let z = colsum(phi_k);
    matmul_into(phi_q, s.view(), out.reborrow()); // L × d_v
    for i in 0..out.rows() {
        let den = dot(phi_q.row(i), &z) + delta;
        let inv = 1.0 / den;
        for o in out.row_mut(i).iter_mut() {
            *o *= inv;
        }
    }
}

/// Causal linear attention via running prefix sums: after consuming token
/// `i` the state is `(S_i, z_i)` and `Y_i = Ψ(q_i)ᵀ S_i / (Ψ(q_i)ᵀ z_i + δ)`.
pub fn linear_attention_causal<'a, 'b, 'c>(
    phi_q: impl Into<MatView<'a>>,
    phi_k: impl Into<MatView<'b>>,
    v: impl Into<MatView<'c>>,
    delta: f32,
) -> Mat {
    let (phi_q, phi_k, v) = (phi_q.into(), phi_k.into(), v.into());
    let mut y = Mat::zeros(phi_q.rows(), v.cols());
    linear_attention_causal_into(phi_q, phi_k, v, delta, y.view_mut());
    y
}

/// [`linear_attention_causal`] writing through an output view.
pub fn linear_attention_causal_into(
    phi_q: MatView,
    phi_k: MatView,
    v: MatView,
    delta: f32,
    mut out: MatViewMut,
) {
    assert_eq!(phi_q.cols(), phi_k.cols());
    assert_eq!(phi_k.rows(), v.rows());
    assert_eq!(phi_q.rows(), phi_k.rows());
    assert_eq!(
        (out.rows(), out.cols()),
        (phi_q.rows(), v.cols()),
        "linear_attention_causal_into: bad output shape"
    );
    let mut state = StreamingState::new(phi_q.cols(), v.cols());
    for i in 0..phi_q.rows() {
        state.append(phi_k.row(i), v.row(i));
        state.query_into(phi_q.row(i), delta, out.row_mut(i));
    }
}

/// Unified entry: dispatch on causality.
pub fn linear_attention<'a, 'b, 'c>(
    phi_q: impl Into<MatView<'a>>,
    phi_k: impl Into<MatView<'b>>,
    v: impl Into<MatView<'c>>,
    causal: bool,
    delta: f32,
) -> Mat {
    if causal {
        linear_attention_causal(phi_q, phi_k, v, delta)
    } else {
        linear_attention_noncausal(phi_q, phi_k, v, delta)
    }
}

/// Unified `_into` entry: dispatch on causality.
pub fn linear_attention_into(
    phi_q: MatView,
    phi_k: MatView,
    v: MatView,
    causal: bool,
    delta: f32,
    out: MatViewMut,
) {
    if causal {
        linear_attention_causal_into(phi_q, phi_k, v, delta, out)
    } else {
        linear_attention_noncausal_into(phi_q, phi_k, v, delta, out)
    }
}

/// Streaming per-sequence state — the linear-attention "KV-cache".
///
/// Memory is `m·(d_v + 1)` floats regardless of how many tokens have been
/// absorbed: this constant-size state is what lets the coordinator serve
/// 131K-token contexts (Fig. 2/21) without quadratic growth.
#[derive(Clone, Debug)]
pub struct StreamingState {
    pub m: usize,
    pub d_v: usize,
    /// `S = Ψ(K)ᵀV`, row-major `m × d_v`.
    pub s: Vec<f32>,
    /// `z = Ψ(K)ᵀ1`.
    pub z: Vec<f32>,
    /// Tokens absorbed so far.
    pub len: usize,
}

impl StreamingState {
    pub fn new(m: usize, d_v: usize) -> Self {
        StreamingState { m, d_v, s: vec![0.0; m * d_v], z: vec![0.0; m], len: 0 }
    }

    /// Absorb one (key-feature, value) pair: `S += φ_k ⊗ v`, `z += φ_k`.
    pub fn append(&mut self, phi_k: &[f32], v: &[f32]) {
        debug_assert_eq!(phi_k.len(), self.m);
        debug_assert_eq!(v.len(), self.d_v);
        for (j, &f) in phi_k.iter().enumerate() {
            if f != 0.0 {
                axpy(f, v, &mut self.s[j * self.d_v..(j + 1) * self.d_v]);
            }
            self.z[j] += f;
        }
        self.len += 1;
    }

    /// Absorb a whole chunk (prefill): `S += Ψ(K)ᵀV` via one contraction.
    pub fn extend<'a, 'b>(&mut self, phi_k: impl Into<MatView<'a>>, v: impl Into<MatView<'b>>) {
        let (phi_k, v) = (phi_k.into(), v.into());
        assert_eq!(phi_k.cols(), self.m);
        assert_eq!(v.cols(), self.d_v);
        assert_eq!(phi_k.rows(), v.rows());
        let delta_s = matmul_at_b(phi_k, v);
        for (a, b) in self.s.iter_mut().zip(delta_s.data.iter()) {
            *a += b;
        }
        colsum_into(phi_k, 0, phi_k.rows(), &mut self.z);
        self.len += phi_k.rows();
    }

    /// Attend with one query-feature row, writing `d_v` outputs into `out`.
    pub fn query_into(&self, phi_q: &[f32], delta: f32, out: &mut [f32]) {
        debug_assert_eq!(phi_q.len(), self.m);
        debug_assert_eq!(out.len(), self.d_v);
        out.fill(0.0);
        for (j, &f) in phi_q.iter().enumerate() {
            if f != 0.0 {
                axpy(f, &self.s[j * self.d_v..(j + 1) * self.d_v], out);
            }
        }
        let den = dot(phi_q, &self.z) + delta;
        let inv = 1.0 / den;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }

    /// Convenience allocating variant.
    pub fn query(&self, phi_q: &[f32], delta: f32) -> Vec<f32> {
        let mut out = vec![0.0; self.d_v];
        self.query_into(phi_q, delta, &mut out);
        out
    }

    /// Bytes held by this state (capacity accounting for the coordinator).
    pub fn bytes(&self) -> usize {
        (self.s.len() + self.z.len()) * std::mem::size_of::<f32>()
    }
}

/// Analytic peak-workspace model (bytes) for one attention head at sequence
/// length `L` — drives the Fig. 2/21 memory series without having to OOM
/// the host for the quadratic mechanisms at 131K tokens.
pub fn workspace_bytes(linear_feature_dim: Option<usize>, l: usize, d: usize, d_v: usize) -> usize {
    let f = std::mem::size_of::<f32>();
    match linear_feature_dim {
        // scores L×L plus Q,K,V,Y
        None => f * (l * l + l * (2 * d + 2 * d_v)),
        // features 2·L×m, state m×(d_v+1), Q,K,V,Y
        Some(m) => f * (2 * l * m + m * (d_v + 1) + l * (2 * d + 2 * d_v)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        Mat::randn(r, c, &mut Rng::new(seed))
    }

    /// Reference: explicit score matrix from features, then quadratic path.
    fn linear_via_quadratic(phi_q: &Mat, phi_k: &Mat, v: &Mat, causal: bool, delta: f32) -> Mat {
        let scores = crate::math::linalg::matmul_a_bt(phi_q, phi_k);
        quadratic_attention(&scores, v, causal, delta)
    }

    #[test]
    fn noncausal_linear_matches_explicit_scores() {
        let phi_q = rand_mat(12, 7, 71).map(|x| x.abs()); // nonneg features
        let phi_k = rand_mat(12, 7, 72).map(|x| x.abs());
        let v = rand_mat(12, 5, 73);
        let fast = linear_attention_noncausal(&phi_q, &phi_k, &v, 1e-6);
        let slow = linear_via_quadratic(&phi_q, &phi_k, &v, false, 1e-6);
        for (a, b) in fast.data.iter().zip(slow.data.iter()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn causal_linear_matches_masked_quadratic() {
        let phi_q = rand_mat(16, 6, 74).map(|x| x.abs());
        let phi_k = rand_mat(16, 6, 75).map(|x| x.abs());
        let v = rand_mat(16, 4, 76);
        let fast = linear_attention_causal(&phi_q, &phi_k, &v, 1e-6);
        let slow = linear_via_quadratic(&phi_q, &phi_k, &v, true, 1e-6);
        for (a, b) in fast.data.iter().zip(slow.data.iter()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn causal_first_token_attends_only_itself() {
        let phi_q = rand_mat(4, 3, 77).map(|x| x.abs() + 0.1);
        let phi_k = phi_q.clone();
        let v = rand_mat(4, 2, 78);
        let y = linear_attention_causal(&phi_q, &phi_k, &v, 0.0);
        // Y_0 = (φq0·φk0 v0)/(φq0·φk0) = v0
        for c in 0..2 {
            assert!((y.get(0, c) - v.get(0, c)).abs() < 1e-5);
        }
    }

    #[test]
    fn streaming_state_equals_batch_causal() {
        let phi_q = rand_mat(20, 8, 79).map(|x| x.abs());
        let phi_k = rand_mat(20, 8, 80).map(|x| x.abs());
        let v = rand_mat(20, 6, 81);
        let batch = linear_attention_causal(&phi_q, &phi_k, &v, 1e-6);
        let mut state = StreamingState::new(8, 6);
        for i in 0..20 {
            state.append(phi_k.row(i), v.row(i));
            let y = state.query(phi_q.row(i), 1e-6);
            for c in 0..6 {
                assert!((y[c] - batch.get(i, c)).abs() < 1e-4, "tok {i} col {c}");
            }
        }
        assert_eq!(state.len, 20);
    }

    #[test]
    fn chunked_extend_equals_append_loop() {
        let phi_k = rand_mat(24, 5, 82).map(|x| x.abs());
        let v = rand_mat(24, 3, 83);
        let mut s1 = StreamingState::new(5, 3);
        for i in 0..24 {
            s1.append(phi_k.row(i), v.row(i));
        }
        let mut s2 = StreamingState::new(5, 3);
        // two chunks, taken as zero-copy row-range views
        let (top, bot) = phi_k.view().split_rows(10);
        let (vt, vb) = v.view().split_rows(10);
        s2.extend(top, vt);
        s2.extend(bot, vb);
        for (a, b) in s1.s.iter().zip(s2.s.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in s1.z.iter().zip(s2.z.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn colsum_matches_transpose_times_ones() {
        let m = rand_mat(9, 5, 70);
        let z = colsum(&m);
        for c in 0..5 {
            let want: f32 = (0..9).map(|r| m.get(r, c)).sum();
            assert!((z[c] - want).abs() < 1e-5, "col {c}: {} vs {want}", z[c]);
        }
        // range accumulation composes
        let mut z2 = vec![0.0f32; 5];
        colsum_into(&m, 0, 4, &mut z2);
        colsum_into(&m, 4, 9, &mut z2);
        for (a, b) in z.iter().zip(z2.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn quadratic_rows_are_convex_combinations() {
        // With nonneg scores and δ→0, each output row is a convex combination
        // of value rows ⇒ stays within [min, max] per column.
        let scores = rand_mat(10, 10, 84).map(|x| x.abs());
        let v = rand_mat(10, 3, 85);
        let y = quadratic_attention(&scores, &v, false, 0.0);
        for c in 0..3 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for r in 0..10 {
                lo = lo.min(v.get(r, c));
                hi = hi.max(v.get(r, c));
            }
            for r in 0..10 {
                let x = y.get(r, c);
                assert!(x >= lo - 1e-4 && x <= hi + 1e-4);
            }
        }
    }

    #[test]
    fn engines_into_strided_output_match_allocating_path() {
        // Writing through a column block of a packed output must be
        // bit-identical to the allocating entry points.
        let phi_q = rand_mat(14, 6, 87).map(|x| x.abs());
        let phi_k = rand_mat(14, 6, 88).map(|x| x.abs());
        let v = rand_mat(14, 4, 89);
        for causal in [false, true] {
            let want = linear_attention(&phi_q, &phi_k, &v, causal, 1e-6);
            let mut packed = Mat::zeros(14, 10);
            let (_, rest) = packed.view_mut().split_cols_at(3);
            let (block, _) = rest.split_cols_at(4);
            linear_attention_into(phi_q.view(), phi_k.view(), v.view(), causal, 1e-6, block);
            for r in 0..14 {
                assert_eq!(&packed.row(r)[3..7], want.row(r), "causal={causal} row {r}");
            }
        }
        let scores = rand_mat(14, 14, 90).map(|x| x.abs());
        let want = quadratic_attention(&scores, &v, true, 1e-6);
        let mut packed = Mat::zeros(14, 6);
        let (block, _) = packed.view_mut().split_cols_at(4);
        quadratic_attention_into(scores.view(), v.view(), true, 1e-6, block);
        for r in 0..14 {
            assert_eq!(&packed.row(r)[..4], want.row(r), "row {r}");
        }
    }

    #[test]
    fn workspace_model_orders_mechanisms_correctly() {
        // quadratic blows past linear once L·L dominates L·m.
        let m = 384;
        let quad_small = workspace_bytes(None, 256, 64, 64);
        let lin_small = workspace_bytes(Some(m), 256, 64, 64);
        assert!(quad_small < lin_small); // short L: features cost more
        let quad_big = workspace_bytes(None, 32_768, 64, 64);
        let lin_big = workspace_bytes(Some(m), 32_768, 64, 64);
        assert!(quad_big > 10 * lin_big); // long L: quadratic explodes
    }

    #[test]
    fn zero_features_yield_finite_outputs() {
        // δ stabilizer prevents 0/0 (Higham-style guard from §2.5).
        let phi = Mat::zeros(3, 4);
        let v = rand_mat(3, 2, 86);
        let y = linear_attention_noncausal(&phi, &phi, &v, 1e-6);
        assert!(y.data.iter().all(|x| x.is_finite()));
        let yc = linear_attention_causal(&phi, &phi, &v, 1e-6);
        assert!(yc.data.iter().all(|x| x.is_finite()));
    }
}
