//! Attention engines.
//!
//! * [`quadratic_attention`] — materializes the L×L score matrix
//!   (reference / baseline path; kernel normalization over the allowed
//!   region).
//! * [`linear_attention`] — the Eq. 11 reordering `Ψ(Q)(Ψ(K)ᵀV)` with
//!   row-wise kernel normalization, non-causal (two contractions) and
//!   causal variants. The L×L matrix is never formed.
//! * [`linear_attention_causal_chunked_into`] — the chunkwise-parallel
//!   causal decomposition (ADR-003): per block of `B` tokens the
//!   intra-block contribution is one small causally-masked quadratic
//!   block, the inter-block contribution one dense matmul against the
//!   running `(S, z)` prefix state, and the state update one `Ψ(K_b)ᵀV_b`
//!   contraction — O(L/B) matmuls that flow through the threaded kernels
//!   instead of O(L) rank-1 scalar updates. This is the engine behind the
//!   causal dispatch paths; [`linear_attention_causal`] keeps the
//!   per-token prefix-sum form as the reference implementation
//!   (property-tested equal across block sizes).
//! * [`StreamingState`] — the linear-attention analog of a KV-cache:
//!   per-sequence `(S = Ψ(K)ᵀV ∈ R^{m×d_v}, z = Ψ(K)ᵀ1 ∈ R^m)`, used by the
//!   coordinator's decode path; [`StreamingState::prefill_chunked_into`]
//!   is the serving-side entry of the chunkwise engine.
//!
//! Every engine takes strided [`MatView`]s (ADR-002) and has an `_into`
//! variant writing through a [`MatViewMut`], so callers can stream head
//! column-blocks or chunk row-ranges in and pack outputs in place without
//! intermediate copies. The chunked engine additionally draws every
//! intermediate (block scores, per-block states) from a caller-supplied
//! [`Scratch`] arena, so a warmed-up serving loop allocates nothing.

use crate::math::linalg::{
    axpy, dot, matmul_a_bt_serial_into, matmul_at_b, matmul_at_b_acc_into,
    matmul_at_b_acc_serial, matmul_into, matmul_serial_into, num_threads, Mat, MatView,
    MatViewMut, Scratch, PAR_FLOPS,
};

/// Column sums of rows `r0..r1` of `m`, accumulated into `z` (`z += Σ_r m[r]`).
/// This is the `Ψ(K)ᵀ1` contraction of Eq. 11 — the single definition used
/// by the non-causal engine, [`StreamingState::extend`] and the backend
/// denominator diagnostics.
pub fn colsum_into<'a>(m: impl Into<MatView<'a>>, r0: usize, r1: usize, z: &mut [f32]) {
    let m = m.into();
    debug_assert!(r1 <= m.rows() && z.len() == m.cols());
    let add = crate::math::simd::kernels().add_assign;
    for r in r0..r1 {
        add(m.row(r), z);
    }
}

/// `Ψ(K)ᵀ1` — column sums of `m` over all rows.
pub fn colsum<'a>(m: impl Into<MatView<'a>>) -> Vec<f32> {
    let m = m.into();
    let mut z = vec![0.0f32; m.cols()];
    colsum_into(m, 0, m.rows(), &mut z);
    z
}

/// Kernel-normalized quadratic attention: `Y_i = Σ_j S_ij V_j / (Σ_j S_ij + δ)`
/// with `j ≤ i` under causal masking. `scores` must be nonnegative for the
/// normalization to be meaningful (softmax scores arrive pre-exponentiated).
pub fn quadratic_attention<'a, 'b>(
    scores: impl Into<MatView<'a>>,
    v: impl Into<MatView<'b>>,
    causal: bool,
    delta: f32,
) -> Mat {
    let (scores, v) = (scores.into(), v.into());
    let mut out = Mat::zeros(scores.rows(), v.cols());
    quadratic_attention_into(scores, v, causal, delta, out.view_mut());
    out
}

/// [`quadratic_attention`] writing through a (possibly strided) output view.
pub fn quadratic_attention_into(
    scores: MatView,
    v: MatView,
    causal: bool,
    delta: f32,
    mut out: MatViewMut,
) {
    assert_eq!(scores.cols(), v.rows(), "scores/V mismatch");
    assert_eq!(
        (out.rows(), out.cols()),
        (scores.rows(), v.cols()),
        "quadratic_attention_into: bad output shape"
    );
    for i in 0..scores.rows() {
        let limit = if causal { (i + 1).min(scores.cols()) } else { scores.cols() };
        let srow = &scores.row(i)[..limit];
        let orow = out.row_mut(i);
        orow.fill(0.0);
        let mut den = 0.0f32;
        for (j, &s) in srow.iter().enumerate() {
            den += s;
            if s != 0.0 {
                axpy(s, v.row(j), orow);
            }
        }
        let inv = 1.0 / (den + delta);
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
}

/// Non-causal linear attention (Eq. 11):
/// `Y = Ψ(Q)(Ψ(K)ᵀV) / (Ψ(Q)(Ψ(K)ᵀ1) + δ)` — O(L·m·d_v).
pub fn linear_attention_noncausal<'a, 'b, 'c>(
    phi_q: impl Into<MatView<'a>>,
    phi_k: impl Into<MatView<'b>>,
    v: impl Into<MatView<'c>>,
    delta: f32,
) -> Mat {
    let (phi_q, phi_k, v) = (phi_q.into(), phi_k.into(), v.into());
    let mut y = Mat::zeros(phi_q.rows(), v.cols());
    linear_attention_noncausal_into(phi_q, phi_k, v, delta, y.view_mut());
    y
}

/// [`linear_attention_noncausal`] writing through an output view.
pub fn linear_attention_noncausal_into(
    phi_q: MatView,
    phi_k: MatView,
    v: MatView,
    delta: f32,
    mut out: MatViewMut,
) {
    assert_eq!(phi_q.cols(), phi_k.cols());
    assert_eq!(phi_k.rows(), v.rows());
    let s = matmul_at_b(phi_k, v); // m × d_v
    let z = colsum(phi_k);
    matmul_into(phi_q, s.view(), out.reborrow()); // L × d_v
    for i in 0..out.rows() {
        let den = dot(phi_q.row(i), &z) + delta;
        let inv = 1.0 / den;
        for o in out.row_mut(i).iter_mut() {
            *o *= inv;
        }
    }
}

/// Default block width `B` for the chunkwise-parallel causal engine
/// (ADR-003). See [`causal_block`] for the tuning knob.
pub const DEFAULT_CAUSAL_BLOCK: usize = 128;

/// Block width used by the causal dispatch paths: the `SLAY_CAUSAL_BLOCK`
/// env var when set (and positive), else [`DEFAULT_CAUSAL_BLOCK`]. Larger
/// blocks amortize matmul/thread overheads but pay O(B·m) extra score
/// flops per token; see ADR-003 in ROADMAP.md for the tuning guidance.
pub fn causal_block() -> usize {
    static B: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *B.get_or_init(|| {
        std::env::var("SLAY_CAUSAL_BLOCK")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&b| b > 0)
            .unwrap_or(DEFAULT_CAUSAL_BLOCK)
    })
}

/// Chunkwise-parallel causal linear attention (ADR-003): block-decomposed
/// Eq. 11 with a per-block masked quadratic term. Equivalent to
/// [`linear_attention_causal`] up to f32 summation order, for every block
/// size ≥ 1.
pub fn linear_attention_causal_chunked<'a, 'b, 'c>(
    phi_q: impl Into<MatView<'a>>,
    phi_k: impl Into<MatView<'b>>,
    v: impl Into<MatView<'c>>,
    delta: f32,
    block: usize,
) -> Mat {
    let (phi_q, phi_k, v) = (phi_q.into(), phi_k.into(), v.into());
    let mut y = Mat::zeros(phi_q.rows(), v.cols());
    linear_attention_causal_chunked_into(phi_q, phi_k, v, delta, block, y.view_mut());
    y
}

/// [`linear_attention_causal_chunked`] writing through an output view.
pub fn linear_attention_causal_chunked_into(
    phi_q: MatView,
    phi_k: MatView,
    v: MatView,
    delta: f32,
    block: usize,
    out: MatViewMut,
) {
    let mut state = StreamingState::new(phi_q.cols(), v.cols());
    let mut scratch = Scratch::new();
    state.prefill_chunked_into(phi_q, phi_k, v, delta, block, &mut scratch, out);
}

/// Causal linear attention via running prefix sums: after consuming token
/// `i` the state is `(S_i, z_i)` and `Y_i = Ψ(q_i)ᵀ S_i / (Ψ(q_i)ᵀ z_i + δ)`.
///
/// This is the per-token **reference engine** — O(L) rank-1 updates. The
/// dispatch paths ([`linear_attention`], [`linear_attention_into`]) use the
/// chunkwise-parallel engine instead; this form remains the ground truth
/// the property tests compare against and the `fig2_scaling` before/after
/// baseline.
pub fn linear_attention_causal<'a, 'b, 'c>(
    phi_q: impl Into<MatView<'a>>,
    phi_k: impl Into<MatView<'b>>,
    v: impl Into<MatView<'c>>,
    delta: f32,
) -> Mat {
    let (phi_q, phi_k, v) = (phi_q.into(), phi_k.into(), v.into());
    let mut y = Mat::zeros(phi_q.rows(), v.cols());
    linear_attention_causal_into(phi_q, phi_k, v, delta, y.view_mut());
    y
}

/// [`linear_attention_causal`] writing through an output view.
pub fn linear_attention_causal_into(
    phi_q: MatView,
    phi_k: MatView,
    v: MatView,
    delta: f32,
    mut out: MatViewMut,
) {
    assert_eq!(phi_q.cols(), phi_k.cols());
    assert_eq!(phi_k.rows(), v.rows());
    assert_eq!(phi_q.rows(), phi_k.rows());
    assert_eq!(
        (out.rows(), out.cols()),
        (phi_q.rows(), v.cols()),
        "linear_attention_causal_into: bad output shape"
    );
    let mut state = StreamingState::new(phi_q.cols(), v.cols());
    for i in 0..phi_q.rows() {
        state.append(phi_k.row(i), v.row(i));
        state.query_into(phi_q.row(i), delta, out.row_mut(i));
    }
}

/// Unified entry: dispatch on causality. The causal branch runs the
/// chunkwise-parallel engine at the [`causal_block`] width (ADR-003).
pub fn linear_attention<'a, 'b, 'c>(
    phi_q: impl Into<MatView<'a>>,
    phi_k: impl Into<MatView<'b>>,
    v: impl Into<MatView<'c>>,
    causal: bool,
    delta: f32,
) -> Mat {
    let (phi_q, phi_k, v) = (phi_q.into(), phi_k.into(), v.into());
    let mut y = Mat::zeros(phi_q.rows(), v.cols());
    linear_attention_into(phi_q, phi_k, v, causal, delta, y.view_mut());
    y
}

/// Unified `_into` entry: dispatch on causality. The causal branch runs
/// the chunkwise-parallel engine at the [`causal_block`] width (ADR-003).
pub fn linear_attention_into(
    phi_q: MatView,
    phi_k: MatView,
    v: MatView,
    causal: bool,
    delta: f32,
    out: MatViewMut,
) {
    if causal {
        linear_attention_causal_chunked_into(phi_q, phi_k, v, delta, causal_block(), out)
    } else {
        linear_attention_noncausal_into(phi_q, phi_k, v, delta, out)
    }
}

/// Engine fan-outs currently in flight across all threads. Concurrent
/// callers — e.g. the per-head threads of
/// [`MultiHeadAttention::forward`](crate::kernels::MultiHeadAttention) —
/// split the [`num_threads`] budget between them instead of each spawning
/// a full complement and oversubscribing the machine.
static ACTIVE_ENGINE_FANOUTS: std::sync::atomic::AtomicUsize =
    std::sync::atomic::AtomicUsize::new(0);

/// RAII registration of one engine-level thread fan-out. Concurrent
/// fan-outs — per-head forwards, chunked prefills, fused decode blocks —
/// each register here and divide the [`num_threads`] budget by
/// [`FanoutGuard::active`] (which counts this registration), so nested or
/// parallel callers share one thread complement instead of multiplying
/// into oversubscription.
pub(crate) struct FanoutGuard {
    active: usize,
}

impl FanoutGuard {
    pub(crate) fn register() -> FanoutGuard {
        use std::sync::atomic::Ordering;
        let active = ACTIVE_ENGINE_FANOUTS.fetch_add(1, Ordering::Relaxed) + 1;
        FanoutGuard { active }
    }

    /// Fan-outs in flight, including this one.
    pub(crate) fn active(&self) -> usize {
        self.active
    }
}

impl Drop for FanoutGuard {
    fn drop(&mut self) {
        use std::sync::atomic::Ordering;
        ACTIVE_ENGINE_FANOUTS.fetch_sub(1, Ordering::Relaxed);
    }
}

/// One block's causal outputs (shared by the sequential loop and the
/// parallel phase 3): inter-chunk contribution against the block's entry
/// state `(s, z)`, then the causally-masked intra-chunk `B×B` scores,
/// then the Eq. 11 normalization. `scores_buf`/`den_buf` are reusable
/// workspaces of at least `B²`/`B` floats.
#[allow(clippy::too_many_arguments)] // one fused engine step: tensors + state + workspaces
fn block_output(
    q_b: MatView,
    k_b: MatView,
    v_b: MatView,
    s: MatView,
    z: &[f32],
    delta: f32,
    scores_buf: &mut [f32],
    den_buf: &mut [f32],
    mut o_b: MatViewMut,
) {
    let nb = q_b.rows();
    // inter-chunk: dense matmul against the entry state
    matmul_serial_into(q_b, s, o_b.reborrow());
    for (i, d) in den_buf[..nb].iter_mut().enumerate() {
        *d = dot(q_b.row(i), z);
    }
    // intra-chunk: causally-masked B×B quadratic block
    let scores = &mut scores_buf[..nb * nb];
    matmul_a_bt_serial_into(q_b, k_b, MatViewMut::new(scores, nb, nb));
    apply_block(&mut o_b, scores, &den_buf[..nb], v_b, delta);
}

/// One block of the chunkwise causal engine, applied on top of the
/// inter-chunk partials already sitting in `out`: add the causally-masked
/// (`j ≤ i`) intra-chunk contributions from the `nb×nb` `scores` block,
/// then normalize each row by its full denominator
/// `den_i + Σ_{j≤i} s_ij + δ` (Eq. 11's kernel normalization).
fn apply_block(out: &mut MatViewMut, scores: &[f32], den: &[f32], v_b: MatView, delta: f32) {
    let nb = den.len();
    debug_assert_eq!(scores.len(), nb * nb);
    debug_assert_eq!(v_b.rows(), nb);
    for i in 0..nb {
        let orow = out.row_mut(i);
        let srow = &scores[i * nb..i * nb + i + 1]; // causal mask: j ≤ i
        let mut d = den[i];
        for (j, &sc) in srow.iter().enumerate() {
            d += sc;
            if sc != 0.0 {
                axpy(sc, v_b.row(j), orow);
            }
        }
        let inv = 1.0 / (d + delta);
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
}

/// Streaming per-sequence state — the linear-attention "KV-cache".
///
/// Memory is `m·(d_v + 1)` floats regardless of how many tokens have been
/// absorbed: this constant-size state is what lets the coordinator serve
/// 131K-token contexts (Fig. 2/21) without quadratic growth.
#[derive(Clone, Debug)]
pub struct StreamingState {
    pub m: usize,
    pub d_v: usize,
    /// `S = Ψ(K)ᵀV`, row-major `m × d_v`.
    pub s: Vec<f32>,
    /// `z = Ψ(K)ᵀ1`.
    pub z: Vec<f32>,
    /// Tokens absorbed so far.
    pub len: usize,
}

impl StreamingState {
    pub fn new(m: usize, d_v: usize) -> Self {
        StreamingState { m, d_v, s: vec![0.0; m * d_v], z: vec![0.0; m], len: 0 }
    }

    /// Absorb one (key-feature, value) pair: `S += φ_k ⊗ v`, `z += φ_k`.
    pub fn append(&mut self, phi_k: &[f32], v: &[f32]) {
        debug_assert_eq!(phi_k.len(), self.m);
        debug_assert_eq!(v.len(), self.d_v);
        for (j, &f) in phi_k.iter().enumerate() {
            if f != 0.0 {
                axpy(f, v, &mut self.s[j * self.d_v..(j + 1) * self.d_v]);
            }
            self.z[j] += f;
        }
        self.len += 1;
    }

    /// Absorb a whole chunk (prefill): `S += Ψ(K)ᵀV` via one accumulating
    /// contraction straight into the state buffer — no `ΔS` temporary.
    pub fn extend<'a, 'b>(&mut self, phi_k: impl Into<MatView<'a>>, v: impl Into<MatView<'b>>) {
        let (phi_k, v) = (phi_k.into(), v.into());
        assert_eq!(phi_k.cols(), self.m);
        assert_eq!(v.cols(), self.d_v);
        assert_eq!(phi_k.rows(), v.rows());
        matmul_at_b_acc_into(phi_k, v, MatViewMut::new(&mut self.s, self.m, self.d_v));
        colsum_into(phi_k, 0, phi_k.rows(), &mut self.z);
        self.len += phi_k.rows();
    }

    /// Chunkwise-parallel causal prefill (ADR-003): stream `L` tokens of
    /// pre-mapped features through this state in blocks of `block` tokens,
    /// writing the causal attention outputs for every token into `out`.
    ///
    /// Decomposition per block `b` (queries `i`, keys `j`, `B = block`):
    ///
    /// * **inter-chunk** — `Ψ(Q_b)·S` against the prefix state (one dense
    ///   matmul) plus denominators `Ψ(Q_b)·z`;
    /// * **intra-chunk** — the `B×B` score block `Ψ(Q_b)Ψ(K_b)ᵀ`,
    ///   causally masked (`j ≤ i`) and accumulated quadratic-style;
    /// * **state update** — `S += Ψ(K_b)ᵀV_b`, `z += Ψ(K_b)ᵀ1`.
    ///
    /// When the problem is large enough the engine runs in three phases:
    /// all per-block `Ψ(K_b)ᵀV_b` contractions fan out across threads,
    /// a serial (cheap) pass turns them into exclusive prefix states, and
    /// the per-block outputs fan out again — two thread fan-outs total for
    /// the whole prefill, with every intermediate drawn from `scratch`.
    /// Small inputs take a sequential block loop over the same math.
    #[allow(clippy::too_many_arguments)] // engine entry: tensors + tuning knobs
    pub fn prefill_chunked_into(
        &mut self,
        phi_q: MatView,
        phi_k: MatView,
        v: MatView,
        delta: f32,
        block: usize,
        scratch: &mut Scratch,
        out: MatViewMut,
    ) {
        let l = phi_q.rows();
        assert!(block >= 1, "prefill_chunked_into: block must be >= 1");
        assert_eq!(phi_q.cols(), self.m, "prefill_chunked_into: phi_q feature dim");
        assert_eq!(phi_k.cols(), self.m, "prefill_chunked_into: phi_k feature dim");
        assert_eq!(v.cols(), self.d_v, "prefill_chunked_into: value dim");
        assert_eq!(phi_k.rows(), l, "prefill_chunked_into: phi_k rows");
        assert_eq!(v.rows(), l, "prefill_chunked_into: v rows");
        assert_eq!(
            (out.rows(), out.cols()),
            (l, self.d_v),
            "prefill_chunked_into: out is {}x{}, need {}x{}",
            out.rows(),
            out.cols(),
            l,
            self.d_v
        );
        if l == 0 {
            return;
        }
        let block = block.min(l);
        let n_blocks = l.div_ceil(block);
        // Total MAC count across the three phases; below the parallel
        // threshold the sequential loop avoids two thread fan-outs.
        let flops = l * self.m * (block + 2 * self.d_v);
        if n_blocks < 2 || num_threads() == 1 || flops < 2 * PAR_FLOPS {
            self.prefill_blocks_sequential(phi_q, phi_k, v, delta, block, scratch, out);
        } else {
            self.prefill_blocks_parallel(phi_q, phi_k, v, delta, block, n_blocks, scratch, out);
        }
    }

    /// Sequential block loop: inter + intra + state update per block, in
    /// order. Used for small prefills (including every decode-sized chunk)
    /// and when threading is disabled.
    #[allow(clippy::too_many_arguments)]
    fn prefill_blocks_sequential(
        &mut self,
        phi_q: MatView,
        phi_k: MatView,
        v: MatView,
        delta: f32,
        block: usize,
        scratch: &mut Scratch,
        out: MatViewMut,
    ) {
        let l = phi_q.rows();
        let mut scores_buf = scratch.take(block * block);
        let mut den_buf = scratch.take(block);
        let mut rest = out;
        let mut r0 = 0;
        while r0 < l {
            let r1 = (r0 + block).min(l);
            let nb = r1 - r0;
            let (o_b, tail) = rest.split_rows_at(nb);
            rest = tail;
            let q_b = phi_q.row_block(r0, r1);
            let k_b = phi_k.row_block(r0, r1);
            let v_b = v.row_block(r0, r1);
            let s_view = MatView::new(&self.s, self.m, self.d_v);
            block_output(q_b, k_b, v_b, s_view, &self.z, delta, &mut scores_buf, &mut den_buf, o_b);
            // absorb the block into the running state
            self.extend(k_b, v_b);
            r0 = r1;
        }
        scratch.put(den_buf);
        scratch.put(scores_buf);
    }

    /// Three-phase parallel form: (1) all `Ψ(K_b)ᵀV_b` contractions fan
    /// out across threads, (2) a serial exclusive prefix-sum turns them
    /// into per-block prefix states (folding in this state's existing
    /// `(S, z)` and leaving the final totals behind), (3) per-block
    /// outputs fan out again.
    #[allow(clippy::too_many_arguments)]
    fn prefill_blocks_parallel(
        &mut self,
        phi_q: MatView,
        phi_k: MatView,
        v: MatView,
        delta: f32,
        block: usize,
        n_blocks: usize,
        scratch: &mut Scratch,
        out: MatViewMut,
    ) {
        let l = phi_q.rows();
        let (m, d_v) = (self.m, self.d_v);
        let su = m * d_v; // floats per block state
        let mut u_buf = scratch.take(n_blocks * su);
        let mut zeta_buf = scratch.take(n_blocks * m);

        // Phase 1: independent per-block contractions U_b = Ψ(K_b)ᵀV_b,
        // ζ_b = Ψ(K_b)ᵀ1 — contiguous ranges of blocks per thread. Thread
        // count is flops-proportional (PAR_FLOPS per spawn), like every
        // other threaded kernel, and divided across concurrently active
        // fan-outs so nested callers (per-head threads) share one budget.
        let guard = FanoutGuard::register();
        let active = guard.active();
        let flops = l * m * (block + 2 * d_v);
        let nt = (num_threads() / active)
            .max(1)
            .min(n_blocks)
            .min((flops / PAR_FLOPS).max(1));
        let per = n_blocks.div_ceil(nt);
        std::thread::scope(|s| {
            let mut u_rest: &mut [f32] = &mut u_buf;
            let mut z_rest: &mut [f32] = &mut zeta_buf;
            let mut b0 = 0;
            while b0 < n_blocks {
                let take = per.min(n_blocks - b0);
                let (u_chunk, u_tail) = u_rest.split_at_mut(take * su);
                u_rest = u_tail;
                let (z_chunk, z_tail) = z_rest.split_at_mut(take * m);
                z_rest = z_tail;
                let start = b0;
                s.spawn(move || {
                    for bi in 0..take {
                        let b = start + bi;
                        let r0 = b * block;
                        let r1 = (r0 + block).min(l);
                        let k_b = phi_k.row_block(r0, r1);
                        let v_b = v.row_block(r0, r1);
                        let u = &mut u_chunk[bi * su..(bi + 1) * su];
                        // u is zeroed by the arena, so acc == assign here
                        matmul_at_b_acc_serial(k_b, v_b, MatViewMut::new(u, m, d_v));
                        colsum_into(k_b, 0, k_b.rows(), &mut z_chunk[bi * m..(bi + 1) * m]);
                    }
                });
                b0 += take;
            }
        });

        // Phase 2: serial exclusive prefix-sum — u_buf[b]/zeta_buf[b]
        // become the state *before* block b (seeded with this state's
        // current totals), and the carry becomes the post-prefill state.
        let mut carry_s = scratch.take(su);
        carry_s.copy_from_slice(&self.s);
        let mut carry_z = scratch.take(m);
        carry_z.copy_from_slice(&self.z);
        for b in 0..n_blocks {
            for (x, c) in u_buf[b * su..(b + 1) * su].iter_mut().zip(carry_s.iter_mut()) {
                let own = *x;
                *x = *c;
                *c += own;
            }
            for (x, c) in zeta_buf[b * m..(b + 1) * m].iter_mut().zip(carry_z.iter_mut()) {
                let own = *x;
                *x = *c;
                *c += own;
            }
        }
        self.s.copy_from_slice(&carry_s);
        self.z.copy_from_slice(&carry_z);
        self.len += l;
        scratch.put(carry_z);
        scratch.put(carry_s);

        // Phase 3: independent per-block outputs — inter via the prefix
        // state, intra via the masked B×B block; same block ranges per
        // thread as phase 1, each thread with its own score/den workspace.
        let mut work_buf = scratch.take(nt * (block * block + block));
        std::thread::scope(|s| {
            let u_all: &[f32] = &u_buf;
            let zeta_all: &[f32] = &zeta_buf;
            let mut out_rest = out;
            let mut work_rest: &mut [f32] = &mut work_buf;
            let mut b0 = 0;
            while b0 < n_blocks {
                let take = per.min(n_blocks - b0);
                let r0 = b0 * block;
                let r1 = (r0 + take * block).min(l);
                let (out_chunk, out_tail) = out_rest.split_rows_at(r1 - r0);
                out_rest = out_tail;
                let (wk, wk_tail) = work_rest.split_at_mut(block * block + block);
                work_rest = wk_tail;
                let start = b0;
                s.spawn(move || {
                    let (scores_buf, den_buf) = wk.split_at_mut(block * block);
                    let mut out_chunk = out_chunk;
                    for bi in 0..take {
                        let b = start + bi;
                        let rb0 = b * block;
                        let rb1 = (rb0 + block).min(l);
                        let nb = rb1 - rb0;
                        let (o_b, rest) = out_chunk.split_rows_at(nb);
                        out_chunk = rest;
                        let s_b = MatView::new(&u_all[b * su..(b + 1) * su], m, d_v);
                        let z_b = &zeta_all[b * m..(b + 1) * m];
                        block_output(
                            phi_q.row_block(rb0, rb1),
                            phi_k.row_block(rb0, rb1),
                            v.row_block(rb0, rb1),
                            s_b,
                            z_b,
                            delta,
                            scores_buf,
                            den_buf,
                            o_b,
                        );
                    }
                });
                b0 += take;
            }
        });
        scratch.put(work_buf);
        scratch.put(zeta_buf);
        scratch.put(u_buf);
    }

    /// Attend with one query-feature row, writing `d_v` outputs into `out`.
    pub fn query_into(&self, phi_q: &[f32], delta: f32, out: &mut [f32]) {
        debug_assert_eq!(phi_q.len(), self.m);
        debug_assert_eq!(out.len(), self.d_v);
        out.fill(0.0);
        for (j, &f) in phi_q.iter().enumerate() {
            if f != 0.0 {
                axpy(f, &self.s[j * self.d_v..(j + 1) * self.d_v], out);
            }
        }
        let den = dot(phi_q, &self.z) + delta;
        let inv = 1.0 / den;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }

    /// Convenience allocating variant.
    pub fn query(&self, phi_q: &[f32], delta: f32) -> Vec<f32> {
        let mut out = vec![0.0; self.d_v];
        self.query_into(phi_q, delta, &mut out);
        out
    }

    /// Bytes held by this state (capacity accounting for the coordinator).
    pub fn bytes(&self) -> usize {
        (self.s.len() + self.z.len()) * std::mem::size_of::<f32>()
    }
}

/// Analytic peak-workspace model (bytes) for one attention head at sequence
/// length `L` — drives the Fig. 2/21 memory series without having to OOM
/// the host for the quadratic mechanisms at 131K tokens.
pub fn workspace_bytes(linear_feature_dim: Option<usize>, l: usize, d: usize, d_v: usize) -> usize {
    let f = std::mem::size_of::<f32>();
    match linear_feature_dim {
        // scores L×L plus Q,K,V,Y
        None => f * (l * l + l * (2 * d + 2 * d_v)),
        // features 2·L×m, state m×(d_v+1), Q,K,V,Y
        Some(m) => f * (2 * l * m + m * (d_v + 1) + l * (2 * d + 2 * d_v)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
        Mat::randn(r, c, &mut Rng::new(seed))
    }

    /// Reference: explicit score matrix from features, then quadratic path.
    fn linear_via_quadratic(phi_q: &Mat, phi_k: &Mat, v: &Mat, causal: bool, delta: f32) -> Mat {
        let scores = crate::math::linalg::matmul_a_bt(phi_q, phi_k);
        quadratic_attention(&scores, v, causal, delta)
    }

    #[test]
    fn noncausal_linear_matches_explicit_scores() {
        let phi_q = rand_mat(12, 7, 71).map(|x| x.abs()); // nonneg features
        let phi_k = rand_mat(12, 7, 72).map(|x| x.abs());
        let v = rand_mat(12, 5, 73);
        let fast = linear_attention_noncausal(&phi_q, &phi_k, &v, 1e-6);
        let slow = linear_via_quadratic(&phi_q, &phi_k, &v, false, 1e-6);
        for (a, b) in fast.data.iter().zip(slow.data.iter()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn causal_linear_matches_masked_quadratic() {
        let phi_q = rand_mat(16, 6, 74).map(|x| x.abs());
        let phi_k = rand_mat(16, 6, 75).map(|x| x.abs());
        let v = rand_mat(16, 4, 76);
        let fast = linear_attention_causal(&phi_q, &phi_k, &v, 1e-6);
        let slow = linear_via_quadratic(&phi_q, &phi_k, &v, true, 1e-6);
        for (a, b) in fast.data.iter().zip(slow.data.iter()) {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn causal_first_token_attends_only_itself() {
        let phi_q = rand_mat(4, 3, 77).map(|x| x.abs() + 0.1);
        let phi_k = phi_q.clone();
        let v = rand_mat(4, 2, 78);
        let y = linear_attention_causal(&phi_q, &phi_k, &v, 0.0);
        // Y_0 = (φq0·φk0 v0)/(φq0·φk0) = v0
        for c in 0..2 {
            assert!((y.get(0, c) - v.get(0, c)).abs() < 1e-5);
        }
    }

    #[test]
    fn streaming_state_equals_batch_causal() {
        let phi_q = rand_mat(20, 8, 79).map(|x| x.abs());
        let phi_k = rand_mat(20, 8, 80).map(|x| x.abs());
        let v = rand_mat(20, 6, 81);
        let batch = linear_attention_causal(&phi_q, &phi_k, &v, 1e-6);
        let mut state = StreamingState::new(8, 6);
        for i in 0..20 {
            state.append(phi_k.row(i), v.row(i));
            let y = state.query(phi_q.row(i), 1e-6);
            for c in 0..6 {
                assert!((y[c] - batch.get(i, c)).abs() < 1e-4, "tok {i} col {c}");
            }
        }
        assert_eq!(state.len, 20);
    }

    #[test]
    fn chunked_extend_equals_append_loop() {
        let phi_k = rand_mat(24, 5, 82).map(|x| x.abs());
        let v = rand_mat(24, 3, 83);
        let mut s1 = StreamingState::new(5, 3);
        for i in 0..24 {
            s1.append(phi_k.row(i), v.row(i));
        }
        let mut s2 = StreamingState::new(5, 3);
        // two chunks, taken as zero-copy row-range views
        let (top, bot) = phi_k.view().split_rows(10);
        let (vt, vb) = v.view().split_rows(10);
        s2.extend(top, vt);
        s2.extend(bot, vb);
        for (a, b) in s1.s.iter().zip(s2.s.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
        for (a, b) in s1.z.iter().zip(s2.z.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn colsum_matches_transpose_times_ones() {
        let m = rand_mat(9, 5, 70);
        let z = colsum(&m);
        for c in 0..5 {
            let want: f32 = (0..9).map(|r| m.get(r, c)).sum();
            assert!((z[c] - want).abs() < 1e-5, "col {c}: {} vs {want}", z[c]);
        }
        // range accumulation composes
        let mut z2 = vec![0.0f32; 5];
        colsum_into(&m, 0, 4, &mut z2);
        colsum_into(&m, 4, 9, &mut z2);
        for (a, b) in z.iter().zip(z2.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn quadratic_rows_are_convex_combinations() {
        // With nonneg scores and δ→0, each output row is a convex combination
        // of value rows ⇒ stays within [min, max] per column.
        let scores = rand_mat(10, 10, 84).map(|x| x.abs());
        let v = rand_mat(10, 3, 85);
        let y = quadratic_attention(&scores, &v, false, 0.0);
        for c in 0..3 {
            let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
            for r in 0..10 {
                lo = lo.min(v.get(r, c));
                hi = hi.max(v.get(r, c));
            }
            for r in 0..10 {
                let x = y.get(r, c);
                assert!(x >= lo - 1e-4 && x <= hi + 1e-4);
            }
        }
    }

    #[test]
    fn engines_into_strided_output_match_allocating_path() {
        // Writing through a column block of a packed output must be
        // bit-identical to the allocating entry points.
        let phi_q = rand_mat(14, 6, 87).map(|x| x.abs());
        let phi_k = rand_mat(14, 6, 88).map(|x| x.abs());
        let v = rand_mat(14, 4, 89);
        for causal in [false, true] {
            let want = linear_attention(&phi_q, &phi_k, &v, causal, 1e-6);
            let mut packed = Mat::zeros(14, 10);
            let (_, rest) = packed.view_mut().split_cols_at(3);
            let (block, _) = rest.split_cols_at(4);
            linear_attention_into(phi_q.view(), phi_k.view(), v.view(), causal, 1e-6, block);
            for r in 0..14 {
                assert_eq!(&packed.row(r)[3..7], want.row(r), "causal={causal} row {r}");
            }
        }
        let scores = rand_mat(14, 14, 90).map(|x| x.abs());
        let want = quadratic_attention(&scores, &v, true, 1e-6);
        let mut packed = Mat::zeros(14, 6);
        let (block, _) = packed.view_mut().split_cols_at(4);
        quadratic_attention_into(scores.view(), v.view(), true, 1e-6, block);
        for r in 0..14 {
            assert_eq!(&packed.row(r)[..4], want.row(r), "row {r}");
        }
    }

    #[test]
    fn chunked_causal_matches_per_token_across_blocks() {
        // ADR-003 invariant: every block size (B=1, tiny, L-divisor,
        // non-divisor, B=L, B>L) reproduces the per-token reference.
        let phi_q = rand_mat(33, 7, 101).map(|x| x.abs());
        let phi_k = rand_mat(33, 7, 102).map(|x| x.abs());
        let v = rand_mat(33, 5, 103);
        let want = linear_attention_causal(&phi_q, &phi_k, &v, 1e-6);
        for block in [1usize, 3, 8, 11, 33, 40] {
            let got = linear_attention_causal_chunked(&phi_q, &phi_k, &v, 1e-6, block);
            for (i, (a, b)) in got.data.iter().zip(want.data.iter()).enumerate() {
                assert!(
                    (a - b).abs() < 1e-4 * (1.0 + b.abs()),
                    "block {block} elem {i}: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn chunked_prefill_continues_existing_state() {
        // Two prefill_chunked_into calls over a split sequence must equal
        // the one-shot per-token causal pass — the serving-continuation
        // contract.
        let phi_q = rand_mat(20, 6, 104).map(|x| x.abs());
        let phi_k = rand_mat(20, 6, 105).map(|x| x.abs());
        let v = rand_mat(20, 4, 106);
        let want = linear_attention_causal(&phi_q, &phi_k, &v, 1e-6);
        let mut st = StreamingState::new(6, 4);
        let mut scratch = Scratch::new();
        let mut got = Mat::zeros(20, 4);
        let split = 13;
        let (top, bot) = got.view_mut().split_rows_at(split);
        st.prefill_chunked_into(
            phi_q.view().row_block(0, split),
            phi_k.view().row_block(0, split),
            v.view().row_block(0, split),
            1e-6,
            5,
            &mut scratch,
            top,
        );
        st.prefill_chunked_into(
            phi_q.view().row_block(split, 20),
            phi_k.view().row_block(split, 20),
            v.view().row_block(split, 20),
            1e-6,
            5,
            &mut scratch,
            bot,
        );
        assert_eq!(st.len, 20);
        for (i, (a, b)) in got.data.iter().zip(want.data.iter()).enumerate() {
            assert!((a - b).abs() < 1e-4 * (1.0 + b.abs()), "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn chunked_parallel_path_matches_sequential() {
        // Force a size that crosses the parallel threshold (l·m·(B+2d_v)
        // ≥ 2·PAR_FLOPS at B=16) and check it against the per-token
        // reference — exercises the 3-phase fan-out when threads exist.
        let phi_q = rand_mat(300, 48, 107).map(|x| x.abs());
        let phi_k = rand_mat(300, 48, 108).map(|x| x.abs());
        let v = rand_mat(300, 24, 109);
        let want = linear_attention_causal(&phi_q, &phi_k, &v, 1e-6);
        let got = linear_attention_causal_chunked(&phi_q, &phi_k, &v, 1e-6, 16);
        for (i, (a, b)) in got.data.iter().zip(want.data.iter()).enumerate() {
            assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "elem {i}: {a} vs {b}");
        }
    }

    #[test]
    fn workspace_model_orders_mechanisms_correctly() {
        // quadratic blows past linear once L·L dominates L·m.
        let m = 384;
        let quad_small = workspace_bytes(None, 256, 64, 64);
        let lin_small = workspace_bytes(Some(m), 256, 64, 64);
        assert!(quad_small < lin_small); // short L: features cost more
        let quad_big = workspace_bytes(None, 32_768, 64, 64);
        let lin_big = workspace_bytes(Some(m), 32_768, 64, 64);
        assert!(quad_big > 10 * lin_big); // long L: quadratic explodes
    }

    #[test]
    fn zero_features_yield_finite_outputs() {
        // δ stabilizer prevents 0/0 (Higham-style guard from §2.5).
        let phi = Mat::zeros(3, 4);
        let v = rand_mat(3, 2, 86);
        let y = linear_attention_noncausal(&phi, &phi, &v, 1e-6);
        assert!(y.data.iter().all(|x| x.is_finite()));
        let yc = linear_attention_causal(&phi, &phi, &v, 1e-6);
        assert!(yc.data.iter().all(|x| x.is_finite()));
    }
}
