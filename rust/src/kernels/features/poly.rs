//! Approximations of the degree-2 polynomial kernel `k(x,y) = (xᵀy)²`
//! (§2.4.2, Table 1, Appendix C).
//!
//! * [`PolyExact`] — `vec(uuᵀ)`: exact, d² features, nonnegative inner
//!   products.
//! * [`Anchor`] — `P^{−1/2}[(xᵀaᵢ)²]`: biased low-rank, **nonnegative**
//!   inner products, the paper's default.
//! * [`Nystrom`] — `K_xA (K_AA + λI)^{−1/2}`: low-rank, whitened, signed.
//! * [`RandomMaclaurin`] — `P^{−1/2}[(rᵢᵀx)(sᵢᵀx)]` with Rademacher `r,s`:
//!   unbiased, signed, high variance at small P.
//! * [`TensorSketch`] — count-sketch of `x ⊗ x` via FFT: near-unbiased,
//!   signed.

use super::FeatureMap;
use crate::math::fft::{circular_convolve, next_pow2};
use crate::math::linalg::{
    dot, matmul_a_bt, matmul_a_bt_into, matmul_into, Mat, MatView, MatViewMut,
};
use crate::math::rng::Rng;

// ---------------------------------------------------------------------------

/// Exact feature map `φ(u) = vec(uuᵀ) ∈ R^{d²}`.
pub struct PolyExact {
    d: usize,
}

impl PolyExact {
    pub fn new(d: usize) -> Self {
        PolyExact { d }
    }
}

impl FeatureMap for PolyExact {
    fn input_dim(&self) -> usize {
        self.d
    }

    fn dim(&self) -> usize {
        self.d * self.d
    }

    fn map_into(&self, x: MatView, _pos0: usize, mut out: MatViewMut) {
        assert_eq!(x.cols(), self.d);
        for r in 0..x.rows() {
            let row = x.row(r);
            let orow = out.row_mut(r);
            for i in 0..self.d {
                for j in 0..self.d {
                    orow[i * self.d + j] = row[i] * row[j];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------

/// Shared anchor set: `P` unit-norm reference directions drawn N(0, I_d)
/// then normalized (anchors live where the data lives — the unit sphere).
pub fn draw_anchors(p: usize, d: usize, rng: &mut Rng) -> Mat {
    Mat::randn(p, d, rng).normalized_rows()
}

/// Anchor features `φ(x) = P^{−1/2} [(xᵀaᵢ)²]_{i=1..P}` (§2.4.2) — the
/// paper's default polynomial approximation: not unbiased, but every
/// coordinate (hence every induced inner product) is nonnegative, which is
/// what the denominator-positivity guarantee needs.
pub struct Anchor {
    anchors: Mat, // P × d
    scale: f32,   // 1/√P
}

impl Anchor {
    pub fn new(p: usize, d: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        Anchor { anchors: draw_anchors(p, d, &mut rng), scale: 1.0 / (p as f32).sqrt() }
    }

    pub fn from_anchors(anchors: Mat) -> Self {
        let p = anchors.rows;
        Anchor { anchors, scale: 1.0 / (p as f32).sqrt() }
    }

    /// Data-driven anchors: sample `p` rows of `data` (normalized). Rank-P
    /// approximations of `(xᵀy)²` are markedly tighter when anchors live
    /// where the tokens live; the serving coordinator uses this for its
    /// calibrated SLAY variant.
    pub fn from_data(data: &Mat, p: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut anchors = Mat::zeros(p, data.cols);
        for i in 0..p {
            let r = rng.below(data.rows.max(1));
            anchors.row_mut(i).copy_from_slice(data.row(r));
        }
        anchors.normalize_rows();
        Anchor::from_anchors(anchors)
    }
}

impl FeatureMap for Anchor {
    fn input_dim(&self) -> usize {
        self.anchors.cols
    }

    fn dim(&self) -> usize {
        self.anchors.rows
    }

    fn map_into(&self, x: MatView, _pos0: usize, mut out: MatViewMut) {
        matmul_a_bt_into(x, self.anchors.view(), out.reborrow()); // L × P of xᵀaᵢ
        let square = crate::math::simd::kernels().square_scale;
        for r in 0..out.rows() {
            square(out.row_mut(r), self.scale);
        }
    }
}

// ---------------------------------------------------------------------------

/// Nystrom features `φ(x) = K_xA (K_AA + λI)^{−1/2}` over the squared-dot
/// kernel (Appendix C). Whitening makes the Gram approximation tighter when
/// anchors are well-conditioned but the whitened coordinates are signed.
pub struct Nystrom {
    anchors: Mat,   // P × d
    whitener: Mat,  // P × P = (K_AA + λI)^{−1/2}
}

impl Nystrom {
    pub fn new(p: usize, d: usize, ridge: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let anchors = draw_anchors(p, d, &mut rng);
        let mut kaa = matmul_a_bt(&anchors, &anchors);
        for v in kaa.data.iter_mut() {
            *v = *v * *v; // (aᵢᵀaⱼ)²
        }
        for i in 0..p {
            let x = kaa.get(i, i) + ridge as f32;
            kaa.set(i, i, x);
        }
        let whitener = crate::math::eigen::inv_sqrt_psd(&kaa, 1e-10);
        Nystrom { anchors, whitener }
    }
}

impl FeatureMap for Nystrom {
    fn input_dim(&self) -> usize {
        self.anchors.cols
    }

    fn dim(&self) -> usize {
        self.anchors.rows
    }

    fn map_into(&self, x: MatView, _pos0: usize, out: MatViewMut) {
        // whitening needs the full K_xA panel as a second operand, so this
        // map keeps one internal temporary (not on the zero-alloc path).
        let mut kxa = matmul_a_bt(x, &self.anchors);
        for v in kxa.data.iter_mut() {
            *v = *v * *v;
        }
        matmul_into(kxa.view(), self.whitener.view(), out);
    }
}

// ---------------------------------------------------------------------------

/// Random Maclaurin features `φ(x) = P^{−1/2}[(rᵢᵀx)(sᵢᵀx)]` with
/// iid Rademacher `rᵢ, sᵢ` (Kar & Karnick 2012): unbiased for `(xᵀy)²`,
/// signed, variance-dominated at small P (Table 2/6 show the blow-up).
pub struct RandomMaclaurin {
    r: Mat, // P × d
    s: Mat, // P × d
    scale: f32,
}

impl RandomMaclaurin {
    pub fn new(p: usize, d: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut r = Mat::zeros(p, d);
        let mut s = Mat::zeros(p, d);
        for i in 0..p {
            r.row_mut(i).copy_from_slice(&rng.rademacher_vec(d));
            s.row_mut(i).copy_from_slice(&rng.rademacher_vec(d));
        }
        RandomMaclaurin { r, s, scale: 1.0 / (p as f32).sqrt() }
    }
}

impl FeatureMap for RandomMaclaurin {
    fn input_dim(&self) -> usize {
        self.r.cols
    }

    fn dim(&self) -> usize {
        self.r.rows
    }

    fn map_into(&self, x: MatView, _pos0: usize, mut out: MatViewMut) {
        matmul_a_bt_into(x, self.r.view(), out.reborrow());
        let ps = matmul_a_bt(x, &self.s); // second Rademacher panel
        for r in 0..out.rows() {
            for (o, &b) in out.row_mut(r).iter_mut().zip(ps.row(r)) {
                *o = *o * b * self.scale;
            }
        }
    }
}

// ---------------------------------------------------------------------------

/// TensorSketch (Pham & Pagh 2013) of the degree-2 tensor `x ⊗ x`:
/// two independent count-sketches circularly convolved via FFT. `dim` is
/// rounded up to a power of two internally.
pub struct TensorSketch {
    d_in: usize,
    d_out: usize,
    h1: Vec<usize>,
    h2: Vec<usize>,
    s1: Vec<f32>,
    s2: Vec<f32>,
}

impl TensorSketch {
    pub fn new(d_out: usize, d_in: usize, seed: u64) -> Self {
        let d_out = next_pow2(d_out.max(2));
        let mut rng = Rng::new(seed);
        let h1 = (0..d_in).map(|_| rng.below(d_out)).collect();
        let h2 = (0..d_in).map(|_| rng.below(d_out)).collect();
        let s1 = rng.rademacher_vec(d_in);
        let s2 = rng.rademacher_vec(d_in);
        TensorSketch { d_in, d_out, h1, h2, s1, s2 }
    }

    fn count_sketch(&self, row: &[f32], h: &[usize], s: &[f32]) -> Vec<f64> {
        let mut cs = vec![0.0f64; self.d_out];
        for (i, &v) in row.iter().enumerate() {
            cs[h[i]] += (s[i] * v) as f64;
        }
        cs
    }
}

impl FeatureMap for TensorSketch {
    fn input_dim(&self) -> usize {
        self.d_in
    }

    fn dim(&self) -> usize {
        self.d_out
    }

    fn map_into(&self, x: MatView, _pos0: usize, mut out: MatViewMut) {
        for r in 0..x.rows() {
            let row = x.row(r);
            let c1 = self.count_sketch(row, &self.h1, &self.s1);
            let c2 = self.count_sketch(row, &self.h2, &self.s2);
            let conv = circular_convolve(&c1, &c2);
            for (o, v) in out.row_mut(r).iter_mut().zip(conv.iter()) {
                *o = *v as f32;
            }
        }
    }
}

// ---------------------------------------------------------------------------

/// Build a polynomial feature map from a [`PolyMethod`](crate::kernels::config::PolyMethod).
pub fn build_poly(
    method: crate::kernels::config::PolyMethod,
    n_poly: usize,
    d: usize,
    ridge: f64,
    seed: u64,
) -> Box<dyn FeatureMap> {
    use crate::kernels::config::PolyMethod as P;
    match method {
        P::Exact => Box::new(PolyExact::new(d)),
        P::Anchor => Box::new(Anchor::new(n_poly, d, seed)),
        P::Nystrom => Box::new(Nystrom::new(n_poly, d, ridge, seed)),
        P::TensorSketch => Box::new(TensorSketch::new(n_poly, d, seed)),
        P::RandomMaclaurin => Box::new(RandomMaclaurin::new(n_poly, d, seed)),
    }
}

/// Estimated kernel value `⟨φ(x), φ(y)⟩` for two single rows (test helper
/// and Fig. 13 probe).
pub fn kernel_estimate(map: &dyn FeatureMap, x: &[f32], y: &[f32]) -> f32 {
    let mx = map.map(MatView::from_row(x), 0);
    let my = map.map(MatView::from_row(y), 0);
    dot(mx.row(0), my.row(0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::stats::Welford;

    fn unit(rng: &mut Rng, d: usize) -> Vec<f32> {
        Mat::randn(1, d, rng).normalized_rows().data
    }

    #[test]
    fn exact_map_reconstructs_squared_dot() {
        let mut rng = Rng::new(41);
        let d = 6;
        let m = PolyExact::new(d);
        for _ in 0..20 {
            let x = unit(&mut rng, d);
            let y = unit(&mut rng, d);
            let want = dot(&x, &y).powi(2);
            let got = kernel_estimate(&m, &x, &y);
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn anchor_inner_products_nonnegative() {
        let mut rng = Rng::new(42);
        let m = Anchor::new(8, 12, 7);
        for _ in 0..100 {
            let x = unit(&mut rng, 12);
            let y = unit(&mut rng, 12);
            assert!(kernel_estimate(&m, &x, &y) >= 0.0);
        }
    }

    #[test]
    fn random_maclaurin_unbiased() {
        // Average over many independent draws converges to (xᵀy)².
        let mut rng = Rng::new(43);
        let d = 8;
        let x = unit(&mut rng, d);
        let y = unit(&mut rng, d);
        let want = dot(&x, &y).powi(2);
        let mut w = Welford::default();
        for seed in 0..300 {
            let m = RandomMaclaurin::new(16, d, seed);
            w.push(kernel_estimate(&m, &x, &y) as f64);
        }
        let se = w.std() / (w.n as f64).sqrt();
        assert!(
            (w.mean() - want as f64).abs() < 4.0 * se + 1e-3,
            "mean={} want={} se={}",
            w.mean(),
            want,
            se
        );
    }

    #[test]
    fn tensor_sketch_approximately_unbiased() {
        let mut rng = Rng::new(44);
        let d = 8;
        let x = unit(&mut rng, d);
        let y = unit(&mut rng, d);
        let want = dot(&x, &y).powi(2) as f64;
        let mut w = Welford::default();
        for seed in 0..300 {
            let m = TensorSketch::new(32, d, seed);
            w.push(kernel_estimate(&m, &x, &y) as f64);
        }
        let se = w.std() / (w.n as f64).sqrt();
        assert!((w.mean() - want).abs() < 4.0 * se + 1e-3, "mean={} want={want}", w.mean());
    }

    #[test]
    fn tensor_sketch_exact_self_norm() {
        // CS preserves ‖x⊗x‖ in expectation; check it is at least finite & sane.
        let m = TensorSketch::new(64, 4, 5);
        let x = vec![0.5f32, -0.5, 0.5, -0.5];
        let est = kernel_estimate(&m, &x, &x);
        assert!(est.is_finite());
    }

    #[test]
    fn nystrom_matches_exact_when_anchors_span() {
        // With P ≫ d² and small ridge, the Nystrom approximation of the
        // rank-d(d+1)/2 kernel should be close on the anchors' span.
        let mut rng = Rng::new(45);
        let d = 4;
        let m = Nystrom::new(32, d, 1e-6, 11);
        let mut errs = 0.0;
        let mut n = 0;
        for _ in 0..30 {
            let x = unit(&mut rng, d);
            let y = unit(&mut rng, d);
            let want = dot(&x, &y).powi(2);
            let got = kernel_estimate(&m, &x, &y);
            errs += (got - want).abs() as f64;
            n += 1;
        }
        assert!(errs / (n as f64) < 0.05, "mean abs err {}", errs / n as f64);
    }

    #[test]
    fn signed_maps_do_produce_negative_estimates() {
        // Appendix L.2: TensorSketch / RM can go negative — the failure mode
        // SLAY's positivity-preserving default avoids.
        let mut rng = Rng::new(46);
        let d = 8;
        for (name, m) in [
            ("ts", Box::new(TensorSketch::new(8, d, 3)) as Box<dyn FeatureMap>),
            ("rm", Box::new(RandomMaclaurin::new(4, d, 3)) as Box<dyn FeatureMap>),
        ] {
            let mut saw_negative = false;
            for _ in 0..500 {
                let x = unit(&mut rng, d);
                let y = unit(&mut rng, d);
                if kernel_estimate(m.as_ref(), &x, &y) < 0.0 {
                    saw_negative = true;
                    break;
                }
            }
            assert!(saw_negative, "{name} never went negative in 500 draws");
        }
    }

    #[test]
    fn maps_are_deterministic_given_seed() {
        let a = Anchor::new(8, 6, 123);
        let b = Anchor::new(8, 6, 123);
        let x = Mat::from_vec(1, 6, vec![0.1, -0.2, 0.3, 0.4, -0.5, 0.6]);
        assert_eq!(a.map(x.view(), 0).data, b.map(x.view(), 0).data);
    }

    #[test]
    fn build_poly_dispatch_dims() {
        use crate::kernels::config::PolyMethod as P;
        let d = 6;
        for (method, want_dim) in [
            (P::Exact, 36),
            (P::Anchor, 8),
            (P::Nystrom, 8),
            (P::TensorSketch, 8),
            (P::RandomMaclaurin, 8),
        ] {
            let m = build_poly(method, 8, d, 1e-3, 1);
            assert_eq!(m.dim(), want_dim, "{method:?}");
            assert_eq!(m.input_dim(), d);
            let x = Mat::randn(3, d, &mut Rng::new(9)).normalized_rows();
            let f = m.map(x.view(), 0);
            assert_eq!((f.rows, f.cols), (3, want_dim));
        }
    }
}
