//! Feature maps used to linearize attention kernels.
//!
//! * [`poly`] — the five approximations of the degree-2 polynomial factor
//!   `(q̂ᵀk̂)²` (Table 1 / Appendix C): exact `vec(uuᵀ)`, anchor, Nystrom,
//!   TensorSketch, Random Maclaurin.
//! * [`prf`] — positive random features for `e^{2s·q̂ᵀk̂}` (Eq. 9) plus the
//!   FAVOR+ ReLU features, the ELU+1 map and cosformer's positional
//!   reweighting used by the baseline mechanisms.

pub mod poly;
pub mod prf;

use crate::math::linalg::{Mat, MatView, MatViewMut};

/// A map from token rows to feature rows. Implementations must be
/// deterministic given their construction-time seed so that Q and K paths
/// share identical randomness.
///
/// Inputs arrive as strided [`MatView`]s (ADR-002): a head's column block,
/// a chunk's row range, or a single decode row wrapped via
/// [`MatView::from_row`] all map without being copied into an owned `Mat`
/// first. Outputs are written through a strided [`MatViewMut`]
/// (ADR-003) — typically a buffer recycled from a per-worker
/// [`Scratch`](crate::math::linalg::Scratch) arena — with [`FeatureMap::map`]
/// as the allocating convenience wrapper.
pub trait FeatureMap: Send + Sync {
    /// Input (model/head) dimension.
    fn input_dim(&self) -> usize;
    /// Output feature dimension.
    fn dim(&self) -> usize;
    /// Map each row of `x` (shape `L × input_dim`) into `out`
    /// (`L × dim`, possibly strided), overwriting every element. `pos0` is
    /// the absolute position of row 0 — only position-dependent maps
    /// (cosformer) read it. This is the *contiguous* special case of
    /// [`FeatureMap::map_rows_into`]: row `r` sits at position `pos0 + r`.
    fn map_into(&self, x: MatView, pos0: usize, out: MatViewMut);
    /// Whether the map reads token positions (`pos0` / `positions`).
    /// Position-independent maps (the default) may batch rows from
    /// different sequences at different positions through one call.
    fn position_dependent(&self) -> bool {
        false
    }
    /// Map a stacked block of rows where row `r` sits at its *own*
    /// absolute position `positions[r]` — the fused cross-session decode
    /// entry (ADR-005): B queued decode tokens from B different sequences
    /// map as one `B × input_dim` batch. Position-independent maps inherit
    /// this default (one batched call — the point of the fusion, and
    /// bit-identical per row because every kernel underneath is
    /// row-independent); any map that returns `true` from
    /// [`FeatureMap::position_dependent`] MUST override it with true
    /// per-row position handling (the default asserts that contract).
    fn map_rows_into(&self, x: MatView, positions: &[usize], out: MatViewMut) {
        debug_assert_eq!(x.rows(), positions.len());
        assert!(
            !self.position_dependent(),
            "position-dependent feature maps must override map_rows_into"
        );
        self.map_into(x, 0, out);
    }
    /// Allocating wrapper over [`FeatureMap::map_into`].
    fn map(&self, x: MatView, pos0: usize) -> Mat {
        let mut out = Mat::zeros(x.rows(), self.dim());
        self.map_into(x, pos0, out.view_mut());
        out
    }
}

/// Dispatchable boxed feature map.
pub type BoxedMap = Box<dyn FeatureMap>;

/// Kronecker product of two feature rows — the explicit tensor-product
/// fusion of Eq. 10 (`φ_poly ⊗ φ_PRF`), producing `|a|·|b|` features.
pub fn kron_row(a: &[f32], b: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), a.len() * b.len());
    let nb = b.len();
    for (i, &ai) in a.iter().enumerate() {
        let chunk = &mut out[i * nb..(i + 1) * nb];
        for (o, &bj) in chunk.iter_mut().zip(b.iter()) {
            *o = ai * bj;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kron_row_matches_definition() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0, 5.0];
        let mut out = [0.0f32; 6];
        kron_row(&a, &b, &mut out);
        assert_eq!(out, [3.0, 4.0, 5.0, 6.0, 8.0, 10.0]);
    }

    #[test]
    fn kron_inner_product_factorizes() {
        // ⟨a⊗b, c⊗d⟩ = ⟨a,c⟩·⟨b,d⟩ — the identity Eq. 10 relies on.
        let a = [0.5f32, -1.0, 2.0];
        let b = [1.5f32, 0.25];
        let c = [2.0f32, 1.0, -0.5];
        let d = [0.1f32, -0.7];
        let mut ab = [0.0f32; 6];
        let mut cd = [0.0f32; 6];
        kron_row(&a, &b, &mut ab);
        kron_row(&c, &d, &mut cd);
        let lhs: f32 = ab.iter().zip(&cd).map(|(x, y)| x * y).sum();
        let ac: f32 = a.iter().zip(&c).map(|(x, y)| x * y).sum();
        let bd: f32 = b.iter().zip(&d).map(|(x, y)| x * y).sum();
        assert!((lhs - ac * bd).abs() < 1e-5);
    }
}
