//! Exponential-kernel random features and the baseline mechanisms' maps.
//!
//! * [`Prf`] — strictly positive random features for `e^{2s·q̂ᵀk̂}` on the
//!   unit sphere (Eq. 9, Choromanski et al. 2021):
//!   `φ(u; s) = D^{−1/2} exp(√(2s)·ωᵀu − s)`, `ω ~ N(0, I_d)`.
//! * [`FavorSoftmax`] — Performer's positive softmax features (general-norm
//!   variant with the `−‖u‖²/2` correction).
//! * [`FavorRelu`] — Performer FAVOR+ ReLU features (Table 9 baseline).
//! * [`EluPlusOne`] — the `elu(x)+1` map of linear attention.
//! * [`CosformerMap`] — ReLU features with cos/sin positional reweighting
//!   (Qin et al. 2022).

use super::FeatureMap;
use crate::math::linalg::{matmul_a_bt_into, Mat, MatView, MatViewMut};
use crate::math::rng::Rng;
use crate::math::simd;

/// Positive random features for the spherical exponential kernel at scale
/// `s` (Eq. 9). **Unbiased only for unit-norm inputs** (Prop. 2) — the SLAY
/// pipeline normalizes upstream.
pub struct Prf {
    omega: Mat, // D × d
    s: f64,
    scale: f32, // 1/√D
}

impl Prf {
    pub fn new(d_features: usize, d: usize, s: f64, rng: &mut Rng) -> Self {
        Self::from_omega(Mat::randn(d_features, d, rng), s)
    }

    /// Build from an explicit projection matrix (golden-file replay: the
    /// Python oracle exports its ω draws so both implementations share the
    /// same randomness).
    pub fn from_omega(omega: Mat, s: f64) -> Self {
        let d_features = omega.rows;
        Prf { omega, s, scale: 1.0 / (d_features as f32).sqrt() }
    }
}

impl FeatureMap for Prf {
    fn input_dim(&self) -> usize {
        self.omega.cols
    }

    fn dim(&self) -> usize {
        self.omega.rows
    }

    fn map_into(&self, x: MatView, _pos0: usize, mut out: MatViewMut) {
        let sqrt2s = (2.0 * self.s).sqrt() as f32;
        let s = self.s as f32;
        matmul_a_bt_into(x, self.omega.view(), out.reborrow()); // L × D of ωᵢᵀu
        let exp = simd::kernels().exp_affine_scale;
        for r in 0..out.rows() {
            exp(out.row_mut(r), sqrt2s, -s, self.scale);
        }
    }
}

/// Performer positive softmax features for general (non-unit) inputs:
/// `φ(u) = D^{−1/2} exp(ωᵀu − ‖u‖²/2)`, unbiased for `e^{uᵀv}`.
pub struct FavorSoftmax {
    /// `ω / d^{1/4}` — softmax attention applies `exp(qᵀk/√d)`, and the
    /// standard Performer split of that `1/√d` as `q/d^{1/4}`, `k/d^{1/4}`
    /// is folded into the projection at construction, so `map` never
    /// materializes a scaled copy of its input.
    omega: Mat,
    scale: f32,
}

impl FavorSoftmax {
    pub fn new(d_features: usize, d: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut omega = Mat::randn(d_features, d, &mut rng);
        omega.scale(1.0 / (d as f32).powf(0.25));
        FavorSoftmax { omega, scale: 1.0 / (d_features as f32).sqrt() }
    }
}

impl FeatureMap for FavorSoftmax {
    fn input_dim(&self) -> usize {
        self.omega.cols
    }

    fn dim(&self) -> usize {
        self.omega.rows
    }

    fn map_into(&self, x: MatView, _pos0: usize, mut out: MatViewMut) {
        // ωᵀ(u/d^{1/4}) via the pre-scaled projection; the Gaussian-norm
        // correction uses ‖u/d^{1/4}‖² = ‖u‖²/√d straight off the raw row.
        let inv_sqrt_d = 1.0 / (x.cols() as f32).sqrt();
        matmul_a_bt_into(x, self.omega.view(), out.reborrow());
        let exp = simd::kernels().exp_affine_scale;
        for r in 0..out.rows() {
            let n2: f32 = x.row(r).iter().map(|v| v * v).sum::<f32>() * inv_sqrt_d;
            exp(out.row_mut(r), 1.0, -0.5 * n2, self.scale);
        }
    }
}

/// FAVOR+ ReLU random features (the Table 9 Performer baseline):
/// `φ(u) = D^{−1/2} relu(ωᵀu)`.
pub struct FavorRelu {
    omega: Mat,
    scale: f32,
}

impl FavorRelu {
    pub fn new(d_features: usize, d: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        FavorRelu {
            omega: Mat::randn(d_features, d, &mut rng),
            scale: 1.0 / (d_features as f32).sqrt(),
        }
    }
}

impl FeatureMap for FavorRelu {
    fn input_dim(&self) -> usize {
        self.omega.cols
    }

    fn dim(&self) -> usize {
        self.omega.rows
    }

    fn map_into(&self, x: MatView, _pos0: usize, mut out: MatViewMut) {
        matmul_a_bt_into(x, self.omega.view(), out.reborrow());
        let relu = simd::kernels().relu_scale;
        for r in 0..out.rows() {
            relu(out.row_mut(r), self.scale);
        }
    }
}

/// `elu(x) + 1` feature map (Katharopoulos et al. linear attention;
/// "Linear (ELU+1)" rows of Tables 3/5/8). Identity dimension.
pub struct EluPlusOne {
    d: usize,
}

impl EluPlusOne {
    pub fn new(d: usize) -> Self {
        EluPlusOne { d }
    }
}

impl FeatureMap for EluPlusOne {
    fn input_dim(&self) -> usize {
        self.d
    }

    fn dim(&self) -> usize {
        self.d
    }

    fn map_into(&self, x: MatView, _pos0: usize, mut out: MatViewMut) {
        // out[i] = elu(x[i]) + 1, i.e. x+1 for x>0 and exp(x) below.
        let elu = simd::kernels().elu_plus_one;
        for r in 0..x.rows() {
            elu(x.row(r), out.row_mut(r));
        }
    }
}

/// Cosformer features (Qin et al. 2022): nonneg `relu(x)` reweighted by
/// `cos(π i / 2M)` and `sin(π i / 2M)` where `i` is the absolute token
/// position and `M` a fixed horizon. The concatenated two-channel feature
/// realizes `cos(π(i−j)/2M)`-reweighted ReLU attention as a pure dot
/// product, keeping linearity.
pub struct CosformerMap {
    d: usize,
    /// Positional horizon M (max sequence length the map supports).
    pub horizon: usize,
}

impl CosformerMap {
    pub fn new(d: usize, horizon: usize) -> Self {
        assert!(horizon > 0);
        CosformerMap { d, horizon }
    }

    /// Map one token row at absolute position `pos` — the single code path
    /// both the contiguous (`map_into`) and per-row-position
    /// (`map_rows_into`) entries go through, so a fused cross-session
    /// decode block is bit-identical to mapping each row on its own.
    #[inline]
    fn map_row(&self, row: &[f32], pos: usize, orow: &mut [f32]) {
        let m = self.horizon as f32;
        let i = pos.min(self.horizon - 1) as f32;
        let theta = std::f32::consts::FRAC_PI_2 * i / m;
        let (sin_t, cos_t) = theta.sin_cos();
        for c in 0..self.d {
            let relu = row[c].max(0.0);
            orow[c] = relu * cos_t;
            orow[self.d + c] = relu * sin_t;
        }
    }
}

impl FeatureMap for CosformerMap {
    fn input_dim(&self) -> usize {
        self.d
    }

    fn dim(&self) -> usize {
        2 * self.d
    }

    fn position_dependent(&self) -> bool {
        true
    }

    fn map_into(&self, x: MatView, pos0: usize, mut out: MatViewMut) {
        for r in 0..x.rows() {
            self.map_row(x.row(r), pos0 + r, out.row_mut(r));
        }
    }

    fn map_rows_into(&self, x: MatView, positions: &[usize], mut out: MatViewMut) {
        debug_assert_eq!(x.rows(), positions.len());
        for r in 0..x.rows() {
            self.map_row(x.row(r), positions[r], out.row_mut(r));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::dot;
    use crate::math::stats::Welford;

    fn unit(rng: &mut Rng, d: usize) -> Vec<f32> {
        Mat::randn(1, d, rng).normalized_rows().data
    }

    #[test]
    fn prf_features_strictly_positive() {
        let mut rng = Rng::new(51);
        let mut prf_rng = Rng::new(52);
        let prf = Prf::new(32, 8, 0.7, &mut prf_rng);
        let x = Mat::randn(10, 8, &mut rng).normalized_rows();
        let f = prf.map(x.view(), 0);
        assert!(f.data.iter().all(|&v| v > 0.0 && v.is_finite()));
    }

    #[test]
    fn prf_unbiased_for_exponential_kernel_prop2() {
        // E[⟨φ(q̂;s), φ(k̂;s)⟩] = e^{2s·q̂ᵀk̂} on the sphere.
        let mut rng = Rng::new(53);
        let d = 8;
        let s = 0.5;
        let q = unit(&mut rng, d);
        let k = unit(&mut rng, d);
        let x = dot(&q, &k) as f64;
        let want = (2.0 * s * x).exp();
        let mut w = Welford::default();
        for seed in 0..400 {
            let mut r = Rng::new(seed + 1000);
            let prf = Prf::new(16, d, s, &mut r);
            let fq = prf.map(MatView::from_row(&q), 0);
            let fk = prf.map(MatView::from_row(&k), 0);
            w.push(dot(fq.row(0), fk.row(0)) as f64);
        }
        let se = w.std() / (w.n as f64).sqrt();
        assert!(
            (w.mean() - want).abs() < 4.0 * se + 1e-3,
            "mean={} want={want} se={se}",
            w.mean()
        );
    }

    #[test]
    fn favor_softmax_unbiased_for_exp_dot() {
        let mut rng = Rng::new(54);
        let d = 4;
        // small-norm inputs keep variance low
        let q: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.5).collect();
        let k: Vec<f32> = (0..d).map(|_| rng.normal_f32() * 0.5).collect();
        let scale = 1.0 / (d as f32).sqrt();
        let want = (dot(&q, &k) * scale).exp() as f64;
        let mut w = Welford::default();
        for seed in 0..600 {
            let m = FavorSoftmax::new(32, d, seed);
            let fq = m.map(MatView::from_row(&q), 0);
            let fk = m.map(MatView::from_row(&k), 0);
            w.push(dot(fq.row(0), fk.row(0)) as f64);
        }
        let se = w.std() / (w.n as f64).sqrt();
        assert!((w.mean() - want).abs() < 5.0 * se + 2e-3, "mean={} want={want}", w.mean());
    }

    #[test]
    fn elu_plus_one_positive_and_smooth() {
        let m = EluPlusOne::new(3);
        let x = Mat::from_vec(2, 3, vec![-5.0, 0.0, 5.0, -0.1, 0.1, 100.0]);
        let f = m.map(x.view(), 0);
        assert!(f.data.iter().all(|&v| v > 0.0));
        assert!((f.get(0, 1) - 1.0).abs() < 1e-6); // elu(0)+1 = 1
        assert!((f.get(0, 2) - 6.0).abs() < 1e-6); // x+1 for x>0
        // continuity at 0
        let eps = m.map(Mat::from_vec(1, 3, vec![1e-6, -1e-6, 0.0]).view(), 0);
        assert!((eps.get(0, 0) - eps.get(0, 1)).abs() < 1e-5);
    }

    #[test]
    fn cosformer_realizes_cos_reweighting() {
        // ⟨φ_i(q), φ_j(k)⟩ = relu(q)ᵀrelu(k) · cos(π(i−j)/2M)
        let d = 4;
        let m = CosformerMap::new(d, 64);
        let q = Mat::from_vec(1, d, vec![0.5, -0.3, 0.8, 0.1]);
        let k = Mat::from_vec(1, d, vec![0.2, 0.9, -0.4, 0.6]);
        let i = 10;
        let j = 3;
        let fq = m.map(q.view(), i);
        let fk = m.map(k.view(), j);
        let got = dot(fq.row(0), fk.row(0));
        let relu_dot: f32 = q
            .row(0)
            .iter()
            .zip(k.row(0))
            .map(|(a, b)| a.max(0.0) * b.max(0.0))
            .sum();
        let want = relu_dot
            * (std::f32::consts::FRAC_PI_2 * (i as f32 - j as f32) / 64.0).cos();
        assert!((got - want).abs() < 1e-5, "{got} vs {want}");
    }

    #[test]
    fn cosformer_clamps_beyond_horizon() {
        let m = CosformerMap::new(2, 8);
        let x = Mat::from_vec(1, 2, vec![1.0, 1.0]);
        let f_at = |p: usize| m.map(x.view(), p).data.clone();
        assert_eq!(f_at(7), f_at(20)); // positions past M−1 clamp
    }

    #[test]
    fn cosformer_map_rows_matches_per_row_positions() {
        // The fused cross-session entry: each row maps at its OWN absolute
        // position (different sequences at different lengths), bit-identical
        // to mapping the rows one at a time.
        let d = 3;
        let m = CosformerMap::new(d, 32);
        let x = Mat::randn(4, d, &mut Rng::new(56));
        let positions = [7usize, 0, 19, 40]; // scattered; 40 clamps past M−1
        let mut fused = Mat::zeros(4, m.dim());
        m.map_rows_into(x.view(), &positions, fused.view_mut());
        for (r, &p) in positions.iter().enumerate() {
            let want = m.map(x.view().row_block(r, r + 1), p);
            assert_eq!(fused.row(r), want.row(0), "row {r} at pos {p}");
        }
    }

    #[test]
    fn position_independent_map_rows_ignores_positions() {
        // Position-independent maps inherit the batched default: one call,
        // bit-identical to map_into regardless of the positions vector.
        let mut rng = Rng::new(57);
        let prf = Prf::new(16, 8, 0.5, &mut rng);
        assert!(!prf.position_dependent());
        let x = Mat::randn(5, 8, &mut Rng::new(58)).normalized_rows();
        let want = prf.map(x.view(), 0);
        let mut fused = Mat::zeros(5, prf.dim());
        prf.map_rows_into(x.view(), &[3, 99, 0, 7, 12], fused.view_mut());
        assert_eq!(fused.data, want.data);
    }

    #[test]
    fn favor_relu_nonnegative() {
        let m = FavorRelu::new(16, 8, 3);
        let x = Mat::randn(5, 8, &mut Rng::new(55));
        let f = m.map(x.view(), 0);
        assert!(f.data.iter().all(|&v| v >= 0.0));
        assert_eq!(f.cols, 16);
    }
}
