//! Configuration types for every attention mechanism in the paper's
//! evaluation (Table 9) and for the SLAY estimator's internal knobs
//! (Appendix I: R, D, P/D_p, fusion, stabilizers).

/// How the degree-2 polynomial factor `(q̂ᵀk̂)²` is approximated (§2.4.2,
/// Table 1, Appendix C).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolyMethod {
    /// Exact `vec(uuᵀ)` map — d² features, unbiased, nonnegative.
    Exact,
    /// Anchor features `P^{-1/2}[(xᵀaᵢ)²]` — biased low-rank, nonnegative.
    /// **Paper default.**
    Anchor,
    /// Nystrom features `K_xA (K_AA+λI)^{−1/2}` — signed.
    Nystrom,
    /// TensorSketch (count-sketch + FFT) — unbiased-ish, signed.
    TensorSketch,
    /// Random Maclaurin Rademacher products — unbiased, signed.
    RandomMaclaurin,
}

impl PolyMethod {
    /// Does the induced approximate inner product stay nonnegative?
    /// (Table 1's last column; drives the denominator-positivity guarantee.)
    pub fn positivity_preserving(self) -> bool {
        matches!(self, PolyMethod::Exact | PolyMethod::Anchor)
    }

    /// Unbiased for `(xᵀy)²`? (Table 1.)
    pub fn unbiased(self) -> bool {
        matches!(self, PolyMethod::Exact | PolyMethod::RandomMaclaurin)
    }

    pub fn name(self) -> &'static str {
        match self {
            PolyMethod::Exact => "exact",
            PolyMethod::Anchor => "anchor",
            PolyMethod::Nystrom => "nystrom",
            PolyMethod::TensorSketch => "tensorsketch",
            PolyMethod::RandomMaclaurin => "random_maclaurin",
        }
    }

    /// Inverse of [`PolyMethod::name`] (registry keys; `rm` is accepted as
    /// shorthand for `random_maclaurin`).
    pub fn parse(s: &str) -> anyhow::Result<PolyMethod> {
        Ok(match s {
            "exact" => PolyMethod::Exact,
            "anchor" => PolyMethod::Anchor,
            "nystrom" => PolyMethod::Nystrom,
            "tensorsketch" => PolyMethod::TensorSketch,
            "random_maclaurin" | "rm" => PolyMethod::RandomMaclaurin,
            other => anyhow::bail!("unknown poly method '{other}'"),
        })
    }
}

/// How the per-node polynomial × exponential features are fused (Eq. 10,
/// Appendix F "Hadamard fusion", Appendix I "explicit tensor product").
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fusion {
    /// Explicit Kronecker product — `D_p·D` features per node; preserves
    /// positivity when both factors do. Default.
    Explicit,
    /// TensorSketch of the Kronecker product to `d_t` dims — saves memory,
    /// signed (accuracy/efficiency baseline).
    Sketch { d_t: usize },
    /// Elementwise product (requires `D_p == D`) — biased kernel (App. F),
    /// fast baseline.
    Hadamard,
    /// Drop the polynomial factor entirely and use the exact Laplace-only
    /// identity with affine correction (App. F): signed estimator, no
    /// positivity guarantee. `φ = PRF only`, correction applied in the
    /// attention engine.
    LaplaceOnly,
}

impl Fusion {
    pub fn name(self) -> &'static str {
        match self {
            Fusion::Explicit => "explicit",
            Fusion::Sketch { .. } => "sketch",
            Fusion::Hadamard => "hadamard",
            Fusion::LaplaceOnly => "laplace_only",
        }
    }

    /// Full registry spelling, including the sketch dimension
    /// (`sketch:64`). Round-trips through [`Fusion::parse`].
    pub fn spec(self) -> String {
        match self {
            Fusion::Sketch { d_t } => format!("sketch:{d_t}"),
            other => other.name().to_string(),
        }
    }

    /// Inverse of [`Fusion::spec`].
    pub fn parse(s: &str) -> anyhow::Result<Fusion> {
        Ok(match s {
            "explicit" => Fusion::Explicit,
            "hadamard" => Fusion::Hadamard,
            "laplace_only" => Fusion::LaplaceOnly,
            other => {
                if let Some(dt) = other.strip_prefix("sketch:") {
                    Fusion::Sketch { d_t: dt.parse()? }
                } else {
                    anyhow::bail!("unknown fusion '{other}'")
                }
            }
        })
    }
}

/// Full SLAY estimator configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SlayConfig {
    /// Yat-kernel stabilizer ε (paper: 1e-3 for Yat family).
    pub eps: f64,
    /// Attention-denominator stabilizer δ (Eq. 11).
    pub delta: f32,
    /// Gauss–Laguerre node count R (paper default 3, App. L.3).
    pub r_nodes: usize,
    /// Polynomial approximation method (default anchor).
    pub poly: PolyMethod,
    /// Anchor count P / polynomial feature dim D_p.
    pub n_poly: usize,
    /// PRF feature count D per node.
    pub d_prf: usize,
    /// Fusion operator.
    pub fusion: Fusion,
    /// RNG seed for anchors / ω draws (deterministic features).
    pub seed: u64,
    /// Nystrom ridge λ.
    pub nystrom_ridge: f64,
}

impl Default for SlayConfig {
    fn default() -> Self {
        // Matches Table 9: ε=1e-3, M_PRF=16, M_Poly=8, with R=3 (App. L.3).
        SlayConfig {
            eps: 1e-3,
            delta: 1e-6,
            r_nodes: 3,
            poly: PolyMethod::Anchor,
            n_poly: 8,
            d_prf: 16,
            fusion: Fusion::Explicit,
            seed: 42,
            nystrom_ridge: 1e-3,
        }
    }
}

impl SlayConfig {
    /// `C = 2 + ε` (Eq. 4).
    pub fn c(&self) -> f64 {
        2.0 + self.eps
    }

    /// Total feature dimension m after concatenating R nodes (App. I).
    pub fn feature_dim(&self, d_model: usize) -> usize {
        let d_p = match self.poly {
            PolyMethod::Exact => d_model * d_model,
            _ => self.n_poly,
        };
        let per_node = match self.fusion {
            Fusion::Explicit => d_p * self.d_prf,
            Fusion::Sketch { d_t } => d_t,
            Fusion::Hadamard => d_p, // requires d_p == d_prf
            Fusion::LaplaceOnly => self.d_prf,
        };
        per_node * self.r_nodes
    }

    /// Whether this configuration carries the paper's strict-positivity
    /// guarantee (App. G): positive poly map + explicit/hadamard fusion.
    pub fn positivity_guaranteed(&self) -> bool {
        self.poly.positivity_preserving()
            && matches!(self.fusion, Fusion::Explicit | Fusion::Hadamard)
    }

    pub fn with_poly(mut self, poly: PolyMethod) -> Self {
        self.poly = poly;
        self
    }

    pub fn with_fusion(mut self, fusion: Fusion) -> Self {
        self.fusion = fusion;
        self
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.eps <= 0.0 {
            anyhow::bail!("eps must be positive (Bernstein representation needs C−2x ≥ ε > 0)");
        }
        if self.r_nodes == 0 || self.r_nodes > 64 {
            anyhow::bail!("r_nodes must be in 1..=64, got {}", self.r_nodes);
        }
        if self.d_prf == 0 || self.n_poly == 0 {
            anyhow::bail!("feature counts must be positive");
        }
        if matches!(self.fusion, Fusion::Hadamard) && self.n_poly != self.d_prf {
            anyhow::bail!(
                "hadamard fusion requires n_poly == d_prf (got {} vs {})",
                self.n_poly,
                self.d_prf
            );
        }
        if let Fusion::Sketch { d_t } = self.fusion {
            if !d_t.is_power_of_two() {
                anyhow::bail!("sketch dim d_t must be a power of two (FFT), got {d_t}");
            }
        }
        Ok(())
    }
}

/// The attention mechanisms compared throughout the paper (Fig. 2, Tables
/// 2–8; Table 9 configs).
///
/// The string-keyed registry is the single construction path shared by the
/// CLI, run configs and bench harnesses: [`Mechanism::parse`] accepts
/// either a bare name (`"slay"`, Table 9 defaults) or a parameterized spec
/// (`"slay:n_poly=16,d_prf=64"`, `"yat:eps=0.01"`, `"favor:m=128,seed=7"`)
/// and round-trips with the [`std::fmt::Display`] implementation.
#[derive(Clone, Debug, PartialEq)]
pub enum Mechanism {
    /// Standard softmax attention — quadratic.
    Standard,
    /// Exact Yat (E-product on raw q,k) — quadratic.
    Yat { eps: f64 },
    /// Exact spherical Yat — quadratic.
    YatSpherical { eps: f64 },
    /// SLAY — linear.
    Slay(SlayConfig),
    /// Performer FAVOR+ (ReLU random features, M=64; Table 9) — linear.
    Favor { m_features: usize, seed: u64 },
    /// Linear attention with ELU+1 feature map — linear.
    EluLinear,
    /// Cosformer (Qin et al. 2022): ReLU features with cos/sin positional
    /// reweighting — linear.
    Cosformer,
}

impl Mechanism {
    pub fn name(&self) -> &'static str {
        match self {
            Mechanism::Standard => "standard",
            Mechanism::Yat { .. } => "yat",
            Mechanism::YatSpherical { .. } => "yat_spherical",
            Mechanism::Slay(_) => "slay",
            Mechanism::Favor { .. } => "favor",
            Mechanism::EluLinear => "elu_linear",
            Mechanism::Cosformer => "cosformer",
        }
    }

    pub fn is_linear(&self) -> bool {
        matches!(
            self,
            Mechanism::Slay(_)
                | Mechanism::Favor { .. }
                | Mechanism::EluLinear
                | Mechanism::Cosformer
        )
    }

    /// Table 9 defaults by bare name (the registry's base entries).
    pub fn from_name(name: &str) -> anyhow::Result<Mechanism> {
        Ok(match name {
            "standard" | "softmax" => Mechanism::Standard,
            "yat" => Mechanism::Yat { eps: 1e-3 },
            "yat_spherical" | "spherical" => Mechanism::YatSpherical { eps: 1e-3 },
            "slay" => Mechanism::Slay(SlayConfig::default()),
            "favor" | "performer" => Mechanism::Favor { m_features: 64, seed: 42 },
            "elu_linear" | "linear" => Mechanism::EluLinear,
            "cosformer" => Mechanism::Cosformer,
            other => anyhow::bail!("unknown mechanism '{other}'"),
        })
    }

    /// Parse a registry spec: `name[:key=value,...]`. The bare name selects
    /// Table 9 defaults; keys override individual knobs. Examples:
    ///
    /// * `slay:n_poly=16,d_prf=64,poly=exact`
    /// * `slay:fusion=sketch:128`
    /// * `yat_spherical:eps=0.01`
    /// * `favor:m=128,seed=7`
    pub fn parse(spec: &str) -> anyhow::Result<Mechanism> {
        let (name, params) = match spec.split_once(':') {
            Some((n, p)) => (n, p),
            None => (spec, ""),
        };
        let mut mech = Mechanism::from_name(name)?;
        for kv in params.split(',').filter(|s| !s.is_empty()) {
            let (key, val) = kv
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("expected key=value, got '{kv}' in '{spec}'"))?;
            match &mut mech {
                Mechanism::Standard | Mechanism::EluLinear | Mechanism::Cosformer => {
                    anyhow::bail!("mechanism '{name}' takes no parameters (got '{key}')")
                }
                Mechanism::Yat { eps } | Mechanism::YatSpherical { eps } => match key {
                    "eps" => *eps = val.parse()?,
                    other => anyhow::bail!("unknown key '{other}' for '{name}'"),
                },
                Mechanism::Favor { m_features, seed } => match key {
                    "m" | "m_features" => *m_features = val.parse()?,
                    "seed" => *seed = val.parse()?,
                    other => anyhow::bail!("unknown key '{other}' for '{name}'"),
                },
                Mechanism::Slay(cfg) => match key {
                    "eps" => cfg.eps = val.parse()?,
                    "delta" => cfg.delta = val.parse()?,
                    "r_nodes" | "r" => cfg.r_nodes = val.parse()?,
                    "n_poly" | "p" => cfg.n_poly = val.parse()?,
                    "d_prf" | "d" => cfg.d_prf = val.parse()?,
                    "poly" => cfg.poly = PolyMethod::parse(val)?,
                    "fusion" => cfg.fusion = Fusion::parse(val)?,
                    "seed" => cfg.seed = val.parse()?,
                    "nystrom_ridge" => cfg.nystrom_ridge = val.parse()?,
                    other => anyhow::bail!("unknown key '{other}' for '{name}'"),
                },
            }
        }
        if let Mechanism::Slay(cfg) = &mech {
            cfg.validate()?;
        }
        Ok(mech)
    }
}

impl std::fmt::Display for Mechanism {
    /// Canonical registry spec — round-trips through [`Mechanism::parse`].
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Mechanism::Standard => write!(f, "standard"),
            Mechanism::EluLinear => write!(f, "elu_linear"),
            Mechanism::Cosformer => write!(f, "cosformer"),
            Mechanism::Yat { eps } => write!(f, "yat:eps={eps}"),
            Mechanism::YatSpherical { eps } => write!(f, "yat_spherical:eps={eps}"),
            Mechanism::Favor { m_features, seed } => write!(f, "favor:m={m_features},seed={seed}"),
            Mechanism::Slay(c) => {
                write!(
                    f,
                    "slay:poly={},fusion={},r_nodes={},n_poly={},d_prf={},eps={},delta={},seed={}",
                    c.poly.name(),
                    c.fusion.spec(),
                    c.r_nodes,
                    c.n_poly,
                    c.d_prf,
                    c.eps,
                    c.delta,
                    c.seed
                )?;
                if c.poly == PolyMethod::Nystrom {
                    write!(f, ",nystrom_ridge={}", c.nystrom_ridge)?;
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_table9() {
        let c = SlayConfig::default();
        assert_eq!(c.eps, 1e-3);
        assert_eq!(c.n_poly, 8);
        assert_eq!(c.d_prf, 16);
        assert_eq!(c.r_nodes, 3);
        assert!(c.positivity_guaranteed());
        c.validate().unwrap();
    }

    #[test]
    fn feature_dims() {
        let c = SlayConfig::default();
        assert_eq!(c.feature_dim(64), 3 * 8 * 16);
        let c2 = c.clone().with_fusion(Fusion::Sketch { d_t: 64 });
        assert_eq!(c2.feature_dim(64), 3 * 64);
        let c3 = SlayConfig { poly: PolyMethod::Exact, ..SlayConfig::default() };
        assert_eq!(c3.feature_dim(4), 3 * 16 * 16);
    }

    #[test]
    fn positivity_table_matches_table1() {
        assert!(PolyMethod::Exact.positivity_preserving());
        assert!(PolyMethod::Anchor.positivity_preserving());
        assert!(!PolyMethod::Nystrom.positivity_preserving());
        assert!(!PolyMethod::TensorSketch.positivity_preserving());
        assert!(!PolyMethod::RandomMaclaurin.positivity_preserving());
        assert!(PolyMethod::Exact.unbiased());
        assert!(PolyMethod::RandomMaclaurin.unbiased());
        assert!(!PolyMethod::Anchor.unbiased());
    }

    #[test]
    fn validation_catches_bad_configs() {
        assert!(SlayConfig { eps: 0.0, ..Default::default() }.validate().is_err());
        assert!(SlayConfig { r_nodes: 0, ..Default::default() }.validate().is_err());
        let bad_had = SlayConfig {
            fusion: Fusion::Hadamard,
            n_poly: 8,
            d_prf: 16,
            ..Default::default()
        };
        assert!(bad_had.validate().is_err());
        let bad_sketch = SlayConfig {
            fusion: Fusion::Sketch { d_t: 100 },
            ..Default::default()
        };
        assert!(bad_sketch.validate().is_err());
    }

    #[test]
    fn mechanism_names_roundtrip() {
        for name in
            ["standard", "yat", "yat_spherical", "slay", "favor", "elu_linear", "cosformer"]
        {
            let m = Mechanism::from_name(name).unwrap();
            assert_eq!(m.name(), name);
        }
        assert!(Mechanism::from_name("bogus").is_err());
    }

    #[test]
    fn parse_specs_override_defaults() {
        let m = Mechanism::parse("slay:n_poly=16,d_prf=64,poly=exact").unwrap();
        let Mechanism::Slay(c) = m else { panic!("expected slay") };
        assert_eq!(c.n_poly, 16);
        assert_eq!(c.d_prf, 64);
        assert_eq!(c.poly, PolyMethod::Exact);
        assert_eq!(c.r_nodes, SlayConfig::default().r_nodes);

        assert_eq!(
            Mechanism::parse("yat:eps=0.01").unwrap(),
            Mechanism::Yat { eps: 0.01 }
        );
        assert_eq!(
            Mechanism::parse("favor:m=128,seed=7").unwrap(),
            Mechanism::Favor { m_features: 128, seed: 7 }
        );
        // bare names still select Table 9 defaults
        assert_eq!(Mechanism::parse("standard").unwrap(), Mechanism::Standard);
        // the sketch fusion dim nests a ':' inside the value
        let m = Mechanism::parse("slay:fusion=sketch:128").unwrap();
        let Mechanism::Slay(c) = m else { panic!("expected slay") };
        assert_eq!(c.fusion, Fusion::Sketch { d_t: 128 });
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(Mechanism::parse("standard:eps=1").is_err());
        assert!(Mechanism::parse("slay:bogus=1").is_err());
        assert!(Mechanism::parse("slay:n_poly").is_err());
        assert!(Mechanism::parse("yat:eps=abc").is_err());
        // parameterized configs still go through validation
        assert!(Mechanism::parse("slay:r_nodes=0").is_err());
        assert!(Mechanism::parse("slay:fusion=sketch:100").is_err());
    }

    #[test]
    fn display_roundtrips_through_parse() {
        let mechs = [
            Mechanism::Standard,
            Mechanism::EluLinear,
            Mechanism::Cosformer,
            Mechanism::Yat { eps: 0.05 },
            Mechanism::YatSpherical { eps: 1e-3 },
            Mechanism::Favor { m_features: 48, seed: 9 },
            Mechanism::Slay(SlayConfig::default()),
            Mechanism::Slay(SlayConfig {
                poly: PolyMethod::Nystrom,
                n_poly: 12,
                d_prf: 24,
                nystrom_ridge: 0.01,
                ..Default::default()
            }),
            Mechanism::Slay(SlayConfig {
                fusion: Fusion::Sketch { d_t: 64 },
                ..Default::default()
            }),
        ];
        for m in mechs {
            let spec = m.to_string();
            let back = Mechanism::parse(&spec).unwrap();
            assert_eq!(back, m, "spec '{spec}' did not round-trip");
        }
    }
}
