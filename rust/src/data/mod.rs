//! Data substrates: the §3.3 synthetic task suite, the synthetic LM corpus
//! (Table 5 / Fig. 3), and the Eurlex-4K extreme-classification simulator
//! (Table 4). All generators are deterministic given a seed.

pub mod corpus;
pub mod eurlex;
pub mod tasks;
