//! The synthetic task suite of §3.3 / Tables 7-8: 22 sequence-modeling
//! tasks grouped into Basic, Memory, Long-Range, Reasoning, Arithmetic,
//! Pattern, Robustness and Aggregation categories.
//!
//! Every task emits `(tokens, targets)` pairs in the LM training format of
//! the AOT `train_step` artifacts: `tokens[t]` is the input stream and
//! `targets[t]` the supervised next-token label at position `t`
//! (−1 = unsupervised position). Layout per example:
//!
//! ```text
//! [input … input SEP answer … answer PAD …]
//! ```
//!
//! with supervision only on the answer span (the position *before* each
//! answer token predicts it), so accuracy measures the capability rather
//! than input copying.

use crate::math::rng::Rng;

/// Reserved control tokens at the top of the vocabulary.
pub const PAD: i32 = 0;
pub const SEP: i32 = 1;
/// First usable data token.
pub const DATA0: i32 = 4;

/// One supervised example.
#[derive(Clone, Debug)]
pub struct Example {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

/// Task category (Table 7).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Category {
    Basic,
    Memory,
    LongRange,
    Reasoning,
    Arithmetic,
    Pattern,
    Robustness,
    Aggregation,
}

impl Category {
    pub fn name(self) -> &'static str {
        match self {
            Category::Basic => "basic",
            Category::Memory => "memory",
            Category::LongRange => "long_range",
            Category::Reasoning => "reasoning",
            Category::Arithmetic => "arithmetic",
            Category::Pattern => "pattern",
            Category::Robustness => "robustness",
            Category::Aggregation => "aggregation",
        }
    }
}

/// Task identifier — all 22 tasks of Table 8.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    Copy,
    Sort,
    Reverse,
    Retrieval,
    KvRecall,
    FirstToken,
    SelectiveCopy,
    LongCopy,
    DistantMatch,
    Multihop,
    Stack,
    Induction,
    Pattern,
    Counting,
    Parity,
    Addition,
    Modular,
    Bigram,
    Majority,
    NoisyCopy,
    Compression,
    Histogram,
}

pub const ALL_TASKS: [Task; 22] = [
    Task::Copy,
    Task::Sort,
    Task::Reverse,
    Task::Retrieval,
    Task::KvRecall,
    Task::FirstToken,
    Task::SelectiveCopy,
    Task::LongCopy,
    Task::DistantMatch,
    Task::Multihop,
    Task::Stack,
    Task::Induction,
    Task::Pattern,
    Task::Counting,
    Task::Parity,
    Task::Addition,
    Task::Modular,
    Task::Bigram,
    Task::Majority,
    Task::NoisyCopy,
    Task::Compression,
    Task::Histogram,
];

impl Task {
    pub fn name(self) -> &'static str {
        match self {
            Task::Copy => "copy",
            Task::Sort => "sort",
            Task::Reverse => "reverse",
            Task::Retrieval => "retrieval",
            Task::KvRecall => "kv_recall",
            Task::FirstToken => "first_token",
            Task::SelectiveCopy => "selective_copy",
            Task::LongCopy => "long_copy",
            Task::DistantMatch => "distant_match",
            Task::Multihop => "multihop",
            Task::Stack => "stack",
            Task::Induction => "induction",
            Task::Pattern => "pattern",
            Task::Counting => "counting",
            Task::Parity => "parity",
            Task::Addition => "addition",
            Task::Modular => "modular",
            Task::Bigram => "bigram",
            Task::Majority => "majority",
            Task::NoisyCopy => "noisy_copy",
            Task::Compression => "compression",
            Task::Histogram => "histogram",
        }
    }

    pub fn category(self) -> Category {
        match self {
            Task::Copy | Task::Sort | Task::Reverse => Category::Basic,
            Task::Retrieval | Task::KvRecall | Task::FirstToken | Task::SelectiveCopy => {
                Category::Memory
            }
            Task::LongCopy | Task::DistantMatch | Task::Multihop => Category::LongRange,
            Task::Stack | Task::Induction | Task::Pattern => Category::Reasoning,
            Task::Counting | Task::Parity | Task::Addition | Task::Modular => {
                Category::Arithmetic
            }
            Task::Bigram | Task::Majority => Category::Pattern,
            Task::NoisyCopy | Task::Compression => Category::Robustness,
            Task::Histogram => Category::Aggregation,
        }
    }

    pub fn from_name(name: &str) -> Option<Task> {
        ALL_TASKS.iter().copied().find(|t| t.name() == name)
    }
}

/// Task generator bound to a (vocab, seq_len) model shape.
pub struct TaskGen {
    pub vocab: usize,
    pub seq_len: usize,
}

impl TaskGen {
    pub fn new(vocab: usize, seq_len: usize) -> Self {
        assert!(vocab >= 16, "need vocab ≥ 16 for the control tokens + data");
        assert!(seq_len >= 32, "need seq_len ≥ 32");
        TaskGen { vocab, seq_len }
    }

    /// Number of distinct data tokens available.
    fn n_data(&self) -> i32 {
        (self.vocab as i32 - DATA0).min(48)
    }

    fn rand_data(&self, rng: &mut Rng) -> i32 {
        DATA0 + rng.below(self.n_data() as usize) as i32
    }

    /// Assemble `[input… SEP answer…]` into fixed-length token/target rows.
    fn pack(&self, input: &[i32], answer: &[i32]) -> Example {
        let mut tokens = vec![PAD; self.seq_len];
        let mut targets = vec![-1i32; self.seq_len];
        let n_in = input.len().min(self.seq_len - answer.len() - 2);
        tokens[..n_in].copy_from_slice(&input[..n_in]);
        tokens[n_in] = SEP;
        // answer span: position (n_in + j) predicts answer[j] at
        // target index (n_in + j), given tokens up to and incl. that pos−1.
        for (j, &a) in answer.iter().enumerate() {
            let pos = n_in + 1 + j;
            if pos >= self.seq_len {
                break;
            }
            tokens[pos] = a;
            targets[pos - 1] = a;
        }
        Example { tokens, targets }
    }

    /// Generate one example of `task`.
    pub fn example(&self, task: Task, rng: &mut Rng) -> Example {
        let l = self.seq_len;
        match task {
            Task::Copy => {
                let n = 4 + rng.below(l / 4);
                let xs: Vec<i32> = (0..n).map(|_| self.rand_data(rng)).collect();
                self.pack(&xs, &xs.clone())
            }
            Task::LongCopy => {
                let n = l / 3;
                let xs: Vec<i32> = (0..n).map(|_| self.rand_data(rng)).collect();
                self.pack(&xs, &xs.clone())
            }
            Task::NoisyCopy => {
                // copy only the non-noise tokens; noise = token 2
                const NOISE: i32 = 2;
                let n = 4 + rng.below(l / 4);
                let mut xs = Vec::new();
                let mut clean = Vec::new();
                for _ in 0..n {
                    if rng.uniform() < 0.3 {
                        xs.push(NOISE);
                    } else {
                        let t = self.rand_data(rng);
                        xs.push(t);
                        clean.push(t);
                    }
                }
                if clean.is_empty() {
                    clean.push(self.rand_data(rng));
                    xs.push(clean[0]);
                }
                self.pack(&xs, &clean)
            }
            Task::Reverse => {
                let n = 4 + rng.below(l / 4);
                let xs: Vec<i32> = (0..n).map(|_| self.rand_data(rng)).collect();
                let mut rev = xs.clone();
                rev.reverse();
                self.pack(&xs, &rev)
            }
            Task::Sort => {
                let n = 4 + rng.below(l / 4);
                let xs: Vec<i32> = (0..n).map(|_| self.rand_data(rng)).collect();
                let mut sorted = xs.clone();
                sorted.sort_unstable();
                self.pack(&xs, &sorted)
            }
            Task::Retrieval => {
                // needle token appears once; answer = the token after it
                let n = l / 2;
                let mut xs: Vec<i32> = (0..n).map(|_| self.rand_data(rng)).collect();
                let needle = 3; // dedicated marker
                let pos = rng.below(n - 2);
                xs[pos] = needle;
                let answer = xs[pos + 1];
                let mut input = xs;
                input.push(needle); // query repeats the marker
                self.pack(&input, &[answer])
            }
            Task::KvRecall => {
                // pairs (k1 v1 k2 v2 …), query a key, answer its value
                let pairs = 4 + rng.below(l / 6);
                let mut input = Vec::new();
                let mut keys = Vec::new();
                let mut vals = Vec::new();
                for _ in 0..pairs {
                    let k = self.rand_data(rng);
                    let v = self.rand_data(rng);
                    input.push(k);
                    input.push(v);
                    keys.push(k);
                    vals.push(v);
                }
                let qi = rng.below(pairs);
                // last occurrence wins for duplicate keys
                let ans = keys
                    .iter()
                    .enumerate()
                    .filter(|(_, &k)| k == keys[qi])
                    .map(|(i, _)| vals[i])
                    .next_back()
                    .unwrap();
                input.push(keys[qi]);
                self.pack(&input, &[ans])
            }
            Task::FirstToken => {
                let n = 4 + rng.below(l / 2);
                let xs: Vec<i32> = (0..n).map(|_| self.rand_data(rng)).collect();
                let first = xs[0];
                self.pack(&xs, &[first])
            }
            Task::SelectiveCopy => {
                // copy tokens that are immediately preceded by marker 3
                let n = 6 + rng.below(l / 3);
                let mut xs = Vec::new();
                let mut sel = Vec::new();
                let mut i = 0;
                while i < n {
                    if rng.uniform() < 0.25 && i + 1 < n {
                        xs.push(3);
                        let t = self.rand_data(rng);
                        xs.push(t);
                        sel.push(t);
                        i += 2;
                    } else {
                        xs.push(self.rand_data(rng));
                        i += 1;
                    }
                }
                if sel.is_empty() {
                    xs.push(3);
                    let t = self.rand_data(rng);
                    xs.push(t);
                    sel.push(t);
                }
                self.pack(&xs, &sel)
            }
            Task::DistantMatch => {
                // answer = token right after SEP-distant first marker
                let n = l * 2 / 3;
                let mut xs: Vec<i32> = (0..n).map(|_| self.rand_data(rng)).collect();
                let marker = 3;
                xs[0] = marker;
                let answer = xs[1];
                xs[n - 1] = marker; // query marker far away
                self.pack(&xs, &[answer])
            }
            Task::Multihop => {
                // chain a→b, b→c; query a, answer c (two hops)
                let pairs = 5 + rng.below(6);
                let chain: Vec<i32> = {
                    let mut pool: Vec<i32> = (0..self.n_data()).map(|i| DATA0 + i).collect();
                    rng.shuffle(&mut pool);
                    pool.truncate(pairs + 2);
                    pool
                };
                let mut input = Vec::new();
                // links chain[i] -> chain[i+1], shuffled
                let mut links: Vec<(i32, i32)> =
                    chain.windows(2).map(|w| (w[0], w[1])).collect();
                rng.shuffle(&mut links);
                for (a, b) in &links {
                    input.push(*a);
                    input.push(*b);
                }
                input.push(chain[0]); // query head
                self.pack(&input, &[chain[2]]) // answer: two hops away
            }
            Task::Stack => {
                // push/pop stream; answer = final stack top.
                // push = marker 2 followed by token; pop = marker 3.
                let ops = 6 + rng.below(l / 4);
                let mut input = Vec::new();
                let mut stack: Vec<i32> = Vec::new();
                for _ in 0..ops {
                    if stack.is_empty() || rng.uniform() < 0.6 {
                        let t = self.rand_data(rng);
                        input.push(2);
                        input.push(t);
                        stack.push(t);
                    } else {
                        input.push(3);
                        stack.pop();
                    }
                }
                if stack.is_empty() {
                    let t = self.rand_data(rng);
                    input.push(2);
                    input.push(t);
                    stack.push(t);
                }
                self.pack(&input, &[*stack.last().unwrap()])
            }
            Task::Induction => {
                // classic induction head probe: …A B … A → B
                let n = l / 2;
                let mut xs: Vec<i32> = (0..n).map(|_| self.rand_data(rng)).collect();
                let a = self.rand_data(rng);
                let b = self.rand_data(rng);
                let pos = rng.below(n - 3);
                xs[pos] = a;
                xs[pos + 1] = b;
                // ensure `a` does not re-occur later with a different next
                for x in xs.iter_mut().skip(pos + 2) {
                    if *x == a {
                        *x = DATA0;
                    }
                }
                xs.push(a);
                self.pack(&xs, &[b])
            }
            Task::Pattern => {
                // periodic pattern continuation: abcabcab → c
                let period = 2 + rng.below(4);
                let motif: Vec<i32> = (0..period).map(|_| self.rand_data(rng)).collect();
                let reps = (l / 2) / period;
                let mut xs = Vec::new();
                for _ in 0..reps {
                    xs.extend_from_slice(&motif);
                }
                let next = motif[xs.len() % period];
                self.pack(&xs, &[next])
            }
            Task::Counting => {
                // count occurrences of marker 3, answer = count as token
                let n = 8 + rng.below(l / 2);
                let mut count = 0;
                let xs: Vec<i32> = (0..n)
                    .map(|_| {
                        if rng.uniform() < 0.2 && count < (self.n_data() - 1) as usize {
                            count += 1;
                            3
                        } else {
                            self.rand_data(rng)
                        }
                    })
                    .collect();
                self.pack(&xs, &[DATA0 + count as i32])
            }
            Task::Parity => {
                // parity of marker-3 count: answer token DATA0 (+1 if odd)
                let n = 8 + rng.below(l / 2);
                let mut ones = 0;
                let xs: Vec<i32> = (0..n)
                    .map(|_| {
                        if rng.uniform() < 0.5 {
                            ones += 1;
                            3
                        } else {
                            2
                        }
                    })
                    .collect();
                self.pack(&xs, &[DATA0 + (ones % 2)])
            }
            Task::Addition => {
                // digit addition: a + b (< n_data), digits as tokens
                let max = (self.n_data() / 2 - 1) as usize;
                let a = rng.below(max);
                let b = rng.below(max);
                let input = [DATA0 + a as i32, 2, DATA0 + b as i32];
                self.pack(&input, &[DATA0 + (a + b) as i32])
            }
            Task::Modular => {
                // (a + b) mod m with m = 7
                let m = 7usize;
                let a = rng.below(self.n_data() as usize);
                let b = rng.below(self.n_data() as usize);
                let input = [DATA0 + a as i32, 2, DATA0 + b as i32];
                self.pack(&input, &[DATA0 + ((a + b) % m) as i32])
            }
            Task::Bigram => {
                // stochastic bigram stream from a fixed per-example table;
                // answer = most likely successor of the query token
                let states = 4;
                let table: Vec<i32> =
                    (0..states).map(|_| self.rand_data(rng)).collect();
                let succ: Vec<i32> = (0..states).map(|_| self.rand_data(rng)).collect();
                let n = l / 2;
                let mut xs = Vec::new();
                for _ in 0..n / 2 {
                    let s = rng.below(states);
                    xs.push(table[s]);
                    xs.push(succ[s]);
                }
                let q = rng.below(states);
                xs.push(table[q]);
                self.pack(&xs, &[succ[q]])
            }
            Task::Majority => {
                // answer = most frequent token in the stream
                let n = 9 + rng.below(l / 2);
                let cands: Vec<i32> = (0..3).map(|_| self.rand_data(rng)).collect();
                let mut counts = [0usize; 3];
                let xs: Vec<i32> = (0..n)
                    .map(|_| {
                        let c = rng.below(3);
                        counts[c] += 1;
                        cands[c]
                    })
                    .collect();
                let best = (0..3).max_by_key(|&i| counts[i]).unwrap();
                self.pack(&xs, &[cands[best]])
            }
            Task::Compression => {
                // run-length: emit unique tokens of runs (dedup consecutive)
                let n = 6 + rng.below(l / 3);
                let mut xs = Vec::new();
                let mut compressed: Vec<i32> = Vec::new();
                while xs.len() < n {
                    let t = self.rand_data(rng);
                    let run = 1 + rng.below(3);
                    for _ in 0..run {
                        xs.push(t);
                    }
                    if compressed.last() != Some(&t) {
                        compressed.push(t);
                    }
                }
                self.pack(&xs, &compressed)
            }
            Task::Histogram => {
                // answer = count of each of 2 probe tokens, in order
                let n = 8 + rng.below(l / 2);
                let probe: Vec<i32> = vec![DATA0, DATA0 + 1];
                let mut c0 = 0;
                let mut c1 = 0;
                let xs: Vec<i32> = (0..n)
                    .map(|_| {
                        let u = rng.uniform();
                        if u < 0.25 && c0 + 1 < self.n_data() as usize {
                            c0 += 1;
                            probe[0]
                        } else if u < 0.5 && c1 + 1 < self.n_data() as usize {
                            c1 += 1;
                            probe[1]
                        } else {
                            DATA0 + 2 + rng.below((self.n_data() - 2) as usize) as i32
                        }
                    })
                    .collect();
                self.pack(&xs, &[DATA0 + c0 as i32, DATA0 + c1 as i32])
            }
        }
    }

    /// Generate a `[batch × seq_len]` training batch (flattened row-major).
    pub fn batch(&self, task: Task, batch: usize, rng: &mut Rng) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * self.seq_len);
        let mut targets = Vec::with_capacity(batch * self.seq_len);
        for _ in 0..batch {
            let ex = self.example(task, rng);
            tokens.extend_from_slice(&ex.tokens);
            targets.extend_from_slice(&ex.targets);
        }
        (tokens, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen() -> TaskGen {
        TaskGen::new(64, 64)
    }

    #[test]
    fn all_22_tasks_generate_valid_examples() {
        let g = gen();
        let mut rng = Rng::new(1);
        assert_eq!(ALL_TASKS.len(), 22);
        for task in ALL_TASKS {
            for _ in 0..50 {
                let ex = g.example(task, &mut rng);
                assert_eq!(ex.tokens.len(), 64, "{}", task.name());
                assert_eq!(ex.targets.len(), 64, "{}", task.name());
                assert!(
                    ex.tokens.iter().all(|&t| (0..64).contains(&t)),
                    "{} token out of vocab",
                    task.name()
                );
                assert!(
                    ex.targets.iter().all(|&t| t == -1 || (0..64).contains(&t)),
                    "{} target out of vocab",
                    task.name()
                );
                let supervised = ex.targets.iter().filter(|&&t| t >= 0).count();
                assert!(supervised >= 1, "{} has no supervision", task.name());
            }
        }
    }

    #[test]
    fn supervision_is_consistent_with_next_token() {
        // For every supervised position t, tokens[t+1] must equal targets[t]
        // (the answer is teacher-forced into the stream).
        let g = gen();
        let mut rng = Rng::new(2);
        for task in ALL_TASKS {
            let ex = g.example(task, &mut rng);
            for t in 0..63 {
                if ex.targets[t] >= 0 {
                    assert_eq!(
                        ex.tokens[t + 1],
                        ex.targets[t],
                        "{}: pos {t}",
                        task.name()
                    );
                }
            }
        }
    }

    #[test]
    fn copy_answer_matches_input() {
        let g = gen();
        let mut rng = Rng::new(3);
        let ex = g.example(Task::Copy, &mut rng);
        let sep = ex.tokens.iter().position(|&t| t == SEP).unwrap();
        let answer: Vec<i32> = ex.targets.iter().filter(|&&t| t >= 0).copied().collect();
        assert_eq!(&ex.tokens[..sep], &answer[..], "copy answer mismatch");
    }

    #[test]
    fn sort_answer_is_sorted() {
        let g = gen();
        let mut rng = Rng::new(4);
        for _ in 0..20 {
            let ex = g.example(Task::Sort, &mut rng);
            let ans: Vec<i32> = ex.targets.iter().filter(|&&t| t >= 0).copied().collect();
            let mut sorted = ans.clone();
            sorted.sort_unstable();
            assert_eq!(ans, sorted);
        }
    }

    #[test]
    fn parity_answer_correct() {
        let g = gen();
        let mut rng = Rng::new(5);
        for _ in 0..30 {
            let ex = g.example(Task::Parity, &mut rng);
            let sep = ex.tokens.iter().position(|&t| t == SEP).unwrap();
            let ones = ex.tokens[..sep].iter().filter(|&&t| t == 3).count() as i32;
            let ans = ex.targets.iter().find(|&&t| t >= 0).copied().unwrap();
            assert_eq!(ans, DATA0 + ones % 2);
        }
    }

    #[test]
    fn induction_probe_shape() {
        let g = gen();
        let mut rng = Rng::new(6);
        for _ in 0..30 {
            let ex = g.example(Task::Induction, &mut rng);
            let sep = ex.tokens.iter().position(|&t| t == SEP).unwrap();
            let query = ex.tokens[sep - 1];
            // the query token must have appeared earlier followed by answer
            let ans = ex.targets.iter().find(|&&t| t >= 0).copied().unwrap();
            let found = ex.tokens[..sep - 1]
                .windows(2)
                .any(|w| w[0] == query && w[1] == ans);
            assert!(found, "induction pair not present");
        }
    }

    #[test]
    fn batches_flatten_correctly() {
        let g = gen();
        let mut rng = Rng::new(7);
        let (tokens, targets) = g.batch(Task::Copy, 5, &mut rng);
        assert_eq!(tokens.len(), 5 * 64);
        assert_eq!(targets.len(), 5 * 64);
    }

    #[test]
    fn category_partition_matches_table7() {
        use std::collections::HashMap;
        let mut by_cat: HashMap<&str, usize> = HashMap::new();
        for t in ALL_TASKS {
            *by_cat.entry(t.category().name()).or_default() += 1;
        }
        assert_eq!(by_cat["basic"], 3);
        assert_eq!(by_cat["memory"], 4);
        assert_eq!(by_cat["long_range"], 3);
        assert_eq!(by_cat["reasoning"], 3);
        assert_eq!(by_cat["arithmetic"], 4);
        assert_eq!(by_cat["pattern"], 2);
        assert_eq!(by_cat["robustness"], 2);
        assert_eq!(by_cat["aggregation"], 1);
    }

    #[test]
    fn name_roundtrip() {
        for t in ALL_TASKS {
            assert_eq!(Task::from_name(t.name()), Some(t));
        }
        assert_eq!(Task::from_name("bogus"), None);
    }
}
