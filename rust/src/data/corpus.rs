//! Synthetic language-modeling corpus (Table 5 / Fig. 3 substitute for the
//! paper's web-text corpus — see DESIGN.md §Substitutions).
//!
//! The generator layers the statistical structure that differentiates
//! attention mechanisms:
//! * **Zipfian unigrams** — realistic marginal token frequencies;
//! * **Markov bigrams** — local syntax-like predictability (what the
//!   "Patterns" capability measures);
//! * **induction motifs** — rare multi-token names re-occur within a
//!   document, so copying/induction (long-range attention) pays off;
//! * **topic drift** — each document draws a topic biasing its unigram
//!   distribution, giving paragraph-level coherence.

use crate::math::rng::{zipf_cdf, Rng};

/// Corpus configuration.
#[derive(Clone, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// Zipf exponent for unigram frequencies.
    pub zipf_alpha: f64,
    /// Number of latent topics.
    pub topics: usize,
    /// Probability of continuing a bigram chain instead of resampling.
    pub bigram_p: f64,
    /// Probability of starting an induction motif replay.
    pub motif_p: f64,
    /// Motif length (multi-token "name").
    pub motif_len: usize,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig {
            vocab: 512,
            zipf_alpha: 1.05,
            topics: 16,
            bigram_p: 0.45,
            motif_p: 0.05,
            motif_len: 3,
        }
    }
}

/// Streaming document generator.
pub struct Corpus {
    cfg: CorpusConfig,
    /// Per-topic Zipf CDFs over a topic-permuted vocab.
    topic_perm: Vec<Vec<i32>>,
    base_cdf: Vec<f64>,
    /// Deterministic bigram successor table.
    succ: Vec<i32>,
}

impl Corpus {
    pub fn new(cfg: CorpusConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let base_cdf = zipf_cdf(cfg.vocab, cfg.zipf_alpha);
        let mut topic_perm = Vec::with_capacity(cfg.topics);
        for _ in 0..cfg.topics {
            let mut perm: Vec<i32> = (0..cfg.vocab as i32).collect();
            // permute only the tail so high-frequency function tokens stay
            // shared across topics (like real text)
            let head = cfg.vocab / 16;
            let tail = &mut perm[head..];
            // manual shuffle on the slice
            for i in (1..tail.len()).rev() {
                let j = rng.below(i + 1);
                tail.swap(i, j);
            }
            topic_perm.push(perm);
        }
        let succ: Vec<i32> = (0..cfg.vocab)
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect();
        Corpus { cfg, topic_perm, base_cdf, succ }
    }

    /// Generate one document of `len` tokens.
    pub fn document(&self, len: usize, rng: &mut Rng) -> Vec<i32> {
        let topic = rng.below(self.cfg.topics);
        let perm = &self.topic_perm[topic];
        let mut out = Vec::with_capacity(len);
        // the document's recurring motif ("name")
        let motif: Vec<i32> = (0..self.cfg.motif_len)
            .map(|_| perm[rng.zipf(&self.base_cdf)])
            .collect();
        let mut prev: i32 = perm[rng.zipf(&self.base_cdf)];
        out.push(prev);
        while out.len() < len {
            let u = rng.uniform();
            if u < self.cfg.motif_p && out.len() + motif.len() <= len {
                out.extend_from_slice(&motif);
                prev = *motif.last().unwrap();
            } else if u < self.cfg.motif_p + self.cfg.bigram_p {
                prev = self.succ[prev as usize % self.cfg.vocab];
                out.push(prev);
            } else {
                prev = perm[rng.zipf(&self.base_cdf)];
                out.push(prev);
            }
        }
        out.truncate(len);
        out
    }

    /// LM batch: `[batch × seq_len]` tokens plus shifted next-token targets.
    pub fn lm_batch(
        &self,
        batch: usize,
        seq_len: usize,
        rng: &mut Rng,
    ) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq_len);
        let mut targets = Vec::with_capacity(batch * seq_len);
        for _ in 0..batch {
            let doc = self.document(seq_len + 1, rng);
            tokens.extend_from_slice(&doc[..seq_len]);
            targets.extend_from_slice(&doc[1..=seq_len]);
        }
        (tokens, targets)
    }

    pub fn vocab(&self) -> usize {
        self.cfg.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn documents_in_vocab_and_right_length() {
        let c = Corpus::new(CorpusConfig::default(), 1);
        let mut rng = Rng::new(2);
        let doc = c.document(200, &mut rng);
        assert_eq!(doc.len(), 200);
        assert!(doc.iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn unigram_distribution_is_skewed() {
        let c = Corpus::new(CorpusConfig::default(), 3);
        let mut rng = Rng::new(4);
        let mut counts = vec![0usize; 512];
        for _ in 0..50 {
            for t in c.document(256, &mut rng) {
                counts[t as usize] += 1;
            }
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = counts.iter().sum();
        let top32: usize = counts[..32].iter().sum();
        assert!(
            top32 as f64 / total as f64 > 0.35,
            "head mass {} too flat",
            top32 as f64 / total as f64
        );
    }

    #[test]
    fn motifs_recur_within_documents() {
        // Induction structure: trigrams should repeat inside a document far
        // more often than across random token choices.
        let c = Corpus::new(
            CorpusConfig { motif_p: 0.1, ..Default::default() },
            5,
        );
        let mut rng = Rng::new(6);
        let mut repeats = 0;
        for _ in 0..20 {
            let doc = c.document(256, &mut rng);
            use std::collections::HashSet;
            let mut seen = HashSet::new();
            for w in doc.windows(3) {
                if !seen.insert([w[0], w[1], w[2]]) {
                    repeats += 1;
                }
            }
        }
        assert!(repeats > 20, "only {repeats} repeated trigrams");
    }

    #[test]
    fn lm_batch_targets_are_shifted() {
        let c = Corpus::new(CorpusConfig::default(), 7);
        let mut rng = Rng::new(8);
        let (tokens, targets) = c.lm_batch(2, 64, &mut rng);
        assert_eq!(tokens.len(), 128);
        for b in 0..2 {
            for t in 0..63 {
                assert_eq!(targets[b * 64 + t], tokens[b * 64 + t + 1]);
            }
        }
    }

    #[test]
    fn deterministic_given_seeds() {
        let c1 = Corpus::new(CorpusConfig::default(), 9);
        let c2 = Corpus::new(CorpusConfig::default(), 9);
        let mut r1 = Rng::new(10);
        let mut r2 = Rng::new(10);
        assert_eq!(c1.document(64, &mut r1), c2.document(64, &mut r2));
    }
}
