//! Eurlex-4K simulator (Table 4 substitute — see DESIGN.md
//! §Substitutions: the real dataset is not available offline).
//!
//! Reproduces the statistics PSP@k probes: ~4K labels with a power-law
//! frequency tail, multi-label documents (~5 labels/doc), and
//! label-dependent token distributions so a text encoder can actually
//! learn the mapping. Token streams share a global Zipf backbone with
//! label-specific "keyword" tokens mixed in.

use crate::math::rng::{zipf_cdf, Rng};

/// Dataset configuration (defaults shaped like Eurlex-4K).
#[derive(Clone, Debug)]
pub struct EurlexConfig {
    pub n_labels: usize,
    pub vocab: usize,
    pub doc_len: usize,
    /// Mean labels per document.
    pub labels_per_doc: usize,
    /// Power-law exponent of label frequencies.
    pub label_alpha: f64,
    /// Fraction of tokens drawn from label keyword pools.
    pub keyword_frac: f64,
    /// Keywords per label.
    pub keywords: usize,
}

impl Default for EurlexConfig {
    fn default() -> Self {
        EurlexConfig {
            n_labels: 3956,
            vocab: 64, // matches the `task` model preset the encoder uses
            doc_len: 64,
            labels_per_doc: 5,
            label_alpha: 1.2,
            keyword_frac: 0.55,
            keywords: 3,
        }
    }
}

/// One document with its label set.
#[derive(Clone, Debug)]
pub struct Doc {
    pub tokens: Vec<i32>,
    pub labels: Vec<usize>,
}

/// The simulated dataset generator.
pub struct Eurlex {
    pub cfg: EurlexConfig,
    label_cdf: Vec<f64>,
    token_cdf: Vec<f64>,
    /// Keyword tokens per label.
    keywords: Vec<Vec<i32>>,
}

impl Eurlex {
    pub fn new(cfg: EurlexConfig, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let label_cdf = zipf_cdf(cfg.n_labels, cfg.label_alpha);
        let token_cdf = zipf_cdf(cfg.vocab - 4, 1.05); // reserve 0..4
        let keywords = (0..cfg.n_labels)
            .map(|_| {
                (0..cfg.keywords)
                    .map(|_| 4 + rng.below(cfg.vocab - 4) as i32)
                    .collect()
            })
            .collect();
        Eurlex { cfg, label_cdf, token_cdf, keywords }
    }

    /// Sample one document.
    pub fn doc(&self, rng: &mut Rng) -> Doc {
        // label set: Zipf-distributed, deduplicated
        let mut labels = Vec::new();
        let n_labels = 1 + rng.below(2 * self.cfg.labels_per_doc - 1);
        for _ in 0..n_labels {
            let l = rng.zipf(&self.label_cdf);
            if !labels.contains(&l) {
                labels.push(l);
            }
        }
        labels.sort_unstable();
        // tokens: mixture of global Zipf and the labels' keyword pools
        let tokens = (0..self.cfg.doc_len)
            .map(|_| {
                if rng.uniform() < self.cfg.keyword_frac {
                    let l = labels[rng.below(labels.len())];
                    let kw = &self.keywords[l];
                    kw[rng.below(kw.len())]
                } else {
                    4 + rng.zipf(&self.token_cdf) as i32
                }
            })
            .collect();
        Doc { tokens, labels }
    }

    /// Sample a dataset split.
    pub fn split(&self, n: usize, rng: &mut Rng) -> Vec<Doc> {
        (0..n).map(|_| self.doc(rng)).collect()
    }

    /// Label frequency counts over a split (propensity input).
    pub fn label_counts(&self, docs: &[Doc]) -> Vec<usize> {
        let mut counts = vec![0usize; self.cfg.n_labels];
        for d in docs {
            for &l in &d.labels {
                counts[l] += 1;
            }
        }
        counts
    }

    /// Multi-hot target row for a doc (f32, length n_labels).
    pub fn multi_hot(&self, doc: &Doc) -> Vec<f32> {
        let mut y = vec![0.0f32; self.cfg.n_labels];
        for &l in &doc.labels {
            y[l] = 1.0;
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Eurlex {
        Eurlex::new(
            EurlexConfig { n_labels: 200, ..Default::default() },
            1,
        )
    }

    #[test]
    fn docs_have_valid_tokens_and_labels() {
        let e = small();
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let d = e.doc(&mut rng);
            assert_eq!(d.tokens.len(), 64);
            assert!(d.tokens.iter().all(|&t| (4..64).contains(&t)));
            assert!(!d.labels.is_empty());
            assert!(d.labels.iter().all(|&l| l < 200));
            // dedup + sorted
            let mut s = d.labels.clone();
            s.dedup();
            assert_eq!(s, d.labels);
        }
    }

    #[test]
    fn label_distribution_is_long_tailed() {
        let e = small();
        let mut rng = Rng::new(3);
        let docs = e.split(2000, &mut rng);
        let mut counts = e.label_counts(&docs);
        counts.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = counts.iter().sum();
        // head (top 5%) captures a large share; tail has rare labels
        let head: usize = counts[..10].iter().sum();
        assert!(head as f64 / total as f64 > 0.25, "head {head}/{total}");
        assert!(counts[150..].iter().any(|&c| c <= 2), "no tail labels");
    }

    #[test]
    fn keywords_make_labels_learnable() {
        // Docs sharing a label should share more tokens than random pairs.
        let e = small();
        let mut rng = Rng::new(4);
        let docs = e.split(400, &mut rng);
        let overlap = |a: &Doc, b: &Doc| {
            let sa: std::collections::HashSet<i32> = a.tokens.iter().copied().collect();
            b.tokens.iter().filter(|t| sa.contains(t)).count()
        };
        let mut same = Vec::new();
        let mut diff = Vec::new();
        for i in 0..docs.len() {
            for j in i + 1..docs.len().min(i + 20) {
                let share = docs[i].labels.iter().any(|l| docs[j].labels.contains(l));
                let o = overlap(&docs[i], &docs[j]) as f64;
                if share {
                    same.push(o);
                } else {
                    diff.push(o);
                }
            }
        }
        let m_same = crate::math::stats::mean(&same);
        let m_diff = crate::math::stats::mean(&diff);
        assert!(
            m_same > m_diff,
            "same-label overlap {m_same} <= diff-label {m_diff}"
        );
    }

    #[test]
    fn multi_hot_encoding() {
        let e = small();
        let mut rng = Rng::new(5);
        let d = e.doc(&mut rng);
        let y = e.multi_hot(&d);
        assert_eq!(y.len(), 200);
        let ones: Vec<usize> = y
            .iter()
            .enumerate()
            .filter(|(_, &v)| v == 1.0)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(ones, d.labels);
    }
}
