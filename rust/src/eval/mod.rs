//! Evaluation metrics: token accuracy and perplexity (Tables 3/5/8),
//! extreme-classification P@k / PSP@k (Table 4), and attention-entropy
//! analysis (Figs. 15/16).

pub mod xmc;

/// Masked token accuracy: fraction of positions with `target >= 0` where
/// `argmax(logits) == target`. `logits` is `[n_positions, vocab]` row-major.
pub fn token_accuracy(logits: &[f32], vocab: usize, targets: &[i32]) -> f64 {
    assert_eq!(logits.len(), targets.len() * vocab);
    let mut correct = 0usize;
    let mut total = 0usize;
    for (i, &t) in targets.iter().enumerate() {
        if t < 0 {
            continue;
        }
        total += 1;
        let row = &logits[i * vocab..(i + 1) * vocab];
        let mut best = 0usize;
        for (j, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = j;
            }
        }
        if best == t as usize {
            correct += 1;
        }
    }
    if total == 0 {
        0.0
    } else {
        correct as f64 / total as f64
    }
}

/// Perplexity from mean cross-entropy (nats).
pub fn perplexity(mean_nll: f64) -> f64 {
    mean_nll.exp()
}

/// Mean Shannon entropy of attention rows (Fig. 15/16): `weights` is a
/// row-major `[rows, cols]` nonnegative matrix.
pub fn mean_attention_entropy(weights: &[f32], cols: usize) -> f64 {
    assert_eq!(weights.len() % cols, 0);
    let rows = weights.len() / cols;
    let mut total = 0.0;
    for r in 0..rows {
        total += crate::math::stats::entropy(&weights[r * cols..(r + 1) * cols]);
    }
    total / rows.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_only_unmasked() {
        // vocab 3, two positions; first predicts class 2 correctly, second
        // is masked.
        let logits = vec![0.0, 0.1, 0.9, 0.9, 0.1, 0.0];
        assert_eq!(token_accuracy(&logits, 3, &[2, -1]), 1.0);
        assert_eq!(token_accuracy(&logits, 3, &[1, -1]), 0.0);
        assert_eq!(token_accuracy(&logits, 3, &[-1, -1]), 0.0);
    }

    #[test]
    fn perplexity_of_uniform() {
        let v = 64.0f64;
        assert!((perplexity(v.ln()) - v).abs() < 1e-9);
    }

    #[test]
    fn entropy_diffuse_vs_peaked() {
        let diffuse = vec![0.25f32; 8]; // two rows of uniform over 4
        let peaked = vec![1.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0];
        assert!(
            mean_attention_entropy(&diffuse, 4) > mean_attention_entropy(&peaked, 4)
        );
    }
}
