//! Extreme multi-label classification metrics (Table 4, Eurlex-4K):
//! precision@k and propensity-scored precision@k.
//!
//! PSP@k follows Jain et al. (2016): label propensity
//! `p_l = 1 / (1 + C e^{−A log(N_l + B)})` with the standard Eurlex
//! constants A = 0.55, B = 1.5; PSP@k divides each hit by its propensity
//! and normalizes by the best attainable propensity-weighted score.

/// Propensity model constants (Jain et al. 2016, Eurlex defaults).
#[derive(Clone, Copy, Debug)]
pub struct PropensityModel {
    pub a: f64,
    pub b: f64,
}

impl Default for PropensityModel {
    fn default() -> Self {
        PropensityModel { a: 0.55, b: 1.5 }
    }
}

impl PropensityModel {
    /// Per-label propensities from training-set label frequencies.
    pub fn propensities(&self, label_counts: &[usize], n_train: usize) -> Vec<f64> {
        let n = n_train as f64;
        let c = (n.ln() - 1.0) * (1.0 + self.b).powf(self.a);
        label_counts
            .iter()
            .map(|&nl| 1.0 / (1.0 + c * (-(self.a) * ((nl as f64) + self.b).ln()).exp()))
            .collect()
    }
}

/// Top-k indices of a score row (descending).
pub fn top_k(scores: &[f32], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    idx.truncate(k);
    idx
}

/// Precision@k over a test set: `scores[i]` is the label-score row of
/// sample i; `truths[i]` its true label set.
pub fn precision_at_k(scores: &[Vec<f32>], truths: &[Vec<usize>], k: usize) -> f64 {
    assert_eq!(scores.len(), truths.len());
    let mut total = 0.0;
    for (s, t) in scores.iter().zip(truths.iter()) {
        let top = top_k(s, k);
        let hits = top.iter().filter(|i| t.contains(i)).count();
        total += hits as f64 / k as f64;
    }
    total / scores.len().max(1) as f64
}

/// Propensity-scored precision@k (normalized as in the XMC literature:
/// numerator over predicted top-k, denominator over the *best possible*
/// top-k by inverse propensity of the true labels).
pub fn psp_at_k(
    scores: &[Vec<f32>],
    truths: &[Vec<usize>],
    propensities: &[f64],
    k: usize,
) -> f64 {
    assert_eq!(scores.len(), truths.len());
    let mut total = 0.0;
    for (s, t) in scores.iter().zip(truths.iter()) {
        let top = top_k(s, k);
        let num: f64 = top
            .iter()
            .filter(|i| t.contains(i))
            .map(|&i| 1.0 / propensities[i].max(1e-12))
            .sum();
        // ideal: the k true labels with smallest propensity
        let mut inv: Vec<f64> = t.iter().map(|&i| 1.0 / propensities[i].max(1e-12)).collect();
        inv.sort_by(|a, b| b.partial_cmp(a).unwrap());
        let den: f64 = inv.iter().take(k).sum();
        if den > 0.0 {
            total += num / den;
        }
    }
    total / scores.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_orders_descending() {
        assert_eq!(top_k(&[0.1, 0.9, 0.5], 2), vec![1, 2]);
    }

    #[test]
    fn perfect_predictions_score_one() {
        let scores = vec![vec![0.9, 0.8, 0.1, 0.0], vec![0.1, 0.9, 0.8, 0.0]];
        let truths = vec![vec![0, 1], vec![1, 2]];
        assert!((precision_at_k(&scores, &truths, 2) - 1.0).abs() < 1e-12);
        let props = vec![0.5; 4];
        assert!((psp_at_k(&scores, &truths, &props, 2) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn precision_partial_credit() {
        let scores = vec![vec![0.9, 0.8, 0.1]];
        let truths = vec![vec![0, 2]]; // one of top-2 correct
        assert!((precision_at_k(&scores, &truths, 2) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn psp_rewards_tail_labels_more() {
        // Two systems, each gets one hit; hitting the tail label (low
        // propensity) must score higher than hitting the head label.
        let props = vec![0.9, 0.1]; // label 0 head, label 1 tail
        let truths = vec![vec![0, 1]];
        let head_hit = vec![vec![1.0, 0.0]];
        let tail_hit = vec![vec![0.0, 1.0]];
        let s_head = psp_at_k(&head_hit, &truths, &props, 1);
        let s_tail = psp_at_k(&tail_hit, &truths, &props, 1);
        assert!(s_tail > s_head, "{s_tail} <= {s_head}");
    }

    #[test]
    fn propensity_model_monotone_in_frequency() {
        let m = PropensityModel::default();
        let p = m.propensities(&[1, 10, 100, 1000], 10_000);
        for w in p.windows(2) {
            assert!(w[1] > w[0], "propensity should grow with frequency: {p:?}");
        }
        assert!(p.iter().all(|&x| x > 0.0 && x < 1.0));
    }
}
