//! Session persistence (ADR-004): the on-disk layout shared by the
//! store's spill tier and coordinator snapshot/restore.
//!
//! A spill or snapshot directory holds one codec file per sequence
//! (`seq_<id>.state`, written by [`crate::kernels::AttnState::encode`]);
//! a snapshot additionally holds `manifest.json` — the mechanism spec,
//! geometry and sequence roster — written (fsynced) *after* every state
//! file, so the manifest's existence commits the snapshot. Restore reads
//! the manifest, verifies the target config is state-compatible, and
//! re-deals every state to its owning shard under the *new* worker count
//! (sequences are hash-sharded by id) — which makes snapshot/restore the
//! shard-migration and rebalance primitive, not just crash recovery.
//!
//! Durability rules: snapshot state files and the manifest are fsynced;
//! spill files are not (the spill tier is a cache — losing one is an
//! eviction, not data loss for the serving contract).

use crate::coordinator::request::SeqId;
use crate::coordinator::CoordinatorConfig;
use crate::kernels::config::Mechanism;
use crate::util::json::Json;
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// File name of the snapshot manifest inside its directory.
pub const MANIFEST_FILE: &str = "manifest.json";

/// Path of one serialized sequence state inside a spill or snapshot
/// directory.
pub fn state_file(dir: &Path, id: SeqId) -> PathBuf {
    dir.join(format!("seq_{}.state", id.0))
}

/// Write `bytes` to `path` durably: temp file in the same directory,
/// fsync, atomic rename, then fsync of the parent directory (the rename
/// itself is only crash-durable once the directory entry is flushed). A
/// crashed writer can never leave a torn or half-new file under the final
/// name — which is what lets repeated snapshots into the same directory
/// stay restorable at every instant.
pub fn write_durable(path: &Path, bytes: &[u8]) -> anyhow::Result<()> {
    // Fault site `snapshot_write` (ADR-008): fails before the temp file is
    // created, so an injected fault can never leave debris behind.
    if crate::util::fault::fire("snapshot_write").is_some() {
        anyhow::bail!("injected snapshot_write fault at {}", path.display());
    }
    let tmp = path.with_extension("tmp");
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(&tmp, path)?;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::File::open(parent)?.sync_all()?;
        }
    }
    Ok(())
}

/// Snapshot manifest: everything needed to rebuild a coordinator around
/// the serialized states — the mechanism registry spec and geometry the
/// states were produced under, the id allocator position, and the roster.
#[derive(Clone, Debug, PartialEq)]
pub struct Manifest {
    /// Full mechanism registry spec ([`Mechanism`]'s `Display`).
    pub mechanism: String,
    pub d_head: usize,
    pub d_v: usize,
    pub horizon: usize,
    pub window: usize,
    /// Next sequence id the coordinator would hand out.
    pub next_seq: u64,
    /// `(sequence id, absorbed tokens)` roster.
    pub seqs: Vec<(u64, usize)>,
}

impl Manifest {
    pub fn from_config(
        cfg: &CoordinatorConfig,
        next_seq: u64,
        seqs: Vec<(u64, usize)>,
    ) -> Manifest {
        Manifest {
            mechanism: cfg.mechanism.to_string(),
            d_head: cfg.d_head,
            d_v: cfg.d_v,
            horizon: cfg.horizon,
            window: cfg.window,
            next_seq,
            seqs,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mechanism", Json::Str(self.mechanism.clone())),
            ("d_head", Json::Num(self.d_head as f64)),
            ("d_v", Json::Num(self.d_v as f64)),
            ("horizon", Json::Num(self.horizon as f64)),
            ("window", Json::Num(self.window as f64)),
            ("next_seq", Json::Num(self.next_seq as f64)),
            (
                "seqs",
                Json::Arr(
                    self.seqs
                        .iter()
                        .map(|&(id, len)| {
                            Json::obj(vec![
                                ("id", Json::Num(id as f64)),
                                ("len", Json::Num(len as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Manifest> {
        fn num(j: &Json, k: &str) -> anyhow::Result<usize> {
            j.req(k)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("manifest field '{k}' must be a number"))
        }
        let mechanism = j
            .req("mechanism")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("manifest field 'mechanism' must be a string"))?
            .to_string();
        let arr = j
            .req("seqs")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest field 'seqs' must be an array"))?;
        let mut seqs = Vec::with_capacity(arr.len());
        for e in arr {
            seqs.push((num(e, "id")? as u64, num(e, "len")?));
        }
        Ok(Manifest {
            mechanism,
            d_head: num(j, "d_head")?,
            d_v: num(j, "d_v")?,
            horizon: num(j, "horizon")?,
            window: num(j, "window")?,
            next_seq: num(j, "next_seq")? as u64,
            seqs,
        })
    }

    /// Write `manifest.json` into `dir` via [`write_durable`] — the commit
    /// point of a snapshot (state files without a manifest are ignored by
    /// restore, and the atomic rename means a crash mid-save leaves the
    /// *previous* manifest intact rather than a truncated one).
    pub fn save(&self, dir: &Path) -> anyhow::Result<()> {
        std::fs::create_dir_all(dir)?;
        write_durable(&dir.join(MANIFEST_FILE), self.to_json().to_pretty().as_bytes())
    }

    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        Manifest::from_json(&Json::from_file(&dir.join(MANIFEST_FILE))?)
    }

    /// Overwrite the state-compatibility fields of `cfg` with the
    /// manifest's (the CLI restore path): mechanism spec and geometry come
    /// from the snapshot, topology knobs — workers, batching, queues,
    /// store budget — stay caller-chosen.
    pub fn apply_to(&self, cfg: &mut CoordinatorConfig) -> anyhow::Result<()> {
        cfg.mechanism = Mechanism::parse(&self.mechanism)?;
        cfg.d_head = self.d_head;
        cfg.d_v = self.d_v;
        cfg.horizon = self.horizon;
        cfg.window = self.window;
        Ok(())
    }

    /// Check that `cfg` can resume this snapshot's states byte-for-byte.
    pub fn check_compatible(&self, cfg: &CoordinatorConfig) -> anyhow::Result<()> {
        anyhow::ensure!(
            cfg.mechanism.to_string() == self.mechanism,
            "mechanism mismatch: snapshot has '{}', config has '{}'",
            self.mechanism,
            cfg.mechanism
        );
        anyhow::ensure!(
            cfg.d_head == self.d_head && cfg.d_v == self.d_v,
            "geometry mismatch: snapshot (d_head={}, d_v={}) vs config (d_head={}, d_v={})",
            self.d_head,
            self.d_v,
            cfg.d_head,
            cfg.d_v
        );
        anyhow::ensure!(
            cfg.horizon == self.horizon && cfg.window == self.window,
            "window mismatch: snapshot (horizon={}, window={}) vs config (horizon={}, window={})",
            self.horizon,
            self.window,
            cfg.horizon,
            cfg.window
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::from_config(
            &CoordinatorConfig::default(),
            42,
            vec![(1, 128), (7, 1), (9, 4096)],
        )
    }

    #[test]
    fn manifest_round_trips_through_json_and_disk() {
        let m = manifest();
        let back = Manifest::from_json(&Json::parse(&m.to_json().to_pretty()).unwrap()).unwrap();
        assert_eq!(m, back);
        let dir = std::env::temp_dir().join("slay_persist_manifest_test");
        let _ = std::fs::remove_dir_all(&dir);
        m.save(&dir).unwrap();
        assert_eq!(Manifest::load(&dir).unwrap(), m);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn apply_to_restores_a_compatible_config() {
        let m = manifest();
        let mut cfg = CoordinatorConfig { d_head: 99, workers: 2, ..Default::default() };
        m.apply_to(&mut cfg).unwrap();
        m.check_compatible(&cfg).unwrap();
        assert_eq!(cfg.workers, 2, "topology knobs stay caller-chosen");
        assert_eq!(cfg.d_head, CoordinatorConfig::default().d_head);
    }

    #[test]
    fn incompatible_configs_are_rejected() {
        let m = manifest();
        let bad_head = CoordinatorConfig { d_head: 1, ..Default::default() };
        assert!(m.check_compatible(&bad_head).is_err());
        let bad_window = CoordinatorConfig { window: 7, ..Default::default() };
        assert!(m.check_compatible(&bad_window).is_err());
        let bad_mech = CoordinatorConfig { mechanism: Mechanism::EluLinear, ..Default::default() };
        assert!(m.check_compatible(&bad_mech).is_err());
    }

    #[test]
    fn state_file_naming_is_stable() {
        let p = state_file(Path::new("/tmp/snap"), SeqId(17));
        assert_eq!(p, PathBuf::from("/tmp/snap/seq_17.state"));
    }

    #[test]
    fn manifest_load_fails_cleanly_without_a_manifest() {
        let dir = std::env::temp_dir().join("slay_persist_no_manifest");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Manifest::load(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
