//! Coordinator metrics: lock-free counters plus the `obs` layer (stage
//! histograms, per-shard stats, event ring). Everything on the per-chunk
//! hot path is a relaxed atomic — the old mutex-guarded latency reservoir
//! (whose replacement index raced on the `completed` counter) is gone,
//! replaced by a lock-free log-linear histogram; the legacy
//! `latency_p50_ms`/`latency_p95_ms`/`latency_mean_ms` keys still emit,
//! now computed from that histogram.
//!
//! Exposition is single-sourced: [`Snapshot::counter_fields`] and
//! [`Snapshot::gauge_fields`] feed *both* the JSON output and the
//! Prometheus renderer (`obs::prom`), and both lists destructure
//! `Snapshot` exhaustively — adding a field without exporting it is a
//! compile error, not a silent gap.

use crate::obs::Obs;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

#[derive(Default)]
pub struct Metrics {
    pub submitted: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub tokens_in: AtomicU64,
    pub decode_chunks: AtomicU64,
    pub prefill_chunks: AtomicU64,
    pub batches: AtomicU64,
    pub batched_items: AtomicU64,
    /// Spill tier (ADR-004): states paged out to disk under budget
    /// pressure instead of destroyed…
    pub spilled: AtomicU64,
    /// …and transparently faulted back in on their next chunk.
    pub restored_from_spill: AtomicU64,
    /// Serialized bytes written by the spill tier (cumulative).
    pub bytes_spilled: AtomicU64,
    /// Coordinator-level snapshots taken.
    pub snapshots: AtomicU64,
    /// Fused cross-session decode blocks executed (ADR-005): one
    /// `decode_batch_with` call per counted block…
    pub fused_decode_batches: AtomicU64,
    /// …and the decode rows those blocks advanced (so
    /// `fused_decode_rows / fused_decode_batches` is the mean fused batch
    /// size — the number that says whether traffic actually fuses).
    pub fused_decode_rows: AtomicU64,
    /// Largest fused decode block seen (high-water mark, `fetch_max`).
    pub max_fused_batch: AtomicU64,
    /// Session forks completed (ADR-006): live or spilled states cloned
    /// under a fresh sequence id.
    pub forks: AtomicU64,
    /// Prefill chunks answered from the shared-prefix cache (ADR-006):
    /// the chunk's compute was skipped entirely…
    pub prefix_hits: AtomicU64,
    /// …vs prefill chunks that consulted the cache and computed normally
    /// (hits / (hits + misses) is the cache's participation rate).
    pub prefix_misses: AtomicU64,
    /// Q/K/V payload bytes whose prefill compute the prefix cache skipped
    /// (cumulative — the "N sessions pay one prefill" number).
    pub prefix_bytes_saved: AtomicU64,
    /// Bytes currently held by the shard prefix caches (gauge, `store`d
    /// on every insert/evict rather than accumulated).
    pub prefix_cache_bytes: AtomicU64,
    /// TCP connections currently being served (gauge; the `--max-conns`
    /// shed threshold applies to this).
    pub active_connections: AtomicU64,
    /// Connections shed at accept because `--max-conns` was reached.
    pub shed_connections: AtomicU64,
    /// Wire bytes read off client sockets (ADR-007; counted at the
    /// `read(2)` boundary, so framing overhead is included).
    pub wire_bytes_rx: AtomicU64,
    /// Wire bytes actually written to client sockets (counted at the
    /// `write(2)` boundary, after buffering).
    pub wire_bytes_tx: AtomicU64,
    /// Complete wire messages parsed off sockets — JSON lines *and*
    /// binary frames both count (the planes share one framing layer).
    pub frames_rx: AtomicU64,
    /// Complete wire messages queued for clients (replies, per-token
    /// stream frames, stream terminators, protocol errors).
    pub frames_tx: AtomicU64,
    /// Requests rejected before reaching the coordinator: framing or
    /// checksum failures, oversized frames/lines, malformed ops.
    pub protocol_errors: AtomicU64,
    /// Times a connection's reads were paused because its pending-request
    /// or pending-write-byte cap was hit (backpressure pushed to the
    /// socket instead of buffering unboundedly).
    pub backpressure_stalls: AtomicU64,
    /// Worker panics caught and isolated (ADR-008): per-item
    /// `catch_unwind` catches plus whole-thread deaths harvested at
    /// respawn.
    pub worker_panics: AtomicU64,
    /// Dead shard worker threads detected and respawned by the
    /// coordinator (each respawn re-installs the shard's spilled
    /// sessions).
    pub worker_restarts: AtomicU64,
    /// Sessions released because a panic struck while their state was
    /// borrowed for compute (possibly torn mid-mutation — releasing is
    /// the only safe disposition; spilled states are left intact).
    pub sessions_poisoned: AtomicU64,
    /// Requests answered with the deterministic deadline error
    /// (`--request-timeout-ms`): worker-side expiry skips plus
    /// reactor-side completion reaps.
    pub request_timeouts: AtomicU64,
    /// Spill-tier writes that failed (real I/O errors or injected
    /// faults); each degrades to a counted destroy-evict, not a crash.
    pub spill_write_failures: AtomicU64,
    /// Replies/acks whose receiving peer had already disconnected — the
    /// delivery was dropped and counted instead of silently discarded.
    pub dropped_replies: AtomicU64,
    /// Observability layer: per-class/per-stage latency histograms,
    /// per-shard stats, structured event ring (see `crate::obs`).
    pub obs: Obs,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record an end-to-end request latency (enqueue → reply built). Feeds
    /// the legacy `latency_p50_ms`/`latency_p95_ms`/`latency_mean_ms`
    /// keys. Lock-free; no-op while the obs layer is disabled.
    pub fn record_latency(&self, d: Duration) {
        self.obs.record_request(d);
    }

    // ---- counter + event-ring pairings ---------------------------------
    // Incidents worth a post-hoc timeline bump their counter *and* land a
    // structured event, through one helper per kind so call sites can't
    // drift apart.

    /// Framing/parse/validation failure on the wire (rejected pre-coordinator).
    pub fn protocol_error(&self, detail: String) {
        self.protocol_errors.fetch_add(1, Ordering::Relaxed);
        self.obs.events.push("protocol_error", detail);
    }

    /// Connection refused at accept because `--max-conns` was reached.
    pub fn shed_connection(&self, detail: String) {
        self.shed_connections.fetch_add(1, Ordering::Relaxed);
        self.obs.events.push("shed_connection", detail);
    }

    /// Dead shard worker detected and respawned (ADR-008).
    pub fn worker_restarted(&self, detail: String) {
        self.worker_restarts.fetch_add(1, Ordering::Relaxed);
        self.obs.events.push("worker_restart", detail);
    }

    /// Session state released after a panic struck mid-borrow (ADR-008).
    pub fn session_poisoned(&self, detail: String) {
        self.sessions_poisoned.fetch_add(1, Ordering::Relaxed);
        self.obs.events.push("session_poisoned", detail);
    }

    /// Spill-tier write failed and degraded to a destroy-evict (ADR-008).
    pub fn spill_write_failed(&self, detail: String) {
        self.spill_write_failures.fetch_add(1, Ordering::Relaxed);
        self.obs.events.push("spill_write_failure", detail);
    }

    /// Coordinator-level snapshot taken (ADR-004).
    pub fn snapshot_taken(&self, detail: String) {
        self.snapshots.fetch_add(1, Ordering::Relaxed);
        self.obs.events.push("snapshot", detail);
    }

    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            tokens_in: self.tokens_in.load(Ordering::Relaxed),
            decode_chunks: self.decode_chunks.load(Ordering::Relaxed),
            prefill_chunks: self.prefill_chunks.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            batched_items: self.batched_items.load(Ordering::Relaxed),
            spilled: self.spilled.load(Ordering::Relaxed),
            restored_from_spill: self.restored_from_spill.load(Ordering::Relaxed),
            bytes_spilled: self.bytes_spilled.load(Ordering::Relaxed),
            snapshots: self.snapshots.load(Ordering::Relaxed),
            fused_decode_batches: self.fused_decode_batches.load(Ordering::Relaxed),
            fused_decode_rows: self.fused_decode_rows.load(Ordering::Relaxed),
            max_fused_batch: self.max_fused_batch.load(Ordering::Relaxed),
            forks: self.forks.load(Ordering::Relaxed),
            prefix_hits: self.prefix_hits.load(Ordering::Relaxed),
            prefix_misses: self.prefix_misses.load(Ordering::Relaxed),
            prefix_bytes_saved: self.prefix_bytes_saved.load(Ordering::Relaxed),
            prefix_cache_bytes: self.prefix_cache_bytes.load(Ordering::Relaxed),
            active_connections: self.active_connections.load(Ordering::Relaxed),
            shed_connections: self.shed_connections.load(Ordering::Relaxed),
            wire_bytes_rx: self.wire_bytes_rx.load(Ordering::Relaxed),
            wire_bytes_tx: self.wire_bytes_tx.load(Ordering::Relaxed),
            frames_rx: self.frames_rx.load(Ordering::Relaxed),
            frames_tx: self.frames_tx.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
            backpressure_stalls: self.backpressure_stalls.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            worker_restarts: self.worker_restarts.load(Ordering::Relaxed),
            sessions_poisoned: self.sessions_poisoned.load(Ordering::Relaxed),
            request_timeouts: self.request_timeouts.load(Ordering::Relaxed),
            spill_write_failures: self.spill_write_failures.load(Ordering::Relaxed),
            dropped_replies: self.dropped_replies.load(Ordering::Relaxed),
            latency_p50_ms: self.obs.request.quantile_ms(50.0),
            latency_p95_ms: self.obs.request.quantile_ms(95.0),
            latency_mean_ms: self.obs.request.mean_ms(),
            simd_backend: crate::math::simd::backend_name(),
        }
    }

    /// Full metrics JSON: the flat snapshot plus the nested per-class,
    /// per-stage latency object (`"stages"`). This is what
    /// `{"op":"metrics"}` returns.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut j = self.snapshot().to_json();
        if let Json::Obj(map) = &mut j {
            map.insert("stages".to_string(), self.obs.stages_json());
        }
        j
    }
}

/// Point-in-time metric values.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Snapshot {
    pub submitted: u64,
    pub completed: u64,
    pub rejected: u64,
    pub tokens_in: u64,
    pub decode_chunks: u64,
    pub prefill_chunks: u64,
    pub batches: u64,
    pub batched_items: u64,
    pub spilled: u64,
    pub restored_from_spill: u64,
    pub bytes_spilled: u64,
    pub snapshots: u64,
    pub fused_decode_batches: u64,
    pub fused_decode_rows: u64,
    pub max_fused_batch: u64,
    pub forks: u64,
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    pub prefix_bytes_saved: u64,
    pub prefix_cache_bytes: u64,
    pub active_connections: u64,
    pub shed_connections: u64,
    pub wire_bytes_rx: u64,
    pub wire_bytes_tx: u64,
    pub frames_rx: u64,
    pub frames_tx: u64,
    pub protocol_errors: u64,
    pub backpressure_stalls: u64,
    pub worker_panics: u64,
    pub worker_restarts: u64,
    pub sessions_poisoned: u64,
    pub request_timeouts: u64,
    pub spill_write_failures: u64,
    pub dropped_replies: u64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_mean_ms: f64,
    /// Resolved SIMD dispatch backend (`"scalar"|"avx2"|"neon"`, ADR-010) —
    /// a label, not a number; exported as a JSON string and as a
    /// Prometheus info-style gauge.
    pub simd_backend: &'static str,
}

impl Snapshot {
    /// Mean items per formed batch (batching effectiveness).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_items as f64 / self.batches as f64
        }
    }

    /// Mean decode rows per fused block (fusion effectiveness, ADR-005):
    /// near 1.0 means batches form but decode traffic never actually
    /// fuses; the coordinator's whole cross-session win lives above that.
    pub fn mean_fused_batch_size(&self) -> f64 {
        if self.fused_decode_batches == 0 {
            0.0
        } else {
            self.fused_decode_rows as f64 / self.fused_decode_batches as f64
        }
    }

    /// The single source of truth for exposition: every field, partitioned
    /// into monotone counters and point-in-time gauges (plus the derived
    /// means). The exhaustive destructure (no `..`) makes "added a field,
    /// forgot to export it" a compile error; JSON and Prometheus both
    /// render from these lists.
    fn field_lists(&self) -> (Vec<(&'static str, u64)>, Vec<(&'static str, f64)>) {
        let Snapshot {
            submitted,
            completed,
            rejected,
            tokens_in,
            decode_chunks,
            prefill_chunks,
            batches,
            batched_items,
            spilled,
            restored_from_spill,
            bytes_spilled,
            snapshots,
            fused_decode_batches,
            fused_decode_rows,
            max_fused_batch,
            forks,
            prefix_hits,
            prefix_misses,
            prefix_bytes_saved,
            prefix_cache_bytes,
            active_connections,
            shed_connections,
            wire_bytes_rx,
            wire_bytes_tx,
            frames_rx,
            frames_tx,
            protocol_errors,
            backpressure_stalls,
            worker_panics,
            worker_restarts,
            sessions_poisoned,
            request_timeouts,
            spill_write_failures,
            dropped_replies,
            latency_p50_ms,
            latency_p95_ms,
            latency_mean_ms,
            // A string label, not a numeric series — exported by
            // `to_json`/`prom::render` directly (the completeness test
            // checks both).
            simd_backend: _,
        } = *self;
        let counters = vec![
            ("submitted", submitted),
            ("completed", completed),
            ("rejected", rejected),
            ("tokens_in", tokens_in),
            ("decode_chunks", decode_chunks),
            ("prefill_chunks", prefill_chunks),
            ("batches", batches),
            ("batched_items", batched_items),
            ("spilled", spilled),
            ("restored_from_spill", restored_from_spill),
            ("bytes_spilled", bytes_spilled),
            ("snapshots", snapshots),
            ("fused_decode_batches", fused_decode_batches),
            ("fused_decode_rows", fused_decode_rows),
            ("forks", forks),
            ("prefix_hits", prefix_hits),
            ("prefix_misses", prefix_misses),
            ("prefix_bytes_saved", prefix_bytes_saved),
            ("shed_connections", shed_connections),
            ("wire_bytes_rx", wire_bytes_rx),
            ("wire_bytes_tx", wire_bytes_tx),
            ("frames_rx", frames_rx),
            ("frames_tx", frames_tx),
            ("protocol_errors", protocol_errors),
            ("backpressure_stalls", backpressure_stalls),
            ("worker_panics", worker_panics),
            ("worker_restarts", worker_restarts),
            ("sessions_poisoned", sessions_poisoned),
            ("request_timeouts", request_timeouts),
            ("spill_write_failures", spill_write_failures),
            ("dropped_replies", dropped_replies),
        ];
        let gauges = vec![
            ("prefix_cache_bytes", prefix_cache_bytes as f64),
            ("active_connections", active_connections as f64),
            ("max_fused_batch", max_fused_batch as f64),
            ("mean_batch_size", self.mean_batch_size()),
            ("mean_fused_batch_size", self.mean_fused_batch_size()),
            ("latency_p50_ms", latency_p50_ms),
            ("latency_p95_ms", latency_p95_ms),
            ("latency_mean_ms", latency_mean_ms),
        ];
        (counters, gauges)
    }

    /// Monotone counters, for `slay_<name>_total` Prometheus rendering.
    pub fn counter_fields(&self) -> Vec<(&'static str, u64)> {
        self.field_lists().0
    }

    /// Point-in-time gauges (plus derived means/quantiles), for
    /// `slay_<name>` Prometheus rendering.
    pub fn gauge_fields(&self) -> Vec<(&'static str, f64)> {
        self.field_lists().1
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let (counters, gauges) = self.field_lists();
        let mut fields: Vec<(&str, Json)> = counters
            .into_iter()
            .map(|(k, v)| (k, Json::Num(v as f64)))
            .collect();
        fields.extend(gauges.into_iter().map(|(k, v)| (k, Json::Num(v))));
        fields.push(("simd_backend", Json::Str(self.simd_backend.to_string())));
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_latency_snapshot() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.completed.fetch_add(2, Ordering::Relaxed);
        m.record_latency(Duration::from_millis(10));
        m.record_latency(Duration::from_millis(20));
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
        // histogram quantiles report bucket midpoints: within one bucket's
        // relative error (≤ 25%) of the exact order statistics
        assert!(
            s.latency_p50_ms >= 7.5 && s.latency_p50_ms <= 20.0,
            "p50={}",
            s.latency_p50_ms
        );
        assert!(
            s.latency_p95_ms >= 15.0 && s.latency_p95_ms <= 25.0,
            "p95={}",
            s.latency_p95_ms
        );
        assert!(
            (s.latency_mean_ms - 15.0).abs() < 0.5,
            "mean={}",
            s.latency_mean_ms
        );
    }

    #[test]
    fn concurrent_latency_records_are_lossless() {
        // the old reservoir's replacement index raced on `completed`;
        // the histogram must count every sample exactly once
        use std::sync::Arc;
        let m = Arc::new(Metrics::new());
        let threads: u64 = 8;
        let per: u64 = 2000;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let m = Arc::clone(&m);
                std::thread::spawn(move || {
                    for i in 0..per {
                        m.record_latency(Duration::from_micros(100 + (t * per + i) % 5000));
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.obs.request.count(), threads * per);
    }

    #[test]
    fn batch_size_math() {
        let m = Metrics::new();
        m.batches.fetch_add(2, Ordering::Relaxed);
        m.batched_items.fetch_add(10, Ordering::Relaxed);
        assert_eq!(m.snapshot().mean_batch_size(), 5.0);
    }

    #[test]
    fn json_serializes() {
        let m = Metrics::new();
        let j = m.snapshot().to_json();
        assert!(j.get("completed").is_some());
    }

    /// Every `Snapshot` field appears in BOTH the JSON and the Prometheus
    /// output. The exhaustive destructure below fails to compile when a
    /// field is added to `Snapshot`, forcing this test (and
    /// `field_lists`) to be revisited — no silently unexported metric,
    /// now or in future PRs.
    #[test]
    fn every_snapshot_field_is_exported_in_both_formats() {
        let m = Metrics::new();
        m.submitted.fetch_add(1, Ordering::Relaxed);
        m.record_latency(Duration::from_millis(5));
        let snap = m.snapshot();

        let Snapshot {
            submitted: _,
            completed: _,
            rejected: _,
            tokens_in: _,
            decode_chunks: _,
            prefill_chunks: _,
            batches: _,
            batched_items: _,
            spilled: _,
            restored_from_spill: _,
            bytes_spilled: _,
            snapshots: _,
            fused_decode_batches: _,
            fused_decode_rows: _,
            max_fused_batch: _,
            forks: _,
            prefix_hits: _,
            prefix_misses: _,
            prefix_bytes_saved: _,
            prefix_cache_bytes: _,
            active_connections: _,
            shed_connections: _,
            wire_bytes_rx: _,
            wire_bytes_tx: _,
            frames_rx: _,
            frames_tx: _,
            protocol_errors: _,
            backpressure_stalls: _,
            worker_panics: _,
            worker_restarts: _,
            sessions_poisoned: _,
            request_timeouts: _,
            spill_write_failures: _,
            dropped_replies: _,
            latency_p50_ms: _,
            latency_p95_ms: _,
            latency_mean_ms: _,
            simd_backend: _,
        } = snap;

        // 38 struct fields render as 31 counters + 8 gauges (the two
        // derived means are gauge-only extras) plus the simd_backend
        // string label, asserted in both formats below.
        let counters = snap.counter_fields();
        let gauges = snap.gauge_fields();
        assert_eq!(counters.len(), 31);
        assert_eq!(gauges.len(), 8);

        let json = m.to_json();
        let prom = crate::obs::prom::render(&m);
        for (name, _) in &counters {
            assert!(json.get(name).is_some(), "JSON missing counter {name}");
            assert!(
                prom.contains(&format!("slay_{name}_total ")),
                "Prometheus missing counter {name}"
            );
        }
        for (name, _) in &gauges {
            assert!(json.get(name).is_some(), "JSON missing gauge {name}");
            assert!(
                prom.contains(&format!("slay_{name} ")),
                "Prometheus missing gauge {name}"
            );
        }
        // the string-valued backend label appears in both formats too
        assert_eq!(
            json.get("simd_backend").and_then(|j| j.as_str()),
            Some(snap.simd_backend)
        );
        assert!(
            prom.contains(&format!(
                "slay_simd_backend_info{{backend=\"{}\"}} 1",
                snap.simd_backend
            )),
            "Prometheus missing simd_backend info metric"
        );
        // and the nested stage object rides along in the full JSON
        assert!(json.get("stages").is_some());
    }

    #[test]
    fn event_helpers_bump_counter_and_ring_together() {
        let m = Metrics::new();
        m.protocol_error("bad frame".into());
        m.shed_connection("at cap 4".into());
        m.worker_restarted("shard 1".into());
        m.session_poisoned("seq 9".into());
        m.spill_write_failed("seq 9: io".into());
        m.snapshot_taken("to /tmp/x".into());
        let s = m.snapshot();
        assert_eq!(s.protocol_errors, 1);
        assert_eq!(s.shed_connections, 1);
        assert_eq!(s.worker_restarts, 1);
        assert_eq!(s.sessions_poisoned, 1);
        assert_eq!(s.spill_write_failures, 1);
        assert_eq!(s.snapshots, 1);
        let kinds: Vec<&str> = m.obs.events.tail(10).iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                "protocol_error",
                "shed_connection",
                "worker_restart",
                "session_poisoned",
                "spill_write_failure",
                "snapshot"
            ]
        );
    }

    #[test]
    fn fused_decode_counters_snapshot_and_serialize() {
        let m = Metrics::new();
        m.fused_decode_batches.fetch_add(4, Ordering::Relaxed);
        m.fused_decode_rows.fetch_add(48, Ordering::Relaxed);
        m.max_fused_batch.fetch_max(16, Ordering::Relaxed);
        m.max_fused_batch.fetch_max(9, Ordering::Relaxed); // high-water holds
        let s = m.snapshot();
        assert_eq!(s.fused_decode_batches, 4);
        assert_eq!(s.fused_decode_rows, 48);
        assert_eq!(s.max_fused_batch, 16);
        assert_eq!(s.mean_fused_batch_size(), 12.0);
        let j = s.to_json();
        assert_eq!(j.get("fused_decode_batches").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("fused_decode_rows").unwrap().as_usize(), Some(48));
        assert_eq!(j.get("max_fused_batch").unwrap().as_usize(), Some(16));
        assert_eq!(j.get("mean_fused_batch_size").unwrap().as_usize(), Some(12));
    }

    #[test]
    fn fork_and_prefix_cache_counters_snapshot_and_serialize() {
        let m = Metrics::new();
        m.forks.fetch_add(2, Ordering::Relaxed);
        m.prefix_hits.fetch_add(9, Ordering::Relaxed);
        m.prefix_misses.fetch_add(1, Ordering::Relaxed);
        m.prefix_bytes_saved.fetch_add(4096, Ordering::Relaxed);
        m.prefix_cache_bytes.store(2048, Ordering::Relaxed);
        m.active_connections.fetch_add(3, Ordering::Relaxed);
        m.shed_connections.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.forks, 2);
        assert_eq!(s.prefix_hits, 9);
        assert_eq!(s.prefix_misses, 1);
        assert_eq!(s.prefix_bytes_saved, 4096);
        assert_eq!(s.prefix_cache_bytes, 2048);
        assert_eq!(s.active_connections, 3);
        assert_eq!(s.shed_connections, 1);
        let j = s.to_json();
        assert_eq!(j.get("forks").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("prefix_hits").unwrap().as_usize(), Some(9));
        assert_eq!(j.get("prefix_misses").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("prefix_bytes_saved").unwrap().as_usize(), Some(4096));
        assert_eq!(j.get("prefix_cache_bytes").unwrap().as_usize(), Some(2048));
        assert_eq!(j.get("active_connections").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("shed_connections").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn wire_counters_snapshot_and_serialize() {
        let m = Metrics::new();
        m.wire_bytes_rx.fetch_add(512, Ordering::Relaxed);
        m.wire_bytes_tx.fetch_add(256, Ordering::Relaxed);
        m.frames_rx.fetch_add(7, Ordering::Relaxed);
        m.frames_tx.fetch_add(8, Ordering::Relaxed);
        m.protocol_errors.fetch_add(2, Ordering::Relaxed);
        m.backpressure_stalls.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.wire_bytes_rx, 512);
        assert_eq!(s.wire_bytes_tx, 256);
        assert_eq!(s.frames_rx, 7);
        assert_eq!(s.frames_tx, 8);
        assert_eq!(s.protocol_errors, 2);
        assert_eq!(s.backpressure_stalls, 1);
        let j = s.to_json();
        assert_eq!(j.get("wire_bytes_rx").unwrap().as_usize(), Some(512));
        assert_eq!(j.get("wire_bytes_tx").unwrap().as_usize(), Some(256));
        assert_eq!(j.get("frames_rx").unwrap().as_usize(), Some(7));
        assert_eq!(j.get("frames_tx").unwrap().as_usize(), Some(8));
        assert_eq!(j.get("protocol_errors").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("backpressure_stalls").unwrap().as_usize(), Some(1));
    }

    #[test]
    fn fault_tolerance_counters_snapshot_and_serialize() {
        let m = Metrics::new();
        m.worker_panics.fetch_add(2, Ordering::Relaxed);
        m.worker_restarts.fetch_add(1, Ordering::Relaxed);
        m.sessions_poisoned.fetch_add(3, Ordering::Relaxed);
        m.request_timeouts.fetch_add(5, Ordering::Relaxed);
        m.spill_write_failures.fetch_add(4, Ordering::Relaxed);
        m.dropped_replies.fetch_add(6, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.worker_panics, 2);
        assert_eq!(s.worker_restarts, 1);
        assert_eq!(s.sessions_poisoned, 3);
        assert_eq!(s.request_timeouts, 5);
        assert_eq!(s.spill_write_failures, 4);
        assert_eq!(s.dropped_replies, 6);
        let j = s.to_json();
        assert_eq!(j.get("worker_panics").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("worker_restarts").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("sessions_poisoned").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("request_timeouts").unwrap().as_usize(), Some(5));
        assert_eq!(j.get("spill_write_failures").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("dropped_replies").unwrap().as_usize(), Some(6));
    }

    #[test]
    fn spill_tier_counters_snapshot_and_serialize() {
        let m = Metrics::new();
        m.spilled.fetch_add(3, Ordering::Relaxed);
        m.restored_from_spill.fetch_add(2, Ordering::Relaxed);
        m.bytes_spilled.fetch_add(1024, Ordering::Relaxed);
        m.snapshots.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.spilled, 3);
        assert_eq!(s.restored_from_spill, 2);
        assert_eq!(s.bytes_spilled, 1024);
        assert_eq!(s.snapshots, 1);
        let j = s.to_json();
        assert_eq!(j.get("spilled").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("restored_from_spill").unwrap().as_usize(), Some(2));
        assert_eq!(j.get("bytes_spilled").unwrap().as_usize(), Some(1024));
        assert_eq!(j.get("snapshots").unwrap().as_usize(), Some(1));
    }
}
