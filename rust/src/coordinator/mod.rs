//! L3 — the serving coordinator.
//!
//! Architecture (vLLM-router-shaped, adapted to linear attention):
//!
//! ```text
//!  clients ──submit──▶ Coordinator ──hash(seq)──▶ shard queue ──▶ worker 0
//!                        │   │                        …              …
//!                        │   └──────metrics◀──────────┴──────────▶ worker W-1
//!                        │                                            │
//!                        │ snapshot(dir) / restore(cfg, dir)          │ evict / fault-in
//!                        ▼                                            ▼
//!                 manifest.json + seq_*.state   ◀── copy ──   spill dir (per shard)
//! ```
//!
//! * **Router**: sequences are hash-sharded across workers so each
//!   sequence's streaming state `(S, z)` is owned by exactly one thread —
//!   no locks on the hot path.
//! * **Dynamic batcher**: each worker gathers up to `max_batch` chunks or
//!   `max_wait` (parked in a timed recv, not spinning), runs the batch's
//!   decode group as fused cross-session blocks — one feature GEMM + B
//!   per-sequence state ops per wave (ADR-005) — and streams prefill
//!   chunks through their per-sequence states, decode-first.
//! * **Backpressure**: bounded `sync_channel` queues; a full queue rejects
//!   with [`request::ServeError::Backpressure`] instead of queueing
//!   unboundedly.
//! * **State manager**: [`state::SequenceStore`] — constant bytes per
//!   sequence for linear mechanisms (the linear-attention KV-cache analog)
//!   and a bounded rolling KV window for the exact quadratic baselines,
//!   LRU idle eviction.
//! * **Persistence** (ADR-004, [`persist`]): with a spill directory
//!   configured, idle eviction *pages states out* through the versioned
//!   session codec instead of destroying them and the worker faults them
//!   back in on the sequence's next chunk — the memory budget then bounds
//!   the resident set, not the session count. [`Coordinator::snapshot`]
//!   serializes every live session plus a manifest;
//!   [`Coordinator::restore`] rebuilds a coordinator from it **with a
//!   possibly different worker count**, re-dealing each state to its new
//!   owning shard (hash-resharding = the live-migration primitive).
//!
//! Every [`Mechanism`] serves through the same
//! [`crate::kernels::AttentionBackend`] session interface — the quadratic
//! baselines (softmax, Yat) run behind identical routing/batching, which
//! is what makes the SLAY-vs-exact serving comparisons apples-to-apples.

pub mod metrics;
pub mod persist;
pub mod prefix;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod state;
pub mod worker;

use crate::coordinator::metrics::{Metrics, Snapshot};
use crate::coordinator::request::{AttendChunk, AttendResult, ReplyTo, SeqId, ServeError, WorkItem};
use crate::coordinator::scheduler::BatchPolicy;
use crate::coordinator::state::StoreConfig;
use crate::kernels::config::Mechanism;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub mechanism: Mechanism,
    pub d_head: usize,
    pub d_v: usize,
    /// cosformer positional horizon / max expected context.
    pub horizon: usize,
    /// Rolling KV-window bound for quadratic sessions, decoupled from
    /// `horizon` (each quadratic sequence is *budgeted* at the fully
    /// populated window, so this knob — not the positional horizon —
    /// decides how many exact-baseline sequences the memory budget
    /// admits). `0` falls back to `horizon`.
    pub window: usize,
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Per-worker bounded queue capacity (backpressure threshold).
    pub queue_cap: usize,
    pub store: StoreConfig,
    /// Root directory the TCP `{"op":"snapshot"}` endpoint may write
    /// under. `None` disables snapshots over the wire (the in-process
    /// [`Coordinator::snapshot`] API is unaffected): a network peer must
    /// never choose arbitrary server-side paths.
    pub snapshot_root: Option<std::path::PathBuf>,
    /// Per-request deadline (`--request-timeout-ms`, ADR-008): stamped
    /// into every submitted [`WorkItem`]; workers answer items past it
    /// with [`ServeError::Timeout`] instead of computing, and both front
    /// ends bound their waits against it so no client hangs on a dead
    /// shard. `None` = no deadline (waits still carry a generous
    /// liveness fallback).
    pub request_timeout: Option<Duration>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            mechanism: Mechanism::Slay(crate::kernels::config::SlayConfig::default()),
            d_head: 32,
            d_v: 32,
            horizon: 131_072,
            window: crate::kernels::DEFAULT_QUADRATIC_WINDOW,
            workers: 4,
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
            store: StoreConfig::default(),
            snapshot_root: None,
            request_timeout: None,
        }
    }
}

/// Summary of one completed [`Coordinator::snapshot`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SnapshotReport {
    /// Live sessions serialized (resident + spilled, across all shards).
    pub sequences: usize,
    /// Total serialized state bytes written (excluding the manifest).
    pub bytes: u64,
}

/// One shard's channel + thread handle, behind a mutex so the liveness
/// check and respawn (ADR-008) are race-free across submitting threads.
struct ShardSlot {
    tx: mpsc::SyncSender<worker::Msg>,
    /// `None` only transiently (during shutdown's join, or when a respawn
    /// attempt itself failed).
    handle: Option<std::thread::JoinHandle<anyhow::Result<()>>>,
}

/// How long a control-plane round trip (create/release/len/fork/
/// snapshot/install acks) may wait before the shard is declared
/// unavailable — generous, because snapshots of large shards do real I/O.
const CONTROL_ACK_TIMEOUT: Duration = Duration::from_secs(30);

/// Fallback bound on a blocking [`Coordinator::attend`] when no
/// `request_timeout` is configured: liveness, not latency policy.
const ATTEND_FALLBACK_TIMEOUT: Duration = Duration::from_secs(120);

/// The running coordinator. Dropping it shuts the workers down.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    shards: Vec<std::sync::Mutex<ShardSlot>>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
    next_seq: AtomicU64,
}

/// Spawn one shard worker thread. `adopt` marks a *respawn* (ADR-008):
/// the replacement store re-admits every session its dead predecessor had
/// paged out to the shard's spill subdirectory.
fn spawn_worker(
    cfg: &CoordinatorConfig,
    w: usize,
    adopt: bool,
    metrics: &Arc<Metrics>,
    inflight: &Arc<AtomicU64>,
) -> anyhow::Result<(mpsc::SyncSender<worker::Msg>, std::thread::JoinHandle<anyhow::Result<()>>)> {
    let (tx, rx) = mpsc::sync_channel(cfg.queue_cap);
    // Each shard spills into its own subdirectory: shards never contend on
    // files, and a restore with a different worker count can't collide
    // with stale spills from the old layout.
    let mut store_cfg = cfg.store.clone();
    if let Some(base) = &store_cfg.spill_dir {
        store_cfg.spill_dir = Some(base.join(format!("shard_{w}")));
    }
    store_cfg.adopt_spills = adopt;
    let wcfg = worker::WorkerConfig {
        shard: w,
        mechanism: cfg.mechanism.clone(),
        d_head: cfg.d_head,
        d_v: cfg.d_v,
        horizon: cfg.horizon,
        window: cfg.window,
        policy: BatchPolicy { max_batch: cfg.max_batch, max_wait: cfg.max_wait },
        store: store_cfg,
    };
    let m = metrics.clone();
    let inf = inflight.clone();
    let handle = std::thread::Builder::new()
        .name(format!("slay-worker-{w}"))
        .spawn(move || worker::run(wcfg, rx, m, inf))?;
    Ok((tx, handle))
}

impl Coordinator {
    /// Spawn the worker topology.
    pub fn start(cfg: CoordinatorConfig) -> anyhow::Result<Coordinator> {
        anyhow::ensure!(cfg.workers > 0, "need at least one worker");
        let metrics = Arc::new(Metrics::new());
        metrics.obs.init_shards(cfg.workers);
        let inflight = Arc::new(AtomicU64::new(0));
        let mut shards = Vec::new();
        for w in 0..cfg.workers {
            let (tx, handle) = spawn_worker(&cfg, w, false, &metrics, &inflight)?;
            shards.push(std::sync::Mutex::new(ShardSlot { tx, handle: Some(handle) }));
        }
        crate::log_info!(
            "coordinator up: {} workers, mechanism={}, d_head={}",
            cfg.workers,
            cfg.mechanism.name(),
            cfg.d_head
        );
        Ok(Coordinator {
            cfg,
            shards,
            metrics,
            inflight,
            next_seq: AtomicU64::new(1),
        })
    }

    fn shard(&self, seq: SeqId) -> usize {
        // splitmix-style hash for uniform sharding
        let mut z = seq.0.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        (z >> 33) as usize % self.shards.len()
    }

    /// Hand out a live sender for `shard`, respawning the worker first if
    /// its thread is dead (ADR-008 supervision). The respawned shard
    /// re-adopts its spilled sessions; resident sessions died with the
    /// thread and will answer "unknown sequence" — a bounded structured
    /// error, never a hang.
    fn shard_sender(&self, shard: usize) -> mpsc::SyncSender<worker::Msg> {
        let mut slot = self.shards[shard].lock().unwrap_or_else(|e| e.into_inner());
        if slot.handle.as_ref().is_some_and(|h| h.is_finished()) {
            if let Some(h) = slot.handle.take() {
                if h.join().is_err() {
                    // uncaught panic killed the thread (the per-item
                    // guards count the caught ones themselves)
                    self.metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
                }
            }
            match spawn_worker(&self.cfg, shard, true, &self.metrics, &self.inflight) {
                Ok((tx, handle)) => {
                    self.metrics.worker_restarted(format!(
                        "shard {shard} worker died; respawned, spilled sessions re-adopted"
                    ));
                    crate::log_warn!(
                        "worker thread for shard {shard} died; respawned \
                         (spilled sessions re-adopted)"
                    );
                    slot.tx = tx;
                    slot.handle = Some(handle);
                }
                Err(e) => {
                    // the stale tx below fails fast as Disconnected; the
                    // next touch retries the spawn
                    crate::log_error!("failed to respawn worker for shard {shard}: {e}");
                }
            }
        }
        slot.tx.clone()
    }

    /// One crash-safe control round trip (ADR-008): fresh ack channel per
    /// attempt, bounded wait, one retry — the retry's [`shard_sender`]
    /// sees the dead thread and respawns it. Exhausted attempts surface
    /// [`ServeError::ShardUnavailable`] instead of hanging forever on an
    /// ack that will never come.
    ///
    /// [`shard_sender`]: Coordinator::shard_sender
    fn control<T>(
        &self,
        shard: usize,
        mk: impl Fn(mpsc::Sender<T>) -> worker::Msg,
    ) -> anyhow::Result<T> {
        for attempt in 0..2 {
            let (ack, rx) = mpsc::channel();
            if self.shard_sender(shard).send(mk(ack)).is_err() {
                continue; // queue closed: the next attempt respawns
            }
            match rx.recv_timeout(CONTROL_ACK_TIMEOUT) {
                Ok(v) => return Ok(v),
                // worker died holding our ack: retry once on a respawn
                Err(mpsc::RecvTimeoutError::Disconnected) if attempt == 0 => continue,
                Err(_) => break,
            }
        }
        Err(ServeError::ShardUnavailable { shard }.into())
    }

    /// Admit a new sequence; returns its id.
    pub fn create_sequence(&self) -> anyhow::Result<SeqId> {
        let id = SeqId(self.next_seq.fetch_add(1, Ordering::Relaxed));
        self.control(self.shard(id), |ack| worker::Msg::Create(id, ack))??;
        Ok(id)
    }

    /// Clone a live (or spilled) sequence under a freshly allocated id
    /// (ADR-006): linear states copy `(S, z)` outright, quadratic states
    /// fork copy-on-write window pages, spilled parents fork by codec-file
    /// copy without fault-in. The child id is drawn from the same
    /// allocator as [`Coordinator::create_sequence`] but constrained to
    /// the parent's shard — a fork is a shard-local O(pages) operation,
    /// never a cross-shard state transfer. Ids that hash elsewhere are
    /// simply skipped (the allocator is monotonic; gaps are harmless), at
    /// an expected cost of `workers` draws.
    ///
    /// Errors when the parent is unknown, the child cannot be admitted,
    /// or the parent is mid-flight in a forming batch (deterministic
    /// rejection — never a torn clone; retry after its replies arrive).
    pub fn fork_sequence(&self, parent: SeqId) -> anyhow::Result<SeqId> {
        let pshard = self.shard(parent);
        let child = loop {
            let id = SeqId(self.next_seq.fetch_add(1, Ordering::Relaxed));
            if self.shard(id) == pshard {
                break id;
            }
        };
        self.control(pshard, |ack| worker::Msg::Fork(parent, child, ack))??;
        Ok(child)
    }

    /// Release a finished sequence's state.
    pub fn release_sequence(&self, id: SeqId) -> anyhow::Result<bool> {
        self.control(self.shard(id), |ack| worker::Msg::Release(id, ack))
    }

    /// Tokens a sequence has absorbed.
    pub fn sequence_len(&self, id: SeqId) -> anyhow::Result<Option<usize>> {
        self.control(self.shard(id), |ack| worker::Msg::Len(id, ack))
    }

    /// Non-blocking submit; the returned receiver yields the result.
    /// Fails fast with [`ServeError::Backpressure`] when the shard is full.
    pub fn submit(
        &self,
        chunk: AttendChunk,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<AttendResult>>> {
        let (tx, rx) = mpsc::channel();
        self.submit_with(chunk, ReplyTo::Channel(tx))?;
        Ok(rx)
    }

    /// Non-blocking submit with an explicit reply route. The epoll front
    /// end (ADR-007) uses [`ReplyTo::Completion`] to fan every in-flight
    /// request into one tagged queue; validation, accounting, and
    /// backpressure are identical to [`Coordinator::submit`].
    pub fn submit_with(&self, chunk: AttendChunk, reply: ReplyTo) -> anyhow::Result<()> {
        let submitted = std::time::Instant::now(); // tick 0
        chunk.validate(self.cfg.d_head)?;
        let shard = self.shard(chunk.seq);
        let now = std::time::Instant::now(); // tick 1: shard enqueue
        let item = WorkItem {
            chunk,
            submitted,
            enqueued: now,
            deadline: self.cfg.request_timeout.map(|t| now + t),
            reply,
        };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.inflight.fetch_add(1, Ordering::Relaxed);
        // Queue-depth gauge: incremented *before* the send (and rolled
        // back on failure) so the worker's decrement-at-dequeue can never
        // observe the item before the increment landed.
        if let Some(ss) = self.metrics.obs.shard(shard) {
            ss.queue_depth.fetch_add(1, Ordering::Relaxed);
        }
        match self.shard_sender(shard).try_send(worker::Msg::Work(item)) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.inflight.fetch_sub(1, Ordering::Relaxed);
                if let Some(ss) = self.metrics.obs.shard(shard) {
                    ss.queue_depth.fetch_sub(1, Ordering::Relaxed);
                }
                match e {
                    mpsc::TrySendError::Full(_) => {
                        self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                        Err(ServeError::Backpressure { depth: self.cfg.queue_cap }.into())
                    }
                    mpsc::TrySendError::Disconnected(_) => {
                        // shard_sender just respawned-if-dead, so a closed
                        // queue here means the respawn itself failed
                        Err(ServeError::ShardUnavailable { shard }.into())
                    }
                }
            }
        }
    }

    /// Blocking convenience: submit and wait for the result — bounded
    /// (ADR-008) by the request deadline plus reply slack, or by a
    /// generous liveness fallback when no deadline is configured. No
    /// caller parks forever on a shard that died mid-request.
    pub fn attend(&self, chunk: AttendChunk) -> anyhow::Result<AttendResult> {
        let shard = self.shard(chunk.seq);
        let rx = self.submit(chunk)?;
        let wait = match self.cfg.request_timeout {
            Some(t) => t + Duration::from_millis(500),
            None => ATTEND_FALLBACK_TIMEOUT,
        };
        match rx.recv_timeout(wait) {
            Ok(r) => r,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                self.metrics.request_timeouts.fetch_add(1, Ordering::Relaxed);
                Err(ServeError::Timeout.into())
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                Err(ServeError::ShardUnavailable { shard }.into())
            }
        }
    }

    /// Current in-flight work items (queue depth proxy).
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn metrics(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    /// Shared metrics sink — the TCP server publishes its connection
    /// gauges (`active_connections`, `shed_connections`) through it, the
    /// `--metrics-addr` scrape listener renders it, and benches toggle
    /// `metrics_handle().obs` for a no-record baseline.
    pub fn metrics_handle(&self) -> Arc<Metrics> {
        self.metrics.clone()
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Snapshot every live session into `dir` (ADR-004). Per shard, the
    /// snapshot message queues behind all work the shard has already
    /// accepted — so the snapshot includes exactly the chunks whose
    /// replies preceded the call (chunks submitted concurrently race it).
    /// Each worker serializes its resident *and* spilled states (fsynced);
    /// the coordinator then commits the snapshot by writing the manifest
    /// (mechanism spec, geometry, `next_seq`, sequence roster) last.
    pub fn snapshot(&self, dir: &std::path::Path) -> anyhow::Result<SnapshotReport> {
        std::fs::create_dir_all(dir)?;
        let mut pending = Vec::with_capacity(self.shards.len());
        for shard in 0..self.shards.len() {
            let (ack, rx) = mpsc::channel();
            self.shard_sender(shard)
                .send(worker::Msg::Snapshot(dir.to_path_buf(), ack))
                .map_err(|_| ServeError::ShardUnavailable { shard })?;
            pending.push((shard, rx));
        }
        let mut seqs = Vec::new();
        let mut bytes = 0u64;
        for (shard, rx) in pending {
            let records = rx
                .recv_timeout(CONTROL_ACK_TIMEOUT)
                .map_err(|_| ServeError::ShardUnavailable { shard })??;
            for (id, len, b) in records {
                seqs.push((id.0, len));
                bytes += b;
            }
        }
        seqs.sort_unstable();
        let manifest = persist::Manifest::from_config(
            &self.cfg,
            self.next_seq.load(Ordering::Relaxed),
            seqs,
        );
        manifest.save(dir)?;
        self.metrics.snapshot_taken(format!(
            "{} sequences, {bytes} bytes -> {}",
            manifest.seqs.len(),
            dir.display()
        ));
        crate::log_info!(
            "snapshot: {} sequences, {bytes} state bytes -> {}",
            manifest.seqs.len(),
            dir.display()
        );
        Ok(SnapshotReport { sequences: manifest.seqs.len(), bytes })
    }

    /// Rebuild a coordinator from a [`Coordinator::snapshot`] directory —
    /// **including with a different `workers` count**: sequences are
    /// hash-sharded by id, so every serialized state is re-dealt to its
    /// new owning shard on install. That re-deal is the live-migration /
    /// rebalance primitive: snapshot on W workers, restore on W′.
    ///
    /// `cfg` must be state-compatible with the snapshot (mechanism spec,
    /// `d_head`/`d_v`, `horizon`/`window` — use
    /// [`persist::Manifest::apply_to`] to derive one); topology knobs
    /// (workers, batching, queue caps, store budget) are free to change.
    /// Every state file is decoded through the backend's validating
    /// loader, so a wrong-mechanism restore fails fast instead of serving
    /// garbage.
    pub fn restore(cfg: CoordinatorConfig, dir: &std::path::Path) -> anyhow::Result<Coordinator> {
        let manifest = persist::Manifest::load(dir)?;
        manifest.check_compatible(&cfg)?;
        let coord = Coordinator::start(cfg)?;
        coord.next_seq.store(manifest.next_seq.max(1), Ordering::Relaxed);
        // Dispatch every install first, then collect the acks: shards
        // decode their state files in parallel instead of one blocking
        // round-trip per sequence (restore throughput is the migration
        // path's headline number).
        let mut pending = Vec::with_capacity(manifest.seqs.len());
        for &(id, _len) in &manifest.seqs {
            let id = SeqId(id);
            let shard = coord.shard(id);
            let (ack, rx) = mpsc::channel();
            coord
                .shard_sender(shard)
                .send(worker::Msg::Install(id, persist::state_file(dir, id), ack))
                .map_err(|_| ServeError::ShardUnavailable { shard })?;
            pending.push((shard, rx));
        }
        for (shard, rx) in pending {
            rx.recv_timeout(CONTROL_ACK_TIMEOUT)
                .map_err(|_| ServeError::ShardUnavailable { shard })??;
        }
        // Roster audit: installs go through the normal admission path, so
        // a store too small for the snapshot (and without a spill tier to
        // absorb the overflow) would silently *evict* earlier installs.
        // Every manifest sequence must still be present at its recorded
        // length, or the restore is a failure — not a partial success.
        for &(id, len) in &manifest.seqs {
            let got = coord.sequence_len(SeqId(id))?;
            anyhow::ensure!(
                got == Some(len),
                "restore lost sequence {id} (store now holds {got:?}, snapshot recorded {len} \
                 tokens): the target store is too small for the snapshot roster — raise \
                 store.memory_budget/max_sequences or configure a spill_dir"
            );
        }
        crate::log_info!(
            "restored {} sequences from {} across {} workers",
            manifest.seqs.len(),
            dir.display(),
            coord.shards.len()
        );
        Ok(coord)
    }

    /// Graceful shutdown: drain queues, join workers.
    pub fn shutdown(self) -> anyhow::Result<()> {
        for slot in &self.shards {
            let s = slot.lock().unwrap_or_else(|e| e.into_inner());
            let _ = s.tx.send(worker::Msg::Shutdown);
        }
        for slot in &self.shards {
            let h = slot.lock().unwrap_or_else(|e| e.into_inner()).handle.take();
            if let Some(h) = h {
                h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
            }
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for slot in &self.shards {
            let s = slot.lock().unwrap_or_else(|e| e.into_inner());
            let _ = s.tx.send(worker::Msg::Shutdown);
        }
        for slot in &self.shards {
            let h = slot.lock().unwrap_or_else(|e| e.into_inner()).handle.take();
            if let Some(h) = h {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::linalg::Mat;
    use crate::math::rng::Rng;

    fn cfg() -> CoordinatorConfig {
        CoordinatorConfig {
            mechanism: Mechanism::EluLinear,
            d_head: 8,
            d_v: 8,
            horizon: 64,
            window: 0,
            workers: 2,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            queue_cap: 64,
            request_timeout: Some(Duration::from_millis(2000)),
            ..CoordinatorConfig::default()
        }
    }

    fn chunk(seq: SeqId, n: usize, rng: &mut Rng) -> AttendChunk {
        AttendChunk {
            seq,
            q: Mat::randn(n, 8, rng),
            k: Mat::randn(n, 8, rng),
            v: Mat::randn(n, 8, rng),
        }
    }

    #[test]
    fn dead_worker_is_respawned_and_requests_stay_bounded() {
        let c = Coordinator::start(cfg()).unwrap();
        let id = c.create_sequence().unwrap();
        let shard = c.shard(id);
        // Kill the sequence's owning shard out from under the coordinator
        // (standing in for the worker_loop fault site's uncaught panic).
        {
            let slot = c.shards[shard].lock().unwrap();
            slot.tx.send(worker::Msg::Shutdown).unwrap();
        }
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let dead = {
                let slot = c.shards[shard].lock().unwrap();
                slot.handle.as_ref().is_some_and(|h| h.is_finished())
            };
            if dead {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "worker never exited");
            std::thread::yield_now();
        }
        // The session was resident on the dead shard (no spill tier): its
        // next chunk must get a bounded structured error, not a hang...
        let mut rng = Rng::new(3);
        let err = c
            .attend(chunk(id, 1, &mut rng))
            .expect_err("lost session must error, not hang");
        assert!(err.to_string().contains("unknown sequence"), "{err}");
        assert!(c.metrics().worker_restarts >= 1, "detection must respawn the shard");
        // ...and the respawned shard admits + serves fresh sessions.
        let mut revived = None;
        for _ in 0..64 {
            let id2 = c.create_sequence().unwrap();
            if c.shard(id2) == shard {
                revived = Some(id2);
                break;
            }
        }
        let id2 = revived.expect("64 draws must land on the respawned shard");
        let r = c.attend(chunk(id2, 4, &mut rng)).expect("respawned shard serves");
        assert_eq!(r.seq_len, 4);
    }
}
