//! L3 — the serving coordinator.
//!
//! Architecture (vLLM-router-shaped, adapted to linear attention):
//!
//! ```text
//!  clients ──submit──▶ Coordinator ──hash(seq)──▶ shard queue ──▶ worker 0
//!                        │                            …              …
//!                        └────────metrics◀────────────┴──────────▶ worker W-1
//! ```
//!
//! * **Router**: sequences are hash-sharded across workers so each
//!   sequence's streaming state `(S, z)` is owned by exactly one thread —
//!   no locks on the hot path.
//! * **Dynamic batcher**: each worker gathers up to `max_batch` chunks or
//!   `max_wait`, maps features over zero-copy views of each chunk's arrival
//!   buffers at its sequence's true position, then streams chunks through
//!   their per-sequence states (decode-first).
//! * **Backpressure**: bounded `sync_channel` queues; a full queue rejects
//!   with [`request::ServeError::Backpressure`] instead of queueing
//!   unboundedly.
//! * **State manager**: [`state::SequenceStore`] — constant bytes per
//!   sequence for linear mechanisms (the linear-attention KV-cache analog)
//!   and a bounded rolling KV window for the exact quadratic baselines,
//!   LRU idle eviction.
//!
//! Every [`Mechanism`] serves through the same
//! [`crate::kernels::AttentionBackend`] session interface — the quadratic
//! baselines (softmax, Yat) run behind identical routing/batching, which
//! is what makes the SLAY-vs-exact serving comparisons apples-to-apples.

pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod state;
pub mod worker;

use crate::coordinator::metrics::{Metrics, Snapshot};
use crate::coordinator::request::{AttendChunk, AttendResult, SeqId, ServeError, WorkItem};
use crate::coordinator::scheduler::BatchPolicy;
use crate::coordinator::state::StoreConfig;
use crate::kernels::config::Mechanism;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub mechanism: Mechanism,
    pub d_head: usize,
    pub d_v: usize,
    /// cosformer positional horizon / max expected context.
    pub horizon: usize,
    /// Rolling KV-window bound for quadratic sessions, decoupled from
    /// `horizon` (each quadratic sequence is *budgeted* at the fully
    /// populated window, so this knob — not the positional horizon —
    /// decides how many exact-baseline sequences the memory budget
    /// admits). `0` falls back to `horizon`.
    pub window: usize,
    pub workers: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Per-worker bounded queue capacity (backpressure threshold).
    pub queue_cap: usize,
    pub store: StoreConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            mechanism: Mechanism::Slay(crate::kernels::config::SlayConfig::default()),
            d_head: 32,
            d_v: 32,
            horizon: 131_072,
            window: crate::kernels::DEFAULT_QUADRATIC_WINDOW,
            workers: 4,
            max_batch: 32,
            max_wait: Duration::from_millis(2),
            queue_cap: 1024,
            store: StoreConfig::default(),
        }
    }
}

/// The running coordinator. Dropping it shuts the workers down.
pub struct Coordinator {
    cfg: CoordinatorConfig,
    senders: Vec<mpsc::SyncSender<worker::Msg>>,
    handles: Vec<std::thread::JoinHandle<anyhow::Result<()>>>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
    next_seq: AtomicU64,
}

impl Coordinator {
    /// Spawn the worker topology.
    pub fn start(cfg: CoordinatorConfig) -> anyhow::Result<Coordinator> {
        anyhow::ensure!(cfg.workers > 0, "need at least one worker");
        let metrics = Arc::new(Metrics::new());
        let inflight = Arc::new(AtomicU64::new(0));
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for w in 0..cfg.workers {
            let (tx, rx) = mpsc::sync_channel(cfg.queue_cap);
            let wcfg = worker::WorkerConfig {
                mechanism: cfg.mechanism.clone(),
                d_head: cfg.d_head,
                d_v: cfg.d_v,
                horizon: cfg.horizon,
                window: cfg.window,
                policy: BatchPolicy { max_batch: cfg.max_batch, max_wait: cfg.max_wait },
                store: cfg.store.clone(),
            };
            let m = metrics.clone();
            let inf = inflight.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("slay-worker-{w}"))
                    .spawn(move || worker::run(wcfg, rx, m, inf))?,
            );
            senders.push(tx);
        }
        crate::log_info!(
            "coordinator up: {} workers, mechanism={}, d_head={}",
            cfg.workers,
            cfg.mechanism.name(),
            cfg.d_head
        );
        Ok(Coordinator {
            cfg,
            senders,
            handles,
            metrics,
            inflight,
            next_seq: AtomicU64::new(1),
        })
    }

    fn shard(&self, seq: SeqId) -> usize {
        // splitmix-style hash for uniform sharding
        let mut z = seq.0.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        (z >> 33) as usize % self.senders.len()
    }

    /// Admit a new sequence; returns its id.
    pub fn create_sequence(&self) -> anyhow::Result<SeqId> {
        let id = SeqId(self.next_seq.fetch_add(1, Ordering::Relaxed));
        let (tx, rx) = mpsc::channel();
        self.senders[self.shard(id)]
            .send(worker::Msg::Create(id, tx))
            .map_err(|_| ServeError::Shutdown)?;
        rx.recv().map_err(|_| ServeError::Shutdown)??;
        Ok(id)
    }

    /// Release a finished sequence's state.
    pub fn release_sequence(&self, id: SeqId) -> anyhow::Result<bool> {
        let (tx, rx) = mpsc::channel();
        self.senders[self.shard(id)]
            .send(worker::Msg::Release(id, tx))
            .map_err(|_| ServeError::Shutdown)?;
        Ok(rx.recv().map_err(|_| ServeError::Shutdown)?)
    }

    /// Tokens a sequence has absorbed.
    pub fn sequence_len(&self, id: SeqId) -> anyhow::Result<Option<usize>> {
        let (tx, rx) = mpsc::channel();
        self.senders[self.shard(id)]
            .send(worker::Msg::Len(id, tx))
            .map_err(|_| ServeError::Shutdown)?;
        Ok(rx.recv().map_err(|_| ServeError::Shutdown)?)
    }

    /// Non-blocking submit; the returned receiver yields the result.
    /// Fails fast with [`ServeError::Backpressure`] when the shard is full.
    pub fn submit(
        &self,
        chunk: AttendChunk,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<AttendResult>>> {
        chunk.validate(self.cfg.d_head)?;
        let shard = self.shard(chunk.seq);
        let (tx, rx) = mpsc::channel();
        let item = WorkItem { chunk, enqueued: std::time::Instant::now(), reply: tx };
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.inflight.fetch_add(1, Ordering::Relaxed);
        match self.senders[shard].try_send(worker::Msg::Work(item)) {
            Ok(()) => Ok(rx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.inflight.fetch_sub(1, Ordering::Relaxed);
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(ServeError::Backpressure { depth: self.cfg.queue_cap }.into())
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                self.inflight.fetch_sub(1, Ordering::Relaxed);
                Err(ServeError::Shutdown.into())
            }
        }
    }

    /// Blocking convenience: submit and wait for the result.
    pub fn attend(&self, chunk: AttendChunk) -> anyhow::Result<AttendResult> {
        let rx = self.submit(chunk)?;
        rx.recv().map_err(|_| ServeError::Shutdown)?
    }

    /// Current in-flight work items (queue depth proxy).
    pub fn inflight(&self) -> u64 {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn metrics(&self) -> Snapshot {
        self.metrics.snapshot()
    }

    pub fn config(&self) -> &CoordinatorConfig {
        &self.cfg
    }

    /// Graceful shutdown: drain queues, join workers.
    pub fn shutdown(mut self) -> anyhow::Result<()> {
        for tx in &self.senders {
            let _ = tx.send(worker::Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            h.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
        }
        Ok(())
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(worker::Msg::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
