//! Shard worker: owns one [`SequenceStore`] shard, an
//! [`AttentionBackend`] and a [`Scratch`] arena, forms dynamic batches
//! from its queue, then streams each chunk through its sequence state via
//! the zero-allocation `prefill_into` path: the backend maps features
//! over zero-copy views of the chunk's arrival buffers at the sequence's
//! true position (ADR-002) with every intermediate — feature rows, block
//! scores, projections — recycled from the worker's arena (ADR-003). In
//! steady state the only per-chunk allocation on this path is the result
//! tensor handed back over the reply channel. Mechanisms without a
//! feature decomposition (the exact quadratic baselines) are served
//! through the same interface over their rolling KV windows.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{AttendResult, SeqId, WorkItem};
use crate::coordinator::scheduler::{order_batch, BatchPolicy};
use crate::coordinator::state::{SequenceStore, SnapshotRecord, StoreConfig};
use crate::kernels::config::Mechanism;
use crate::kernels::AttentionBackend;
use crate::math::linalg::{Mat, Scratch};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Control/work messages a worker consumes.
pub enum Msg {
    Work(WorkItem),
    Create(SeqId, mpsc::Sender<anyhow::Result<()>>),
    Release(SeqId, mpsc::Sender<bool>),
    /// Query a sequence's length (diagnostics).
    Len(SeqId, mpsc::Sender<Option<usize>>),
    /// Serialize every sequence this shard owns (resident and spilled)
    /// into the directory (coordinator snapshot, ADR-004); replies with
    /// one [`SnapshotRecord`] per sequence. Queued behind all work the
    /// shard has already accepted, so the snapshot includes exactly the
    /// chunks whose replies preceded it.
    Snapshot(std::path::PathBuf, mpsc::Sender<anyhow::Result<Vec<SnapshotRecord>>>),
    /// Re-admit one serialized sequence under the given id (coordinator
    /// restore / shard migration): the state file is loaded through the
    /// backend's validating decoder, so a snapshot can never be resumed
    /// under the wrong mechanism or geometry.
    Install(SeqId, std::path::PathBuf, mpsc::Sender<anyhow::Result<()>>),
    Shutdown,
}

pub struct WorkerConfig {
    pub mechanism: Mechanism,
    pub d_head: usize,
    pub d_v: usize,
    pub horizon: usize,
    /// Rolling KV-window bound for quadratic sessions (0 = fall back to
    /// `horizon`); see [`crate::kernels::build_with_window`].
    pub window: usize,
    pub policy: BatchPolicy,
    pub store: StoreConfig,
}

/// Run the worker loop until `Shutdown`. Owns its shard exclusively —
/// no locks on the hot path. The denominator stabilizer δ lives inside the
/// backend (it flows from the mechanism config), so every mechanism serves
/// with its own normalization floor.
pub fn run(
    cfg: WorkerConfig,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
) -> anyhow::Result<()> {
    let backend =
        crate::kernels::build_with_window(&cfg.mechanism, cfg.d_head, cfg.horizon, cfg.window)?;
    let mut store = SequenceStore::new(cfg.store.clone());
    store.attach_metrics(metrics.clone());
    // Per-worker scratch arena (ADR-003): reused feature/projection/score
    // buffers make steady-state prefill and decode allocation-free.
    let mut scratch = Scratch::new();

    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => return Ok(()), // senders dropped
        };
        match msg {
            Msg::Shutdown => return Ok(()),
            Msg::Create(id, ack) => {
                let _ = ack.send(store.create(id, backend.new_state(cfg.d_v)));
            }
            Msg::Release(id, ack) => {
                let _ = ack.send(store.release(id));
            }
            Msg::Len(id, ack) => {
                let _ = ack.send(store.seq_len(id));
            }
            Msg::Snapshot(dir, ack) => {
                let _ = ack.send(store.export_all(&dir));
            }
            Msg::Install(id, path, ack) => {
                let _ = ack.send(install(&mut store, backend.as_ref(), id, &path));
            }
            Msg::Work(first) => {
                // Continuous batching (§Perf iteration 1): drain whatever is
                // already queued — up to max_batch — WITHOUT an artificial
                // wait. Under concurrent load items accumulate while the
                // previous batch computes, so large batches form naturally;
                // a lone decode request proceeds immediately instead of
                // eating the max_wait window (was the p50 decode latency
                // floor). `max_wait` still bounds a short gather when the
                // batch is under-filled and traffic is in flight.
                let mut batch = vec![first];
                let first_arrival = Instant::now();
                let mut shutdown = false;
                // A snapshot that arrives during batch formation is
                // deferred until after the batch is processed: the work
                // items being gathered were accepted before it, and the
                // snapshot contract is "includes every chunk whose reply
                // preceded it" — so the gather closes early instead.
                let mut deferred_snapshot = None;
                loop {
                    // non-blocking drain first
                    match rx.try_recv() {
                        Ok(Msg::Work(w)) => {
                            batch.push(w);
                            if batch.len() >= cfg.policy.max_batch {
                                break;
                            }
                            continue;
                        }
                        Ok(Msg::Create(id, ack)) => {
                            let _ = ack.send(store.create(id, backend.new_state(cfg.d_v)));
                            continue;
                        }
                        Ok(Msg::Release(id, ack)) => {
                            let _ = ack.send(store.release(id));
                            continue;
                        }
                        Ok(Msg::Len(id, ack)) => {
                            let _ = ack.send(store.seq_len(id));
                            continue;
                        }
                        Ok(Msg::Snapshot(dir, ack)) => {
                            deferred_snapshot = Some((dir, ack));
                            break;
                        }
                        Ok(Msg::Install(id, path, ack)) => {
                            let _ = ack.send(install(&mut store, backend.as_ref(), id, &path));
                            continue;
                        }
                        Ok(Msg::Shutdown) => {
                            shutdown = true;
                            break;
                        }
                        Err(mpsc::TryRecvError::Empty) => {}
                        Err(mpsc::TryRecvError::Disconnected) => {
                            shutdown = true;
                            break;
                        }
                    }
                    // queue empty: only linger while other requests are in
                    // flight and the batch is still small
                    let now = Instant::now();
                    let in_flight = inflight.load(Ordering::Relaxed) as usize > batch.len();
                    if !in_flight || cfg.policy.should_close(first_arrival, batch.len(), now) {
                        break;
                    }
                    std::thread::yield_now();
                }
                process_batch(
                    &mut store,
                    backend.as_ref(),
                    &mut scratch,
                    batch,
                    &metrics,
                    &inflight,
                );
                if let Some((dir, ack)) = deferred_snapshot {
                    let _ = ack.send(store.export_all(&dir));
                }
                if shutdown {
                    return Ok(());
                }
            }
        }
    }
}

/// Load one serialized state through the backend's validating decoder and
/// admit it under `id` — the restore / shard-migration entry (ADR-004).
fn install(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    id: SeqId,
    path: &std::path::Path,
) -> anyhow::Result<()> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("cannot open state file {}: {e}", path.display()))?;
    let state = backend.load_state(&mut std::io::BufReader::new(f))?;
    store.create(id, state)
}

fn process_batch(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    scratch: &mut Scratch,
    mut batch: Vec<WorkItem>,
    metrics: &Metrics,
    inflight: &AtomicU64,
) {
    order_batch(&mut batch);
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics
        .batched_items
        .fetch_add(batch.len() as u64, Ordering::Relaxed);

    // ---- per-chunk streaming through sequence state ---------------------
    // Each chunk streams through `prefill_into`: the backend maps features
    // over zero-copy views of the arrival buffers at the session's true
    // position (`state.len()`, so cosformer serving matches its one-shot
    // forward) and draws every intermediate from the worker's scratch
    // arena. The result tensor is the only allocation on this path — it
    // crosses the reply channel, so the caller owns it.
    for w in batch {
        let n = w.chunk.n_tokens();
        if w.chunk.is_decode() {
            metrics.decode_chunks.fetch_add(1, Ordering::Relaxed);
        } else {
            metrics.prefill_chunks.fetch_add(1, Ordering::Relaxed);
        }
        let result = match store.get_mut(w.chunk.seq) {
            None => Err(anyhow::anyhow!("unknown sequence {:?}", w.chunk.seq)),
            Some(state) => {
                let (q, k, v) = (w.chunk.q.view(), w.chunk.k.view(), w.chunk.v.view());
                let mut y = Mat::zeros(v.rows(), v.cols());
                let res = backend.prefill_into(scratch, state, q, k, v, y.view_mut());
                res.map(|()| AttendResult {
                    seq: w.chunk.seq,
                    y,
                    seq_len: state.len(),
                    latency: w.enqueued.elapsed(),
                })
            }
        };
        if let Ok(res) = &result {
            metrics.record_latency(res.latency);
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            metrics
                .tokens_in
                .fetch_add(n as u64, Ordering::Relaxed);
        }
        inflight.fetch_sub(1, Ordering::Relaxed);
        let _ = w.reply.send(result);
    }
}
