//! Shard worker: owns one [`SequenceStore`] shard, an
//! [`AttentionBackend`] and a [`Scratch`] arena, forms dynamic batches
//! from its queue (parking in `recv_timeout` for the window's remainder
//! while under-filled — no busy spin), then executes them in two lanes:
//!
//! * **fused decode** (ADR-005): the batch's decode chunks — different
//!   sequences, n = 1 each, each at its own position — are stacked into
//!   one q/k/v block and advanced by ONE
//!   [`AttentionBackend::decode_batch_with`] call per wave (same-sequence
//!   repeats split into ordered waves), with the states borrowed
//!   disjointly via [`SequenceStore::get_many_mut`]. B matvecs become one
//!   feature GEMM + B cheap state ops, bit-identical per sequence to the
//!   per-item path.
//! * **per-item prefill**: each prefill chunk streams through the
//!   zero-allocation `prefill_into` path: the backend maps features over
//!   zero-copy views of the chunk's arrival buffers at the sequence's
//!   true position (ADR-002) with every intermediate — feature rows,
//!   block scores, projections — recycled from the worker's arena
//!   (ADR-003).
//!
//! In steady state the only per-chunk allocation on these paths is the
//! result tensor handed back over the reply channel. Mechanisms without a
//! feature decomposition (the exact quadratic baselines) are served
//! through the same interface over their rolling KV windows.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{AttendResult, SeqId, WorkItem};
use crate::coordinator::scheduler::{order_batch, BatchPolicy};
use crate::coordinator::state::{SequenceStore, SnapshotRecord, StoreConfig};
use crate::kernels::config::Mechanism;
use crate::kernels::AttentionBackend;
use crate::math::linalg::{Mat, MatView, MatViewMut, Scratch};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Control/work messages a worker consumes.
pub enum Msg {
    Work(WorkItem),
    Create(SeqId, mpsc::Sender<anyhow::Result<()>>),
    Release(SeqId, mpsc::Sender<bool>),
    /// Query a sequence's length (diagnostics).
    Len(SeqId, mpsc::Sender<Option<usize>>),
    /// Serialize every sequence this shard owns (resident and spilled)
    /// into the directory (coordinator snapshot, ADR-004); replies with
    /// one [`SnapshotRecord`] per sequence. Queued behind all work the
    /// shard has already accepted, so the snapshot includes exactly the
    /// chunks whose replies preceded it.
    Snapshot(std::path::PathBuf, mpsc::Sender<anyhow::Result<Vec<SnapshotRecord>>>),
    /// Re-admit one serialized sequence under the given id (coordinator
    /// restore / shard migration): the state file is loaded through the
    /// backend's validating decoder, so a snapshot can never be resumed
    /// under the wrong mechanism or geometry.
    Install(SeqId, std::path::PathBuf, mpsc::Sender<anyhow::Result<()>>),
    /// Clone a live (or spilled) sequence under a fresh id on this shard
    /// (ADR-006): `Fork(parent, child, ack)`. Rejected deterministically
    /// when the parent is mid-flight in the forming batch — never a torn
    /// clone.
    Fork(SeqId, SeqId, mpsc::Sender<anyhow::Result<()>>),
    Shutdown,
}

pub struct WorkerConfig {
    pub mechanism: Mechanism,
    pub d_head: usize,
    pub d_v: usize,
    pub horizon: usize,
    /// Rolling KV-window bound for quadratic sessions (0 = fall back to
    /// `horizon`); see [`crate::kernels::build_with_window`].
    pub window: usize,
    pub policy: BatchPolicy,
    pub store: StoreConfig,
}

/// Run the worker loop until `Shutdown`. Owns its shard exclusively —
/// no locks on the hot path. The denominator stabilizer δ lives inside the
/// backend (it flows from the mechanism config), so every mechanism serves
/// with its own normalization floor.
pub fn run(
    cfg: WorkerConfig,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
) -> anyhow::Result<()> {
    let backend =
        crate::kernels::build_with_window(&cfg.mechanism, cfg.d_head, cfg.horizon, cfg.window)?;
    let mut store = SequenceStore::new(cfg.store.clone());
    store.attach_metrics(metrics.clone());
    // Shared-prefix cache identity (ADR-006): the hash seed folds in the
    // mechanism and geometry, the mechanism tag re-guards every lookup.
    let window = if cfg.window == 0 { cfg.horizon } else { cfg.window };
    let seed = crate::coordinator::prefix::prefix_seed(
        cfg.mechanism.name(),
        cfg.d_head,
        cfg.d_v,
        window,
    );
    let mech_tag = backend.new_state(cfg.d_v).mech_tag();
    // Per-worker scratch arena (ADR-003): reused feature/projection/score
    // buffers make steady-state prefill and decode allocation-free.
    let mut scratch = Scratch::new();

    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => return Ok(()), // senders dropped
        };
        match msg {
            Msg::Shutdown => return Ok(()),
            Msg::Create(id, ack) => {
                let _ = ack.send(create_seq(&mut store, backend.as_ref(), cfg.d_v, seed, id));
            }
            Msg::Release(id, ack) => {
                let _ = ack.send(store.release(id));
            }
            Msg::Len(id, ack) => {
                let _ = ack.send(store.seq_len(id));
            }
            Msg::Snapshot(dir, ack) => {
                let _ = ack.send(store.export_all(&dir));
            }
            Msg::Install(id, path, ack) => {
                let _ = ack.send(install(&mut store, backend.as_ref(), id, &path));
            }
            Msg::Fork(parent, child, ack) => {
                let _ = ack.send(store.fork(parent, child));
            }
            Msg::Work(first) => {
                // Continuous batching (§Perf iteration 1): drain whatever is
                // already queued — up to max_batch — WITHOUT an artificial
                // wait. Under concurrent load items accumulate while the
                // previous batch computes, so large batches form naturally;
                // a lone decode request proceeds immediately instead of
                // eating the max_wait window (was the p50 decode latency
                // floor). While the batch is under-filled and traffic is in
                // flight the worker parks in `recv_timeout` for the
                // window's remaining budget — the old yield-spin burned a
                // core per shard while batches formed (ADR-005).
                let mut batch = vec![first];
                let first_arrival = Instant::now();
                let mut shutdown = false;
                // A snapshot that arrives during batch formation is
                // deferred until after the batch is processed: the work
                // items being gathered were accepted before it, and the
                // snapshot contract is "includes every chunk whose reply
                // preceded it" — so the gather closes early instead.
                let mut deferred_snapshot = None;
                loop {
                    // non-blocking drain first
                    let msg = match rx.try_recv() {
                        Ok(m) => m,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            shutdown = true;
                            break;
                        }
                        Err(mpsc::TryRecvError::Empty) => {
                            // queue empty: only linger while other requests
                            // are in flight and the batch is still small —
                            // and linger *blocked on the channel*, bounded
                            // by what is left of the batch window.
                            let now = Instant::now();
                            let in_flight =
                                inflight.load(Ordering::Relaxed) as usize > batch.len();
                            if !in_flight
                                || cfg.policy.should_close(first_arrival, batch.len(), now)
                            {
                                break;
                            }
                            match rx.recv_timeout(cfg.policy.remaining(first_arrival, now)) {
                                Ok(m) => m,
                                Err(mpsc::RecvTimeoutError::Timeout) => break,
                                Err(mpsc::RecvTimeoutError::Disconnected) => {
                                    shutdown = true;
                                    break;
                                }
                            }
                        }
                    };
                    match msg {
                        Msg::Work(w) => {
                            batch.push(w);
                            if batch.len() >= cfg.policy.max_batch {
                                break;
                            }
                        }
                        Msg::Create(id, ack) => {
                            let _ =
                                ack.send(create_seq(&mut store, backend.as_ref(), cfg.d_v, seed, id));
                        }
                        Msg::Release(id, ack) => {
                            let _ = ack.send(store.release(id));
                        }
                        Msg::Len(id, ack) => {
                            let _ = ack.send(store.seq_len(id));
                        }
                        Msg::Snapshot(dir, ack) => {
                            deferred_snapshot = Some((dir, ack));
                            break;
                        }
                        Msg::Install(id, path, ack) => {
                            let _ = ack.send(install(&mut store, backend.as_ref(), id, &path));
                        }
                        Msg::Fork(parent, child, ack) => {
                            // A fork racing chunks already gathered for the
                            // parent would clone a state the client believes
                            // includes those chunks — reject deterministically,
                            // never hand out a torn clone (ADR-006).
                            if batch.iter().any(|w| w.chunk.seq == parent) {
                                let _ = ack.send(Err(anyhow::anyhow!(
                                    "sequence {parent:?} is mid-flight in a forming batch; \
                                     fork after its replies"
                                )));
                            } else {
                                let _ = ack.send(store.fork(parent, child));
                            }
                        }
                        Msg::Shutdown => {
                            shutdown = true;
                            break;
                        }
                    }
                }
                process_batch(
                    &mut store,
                    backend.as_ref(),
                    &mut scratch,
                    batch,
                    &metrics,
                    &inflight,
                    mech_tag,
                );
                if let Some((dir, ack)) = deferred_snapshot {
                    let _ = ack.send(store.export_all(&dir));
                }
                if shutdown {
                    return Ok(());
                }
            }
        }
    }
}

/// Admit a fresh sequence and seed its rolling prefix-hash cursor — a
/// newborn session's (empty) chunk stream is cacheable by definition.
fn create_seq(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    d_v: usize,
    seed: u64,
    id: SeqId,
) -> anyhow::Result<()> {
    store.create(id, backend.new_state(d_v))?;
    store.set_prefix_cursor(id, Some(seed));
    Ok(())
}

/// Load one serialized state through the backend's validating decoder and
/// admit it under `id` — the restore / shard-migration entry (ADR-004).
/// The cursor stays `None`: an installed state's chunk provenance is
/// unknown, so it must neither hit nor poison the prefix cache.
fn install(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    id: SeqId,
    path: &std::path::Path,
) -> anyhow::Result<()> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("cannot open state file {}: {e}", path.display()))?;
    let state = backend.load_state(&mut std::io::BufReader::new(f))?;
    store.create(id, state)
}

fn process_batch(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    scratch: &mut Scratch,
    mut batch: Vec<WorkItem>,
    metrics: &Metrics,
    inflight: &AtomicU64,
    mech_tag: u64,
) {
    order_batch(&mut batch);
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics
        .batched_items
        .fetch_add(batch.len() as u64, Ordering::Relaxed);

    // ---- fused cross-session decode (ADR-005) ---------------------------
    // `order_batch` puts decode chunks (single token, latency-critical)
    // first, so the decode group is the batch's prefix. Same-sequence
    // decodes must apply in arrival order, so the group executes as a
    // series of WAVES: each wave takes the first pending decode of every
    // distinct sequence and runs them as ONE fused `decode_batch_with`
    // block — cross-sequence order inside a wave is immaterial, the states
    // are disjoint. Under steady multi-session traffic a batch is one wave.
    let n_decode = batch.iter().take_while(|w| w.chunk.is_decode()).count();
    let mut decode_items: Vec<WorkItem> = batch.drain(..n_decode).collect();
    while !decode_items.is_empty() {
        let mut wave: Vec<WorkItem> = Vec::with_capacity(decode_items.len());
        let mut later: Vec<WorkItem> = Vec::new();
        for w in decode_items {
            // a wave holds at most one chunk per sequence (ordering) and is
            // homogeneous in value width (it becomes one stacked block)
            if wave.iter().any(|p| p.chunk.seq == w.chunk.seq)
                || wave.first().is_some_and(|p| p.chunk.v.cols != w.chunk.v.cols)
            {
                later.push(w);
            } else {
                wave.push(w);
            }
        }
        decode_items = later;
        process_decode_wave(store, backend, scratch, wave, metrics, inflight, mech_tag);
    }

    // ---- per-chunk prefill streaming through sequence state -------------
    // Each prefill chunk streams through `prefill_into`: the backend maps
    // features over zero-copy views of the arrival buffers at the
    // session's true position (`state.len()`, so cosformer serving matches
    // its one-shot forward) and draws every intermediate from the worker's
    // scratch arena. The result tensor is the only allocation on this
    // path — it crosses the reply channel, so the caller owns it.
    for w in batch {
        metrics.prefill_chunks.fetch_add(1, Ordering::Relaxed);
        process_item(store, backend, scratch, w, metrics, inflight, mech_tag);
    }
}

/// Stream one work item's chunk through its sequence state — the per-item
/// path: every prefill chunk, plus any decode wave that fell back out of
/// the fused path.
fn process_item(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    scratch: &mut Scratch,
    w: WorkItem,
    metrics: &Metrics,
    inflight: &AtomicU64,
    mech_tag: u64,
) {
    let n = w.chunk.n_tokens();
    let is_decode = w.chunk.is_decode();
    // Rolling prefix hash (ADR-006): the cursor chains over prefill chunks
    // from creation; any decode (or a restore-installed session) sets it
    // to None, so decode traffic skips this path entirely.
    let rolled = if is_decode {
        None
    } else {
        store.prefix_cursor(w.chunk.seq).map(|h| {
            crate::coordinator::prefix::roll_chunk(h, &w.chunk.q, &w.chunk.k, &w.chunk.v)
        })
    };
    if let Some(h) = rolled {
        // fault the session in first: the hit path swaps the memoized
        // post-chunk state into the *resident* entry
        if store.get_mut(w.chunk.seq).is_some() {
            if let Some(y) = store.prefix_lookup(w.chunk.seq, h, mech_tag, n) {
                // cache hit: the chunk's compute is skipped and its cached
                // output replays verbatim
                metrics.prefix_hits.fetch_add(1, Ordering::Relaxed);
                let saved = (w.chunk.q.data.len()
                    + w.chunk.k.data.len()
                    + w.chunk.v.data.len())
                    * std::mem::size_of::<f32>();
                metrics.prefix_bytes_saved.fetch_add(saved as u64, Ordering::Relaxed);
                let result = AttendResult {
                    seq: w.chunk.seq,
                    y,
                    seq_len: store.seq_len(w.chunk.seq).unwrap_or(0),
                    latency: w.enqueued.elapsed(),
                };
                metrics.record_latency(result.latency);
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                metrics.tokens_in.fetch_add(n as u64, Ordering::Relaxed);
                inflight.fetch_sub(1, Ordering::Relaxed);
                let _ = w.reply.send(Ok(result));
                return;
            }
            metrics.prefix_misses.fetch_add(1, Ordering::Relaxed);
        }
    }
    let result = match store.get_mut(w.chunk.seq) {
        None => Err(anyhow::anyhow!("unknown sequence {:?}", w.chunk.seq)),
        Some(state) => {
            let (q, k, v) = (w.chunk.q.view(), w.chunk.k.view(), w.chunk.v.view());
            let mut y = Mat::zeros(v.rows(), v.cols());
            let res = backend.prefill_into(scratch, state, q, k, v, y.view_mut());
            res.map(|()| AttendResult {
                seq: w.chunk.seq,
                y,
                seq_len: state.len(),
                latency: w.enqueued.elapsed(),
            })
        }
    };
    match &result {
        Ok(res) => {
            metrics.record_latency(res.latency);
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            metrics.tokens_in.fetch_add(n as u64, Ordering::Relaxed);
            if is_decode {
                // divergence: the hash chain no longer covers the stream
                store.set_prefix_cursor(w.chunk.seq, None);
            } else if let Some(h) = rolled {
                // memoize the post-chunk boundary and advance the cursor
                store.prefix_insert(w.chunk.seq, h, &res.y);
                store.set_prefix_cursor(w.chunk.seq, Some(h));
            }
        }
        Err(_) => {
            // unknown whether the state advanced — stop the hash chain
            store.set_prefix_cursor(w.chunk.seq, None);
        }
    }
    inflight.fetch_sub(1, Ordering::Relaxed);
    let _ = w.reply.send(result);
}

/// Execute one wave of single-token decode chunks — distinct sequences,
/// each at its own position — as one fused step (ADR-005): stack the
/// wave's q/k/v rows into scratch-backed matrices, borrow every state
/// disjointly ([`SequenceStore::get_many_mut`]), run ONE
/// [`AttentionBackend::decode_batch_with`] call, and fan the per-item
/// replies back out. Unknown sequences fail alone before the fused call;
/// if the fused preconditions don't hold (a width-mismatched wave, a store
/// too small to co-resident the whole wave), the wave falls back to the
/// exact per-item path — `decode_batch_with` validates before mutating, so
/// no token is ever absorbed twice.
fn process_decode_wave(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    scratch: &mut Scratch,
    wave: Vec<WorkItem>,
    metrics: &Metrics,
    inflight: &AtomicU64,
    mech_tag: u64,
) {
    metrics
        .decode_chunks
        .fetch_add(wave.len() as u64, Ordering::Relaxed);
    // Per-item admission: an unknown sequence fails alone, not its wave.
    let mut items: Vec<WorkItem> = Vec::with_capacity(wave.len());
    for w in wave {
        if store.contains(w.chunk.seq) {
            items.push(w);
        } else {
            inflight.fetch_sub(1, Ordering::Relaxed);
            let _ = w
                .reply
                .send(Err(anyhow::anyhow!("unknown sequence {:?}", w.chunk.seq)));
        }
    }
    if items.is_empty() {
        return;
    }
    let b = items.len();
    let d_k = items[0].chunk.q.cols;
    let d_v = items[0].chunk.v.cols;
    // Stack the wave's rows into scratch-backed matrices — B×d copies are
    // noise next to the one feature GEMM they enable.
    let mut q_buf = scratch.take(b * d_k);
    let mut k_buf = scratch.take(b * d_k);
    let mut v_buf = scratch.take(b * d_v);
    let mut y_buf = scratch.take(b * d_v);
    for (i, w) in items.iter().enumerate() {
        q_buf[i * d_k..(i + 1) * d_k].copy_from_slice(w.chunk.q.row(0));
        k_buf[i * d_k..(i + 1) * d_k].copy_from_slice(w.chunk.k.row(0));
        v_buf[i * d_v..(i + 1) * d_v].copy_from_slice(w.chunk.v.row(0));
    }
    let ids: Vec<SeqId> = items.iter().map(|w| w.chunk.seq).collect();
    // Pre-call lengths guard the fall-back below: decode_batch_with
    // implementations validate before mutating, but a length that DID
    // advance (a contract violation, e.g. a future backend keeping the
    // partial-on-error provided default) must never be re-run — that would
    // absorb the same token twice.
    let pre_lens: Vec<Option<usize>> = ids.iter().map(|&id| store.seq_len(id)).collect();
    let fused = store.get_many_mut(&ids).and_then(|mut states| {
        backend.decode_batch_with(
            scratch,
            &mut states,
            MatView::new(&q_buf, b, d_k),
            MatView::new(&k_buf, b, d_k),
            MatView::new(&v_buf, b, d_v),
            MatViewMut::new(&mut y_buf, b, d_v),
        )
    });
    match fused {
        Ok(()) => {
            metrics.fused_decode_batches.fetch_add(1, Ordering::Relaxed);
            metrics.fused_decode_rows.fetch_add(b as u64, Ordering::Relaxed);
            metrics.max_fused_batch.fetch_max(b as u64, Ordering::Relaxed);
            for (i, w) in items.into_iter().enumerate() {
                // a decode diverges the stream from its cacheable prefix
                store.set_prefix_cursor(w.chunk.seq, None);
                let y = Mat::from_vec(1, d_v, y_buf[i * d_v..(i + 1) * d_v].to_vec());
                let result = AttendResult {
                    seq: w.chunk.seq,
                    y,
                    seq_len: store.seq_len(w.chunk.seq).unwrap_or(0),
                    latency: w.enqueued.elapsed(),
                };
                metrics.record_latency(result.latency);
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                metrics.tokens_in.fetch_add(1, Ordering::Relaxed);
                inflight.fetch_sub(1, Ordering::Relaxed);
                let _ = w.reply.send(Ok(result));
            }
        }
        Err(e) => {
            crate::log_warn!("fused decode wave of {b} fell back to per-item: {e}");
            let msg = e.to_string();
            for (i, w) in items.into_iter().enumerate() {
                // re-run only sequences the failed fused call verifiably
                // did not advance; an advanced one gets an error instead of
                // a double-absorbed token
                if store.seq_len(w.chunk.seq) == pre_lens[i] {
                    process_item(store, backend, scratch, w, metrics, inflight, mech_tag);
                } else {
                    inflight.fetch_sub(1, Ordering::Relaxed);
                    let _ = w.reply.send(Err(anyhow::anyhow!(
                        "fused decode failed after advancing sequence {:?}: {msg}",
                        w.chunk.seq
                    )));
                }
            }
        }
    }
    scratch.put(y_buf);
    scratch.put(v_buf);
    scratch.put(k_buf);
    scratch.put(q_buf);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{AttendChunk, ReplyTo};
    use crate::math::rng::Rng;
    use std::time::Duration;

    fn worker_cfg() -> WorkerConfig {
        WorkerConfig {
            mechanism: Mechanism::EluLinear,
            d_head: 8,
            d_v: 4,
            horizon: 64,
            window: 0,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) },
            store: StoreConfig::default(),
        }
    }

    fn work(
        seq: SeqId,
        n: usize,
        rng: &mut Rng,
    ) -> (Msg, mpsc::Receiver<anyhow::Result<AttendResult>>) {
        let (tx, rx) = mpsc::channel();
        let item = WorkItem {
            chunk: AttendChunk {
                seq,
                q: Mat::randn(n, 8, rng),
                k: Mat::randn(n, 8, rng),
                v: Mat::randn(n, 4, rng),
            },
            enqueued: Instant::now(),
            reply: ReplyTo::Channel(tx),
        };
        (Msg::Work(item), rx)
    }

    #[test]
    fn fork_of_mid_flight_parent_rejected_deterministically() {
        // The whole schedule is pre-loaded before the worker runs: the
        // fork is already queued behind the parent's chunk when the batch
        // forms, so the gather loop MUST see it while the parent is
        // mid-flight — no timing involved, the rejection is deterministic.
        let (tx, rx) = mpsc::channel();
        let inflight = Arc::new(AtomicU64::new(1));
        let metrics = Arc::new(Metrics::new());
        let mut rng = Rng::new(7);
        let (cack_tx, cack_rx) = mpsc::channel();
        tx.send(Msg::Create(SeqId(1), cack_tx)).unwrap();
        let (wmsg, wrx) = work(SeqId(1), 4, &mut rng);
        tx.send(wmsg).unwrap();
        let (fack_tx, fack_rx) = mpsc::channel();
        tx.send(Msg::Fork(SeqId(1), SeqId(2), fack_tx)).unwrap();
        tx.send(Msg::Shutdown).unwrap();
        run(worker_cfg(), rx, metrics.clone(), inflight).unwrap();
        cack_rx.recv().unwrap().unwrap();
        let err = fack_rx.recv().unwrap().expect_err("mid-flight fork must be rejected");
        assert!(err.to_string().contains("mid-flight"), "{err}");
        wrx.recv().unwrap().unwrap(); // the parent's chunk still completes
        assert_eq!(metrics.forks.load(Ordering::Relaxed), 0, "no torn clone was made");
    }

    #[test]
    fn fork_of_idle_parent_during_gather_succeeds() {
        // Same pre-loaded-schedule construction, but the fork's parent is
        // NOT in the forming batch — the gather loop serves it inline.
        let (tx, rx) = mpsc::channel();
        let inflight = Arc::new(AtomicU64::new(1));
        let metrics = Arc::new(Metrics::new());
        let mut rng = Rng::new(8);
        let (a_tx, a_rx) = mpsc::channel();
        tx.send(Msg::Create(SeqId(1), a_tx)).unwrap();
        let (b_tx, b_rx) = mpsc::channel();
        tx.send(Msg::Create(SeqId(2), b_tx)).unwrap();
        let (wmsg, wrx) = work(SeqId(2), 4, &mut rng);
        tx.send(wmsg).unwrap();
        let (fack_tx, fack_rx) = mpsc::channel();
        tx.send(Msg::Fork(SeqId(1), SeqId(3), fack_tx)).unwrap();
        let (len_tx, len_rx) = mpsc::channel();
        tx.send(Msg::Len(SeqId(3), len_tx)).unwrap();
        tx.send(Msg::Shutdown).unwrap();
        run(worker_cfg(), rx, metrics.clone(), inflight).unwrap();
        a_rx.recv().unwrap().unwrap();
        b_rx.recv().unwrap().unwrap();
        fack_rx.recv().unwrap().expect("fork of a sequence outside the batch succeeds");
        assert_eq!(len_rx.recv().unwrap(), Some(0), "the child exists on the shard");
        wrx.recv().unwrap().unwrap();
        assert_eq!(metrics.forks.load(Ordering::Relaxed), 1);
    }
}
