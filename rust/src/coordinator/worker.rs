//! Shard worker: owns one [`SequenceStore`] shard, an
//! [`AttentionBackend`] and a [`Scratch`] arena, forms dynamic batches
//! from its queue (parking in `recv_timeout` for the window's remainder
//! while under-filled — no busy spin), then executes them in two lanes:
//!
//! * **fused decode** (ADR-005): the batch's decode chunks — different
//!   sequences, n = 1 each, each at its own position — are stacked into
//!   one q/k/v block and advanced by ONE
//!   [`AttentionBackend::decode_batch_with`] call per wave (same-sequence
//!   repeats split into ordered waves), with the states borrowed
//!   disjointly via [`SequenceStore::get_many_mut`]. B matvecs become one
//!   feature GEMM + B cheap state ops, bit-identical per sequence to the
//!   per-item path.
//! * **per-item prefill**: each prefill chunk streams through the
//!   zero-allocation `prefill_into` path: the backend maps features over
//!   zero-copy views of the chunk's arrival buffers at the sequence's
//!   true position (ADR-002) with every intermediate — feature rows,
//!   block scores, projections — recycled from the worker's arena
//!   (ADR-003).
//!
//! In steady state the only per-chunk allocation on these paths is the
//! result tensor handed back over the reply channel. Mechanisms without a
//! feature decomposition (the exact quadratic baselines) are served
//! through the same interface over their rolling KV windows.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{AttendResult, ReplyTo, SeqId, ServeError, WorkItem};
use crate::coordinator::scheduler::{order_batch, BatchPolicy};
use crate::coordinator::state::{SequenceStore, SnapshotRecord, StoreConfig};
use crate::kernels::config::Mechanism;
use crate::kernels::AttentionBackend;
use crate::math::linalg::{Mat, MatView, MatViewMut, Scratch};
use crate::obs::{Class, ObsTicks, Stage};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Control/work messages a worker consumes.
pub enum Msg {
    Work(WorkItem),
    Create(SeqId, mpsc::Sender<anyhow::Result<()>>),
    Release(SeqId, mpsc::Sender<bool>),
    /// Query a sequence's length (diagnostics).
    Len(SeqId, mpsc::Sender<Option<usize>>),
    /// Serialize every sequence this shard owns (resident and spilled)
    /// into the directory (coordinator snapshot, ADR-004); replies with
    /// one [`SnapshotRecord`] per sequence. Queued behind all work the
    /// shard has already accepted, so the snapshot includes exactly the
    /// chunks whose replies preceded it.
    Snapshot(std::path::PathBuf, mpsc::Sender<anyhow::Result<Vec<SnapshotRecord>>>),
    /// Re-admit one serialized sequence under the given id (coordinator
    /// restore / shard migration): the state file is loaded through the
    /// backend's validating decoder, so a snapshot can never be resumed
    /// under the wrong mechanism or geometry.
    Install(SeqId, std::path::PathBuf, mpsc::Sender<anyhow::Result<()>>),
    /// Clone a live (or spilled) sequence under a fresh id on this shard
    /// (ADR-006): `Fork(parent, child, ack)`. Rejected deterministically
    /// when the parent is mid-flight in the forming batch — never a torn
    /// clone.
    Fork(SeqId, SeqId, mpsc::Sender<anyhow::Result<()>>),
    Shutdown,
}

pub struct WorkerConfig {
    /// This worker's shard index — keys its [`crate::obs::ShardStats`]
    /// slot (queue-depth gauge, per-shard items/batches counters).
    pub shard: usize,
    pub mechanism: Mechanism,
    pub d_head: usize,
    pub d_v: usize,
    pub horizon: usize,
    /// Rolling KV-window bound for quadratic sessions (0 = fall back to
    /// `horizon`); see [`crate::kernels::build_with_window`].
    pub window: usize,
    pub policy: BatchPolicy,
    pub store: StoreConfig,
}

/// Run the worker loop until `Shutdown`. Owns its shard exclusively —
/// no locks on the hot path. The denominator stabilizer δ lives inside the
/// backend (it flows from the mechanism config), so every mechanism serves
/// with its own normalization floor.
pub fn run(
    cfg: WorkerConfig,
    rx: mpsc::Receiver<Msg>,
    metrics: Arc<Metrics>,
    inflight: Arc<AtomicU64>,
) -> anyhow::Result<()> {
    let backend =
        crate::kernels::build_with_window(&cfg.mechanism, cfg.d_head, cfg.horizon, cfg.window)?;
    let mut store = SequenceStore::new(cfg.store.clone());
    store.attach_metrics(metrics.clone());
    // Respawn path (ADR-008): a shard replacing a dead worker re-adopts
    // every session its predecessor had paged out — those files were not
    // being mutated when the thread died, so they are exactly as good as
    // any other spill.
    if cfg.store.adopt_spills {
        if let Some(dir) = cfg.store.spill_dir.clone() {
            adopt_spill_files(&mut store, backend.as_ref(), &dir);
        }
    }
    // Shared-prefix cache identity (ADR-006): the hash seed folds in the
    // mechanism and geometry, the mechanism tag re-guards every lookup.
    let window = if cfg.window == 0 { cfg.horizon } else { cfg.window };
    let seed = crate::coordinator::prefix::prefix_seed(
        cfg.mechanism.name(),
        cfg.d_head,
        cfg.d_v,
        window,
    );
    let mech_tag = backend.new_state(cfg.d_v).mech_tag();
    // Per-worker scratch arena (ADR-003): reused feature/projection/score
    // buffers make steady-state prefill and decode allocation-free.
    let mut scratch = Scratch::new();

    loop {
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => return Ok(()), // senders dropped
        };
        match msg {
            Msg::Shutdown => return Ok(()),
            Msg::Create(id, ack) => {
                send_ack(&metrics, &ack, create_seq(&mut store, backend.as_ref(), cfg.d_v, seed, id));
            }
            Msg::Release(id, ack) => {
                send_ack(&metrics, &ack, store.release(id));
            }
            Msg::Len(id, ack) => {
                send_ack(&metrics, &ack, store.seq_len(id));
            }
            Msg::Snapshot(dir, ack) => {
                send_ack(&metrics, &ack, store.export_all(&dir));
            }
            Msg::Install(id, path, ack) => {
                send_ack(&metrics, &ack, install(&mut store, backend.as_ref(), id, &path));
            }
            Msg::Fork(parent, child, ack) => {
                send_ack(&metrics, &ack, store.fork(parent, child));
            }
            Msg::Work(first) => {
                // Fault site `worker_loop` (ADR-008): a fired draw kills
                // the whole thread (deliberately OUTSIDE the per-item
                // guards) — what the coordinator's liveness check and
                // shard respawn exist to absorb.
                crate::util::fault::maybe_panic("worker_loop");
                note_dequeue(&metrics, cfg.shard);
                // Continuous batching (§Perf iteration 1): drain whatever is
                // already queued — up to max_batch — WITHOUT an artificial
                // wait. Under concurrent load items accumulate while the
                // previous batch computes, so large batches form naturally;
                // a lone decode request proceeds immediately instead of
                // eating the max_wait window (was the p50 decode latency
                // floor). While the batch is under-filled and traffic is in
                // flight the worker parks in `recv_timeout` for the
                // window's remaining budget — the old yield-spin burned a
                // core per shard while batches formed (ADR-005).
                let mut batch = vec![first];
                let first_arrival = Instant::now();
                let mut shutdown = false;
                // A snapshot that arrives during batch formation is
                // deferred until after the batch is processed: the work
                // items being gathered were accepted before it, and the
                // snapshot contract is "includes every chunk whose reply
                // preceded it" — so the gather closes early instead.
                let mut deferred_snapshot = None;
                loop {
                    // non-blocking drain first
                    let msg = match rx.try_recv() {
                        Ok(m) => m,
                        Err(mpsc::TryRecvError::Disconnected) => {
                            shutdown = true;
                            break;
                        }
                        Err(mpsc::TryRecvError::Empty) => {
                            // queue empty: only linger while other requests
                            // are in flight and the batch is still small —
                            // and linger *blocked on the channel*, bounded
                            // by what is left of the batch window.
                            let now = Instant::now();
                            let in_flight =
                                inflight.load(Ordering::Relaxed) as usize > batch.len();
                            if !in_flight
                                || cfg.policy.should_close(first_arrival, batch.len(), now)
                            {
                                break;
                            }
                            match rx.recv_timeout(cfg.policy.remaining(first_arrival, now)) {
                                Ok(m) => m,
                                Err(mpsc::RecvTimeoutError::Timeout) => break,
                                Err(mpsc::RecvTimeoutError::Disconnected) => {
                                    shutdown = true;
                                    break;
                                }
                            }
                        }
                    };
                    match msg {
                        Msg::Work(w) => {
                            note_dequeue(&metrics, cfg.shard);
                            batch.push(w);
                            if batch.len() >= cfg.policy.max_batch {
                                break;
                            }
                        }
                        Msg::Create(id, ack) => {
                            send_ack(
                                &metrics,
                                &ack,
                                create_seq(&mut store, backend.as_ref(), cfg.d_v, seed, id),
                            );
                        }
                        Msg::Release(id, ack) => {
                            send_ack(&metrics, &ack, store.release(id));
                        }
                        Msg::Len(id, ack) => {
                            send_ack(&metrics, &ack, store.seq_len(id));
                        }
                        Msg::Snapshot(dir, ack) => {
                            deferred_snapshot = Some((dir, ack));
                            break;
                        }
                        Msg::Install(id, path, ack) => {
                            send_ack(&metrics, &ack, install(&mut store, backend.as_ref(), id, &path));
                        }
                        Msg::Fork(parent, child, ack) => {
                            // A fork racing chunks already gathered for the
                            // parent would clone a state the client believes
                            // includes those chunks — reject deterministically,
                            // never hand out a torn clone (ADR-006).
                            if batch.iter().any(|w| w.chunk.seq == parent) {
                                send_ack(
                                    &metrics,
                                    &ack,
                                    Err(anyhow::anyhow!(
                                        "sequence {parent:?} is mid-flight in a forming batch; \
                                         fork after its replies"
                                    )),
                                );
                            } else {
                                send_ack(&metrics, &ack, store.fork(parent, child));
                            }
                        }
                        Msg::Shutdown => {
                            shutdown = true;
                            break;
                        }
                    }
                }
                // Tick 2: the batch is formed — everything gathered above
                // was queue wait, everything until compute starts is
                // batch-form overhead (ordering, wave splitting, stacking).
                let batch_formed = Instant::now();
                process_batch(
                    &mut store,
                    backend.as_ref(),
                    &mut scratch,
                    batch,
                    batch_formed,
                    &metrics,
                    &inflight,
                    mech_tag,
                );
                if let Some(ss) = metrics.obs.shard(cfg.shard) {
                    ss.batches.fetch_add(1, Ordering::Relaxed);
                    ss.resident_seqs.store(store.len() as u64, Ordering::Relaxed);
                    ss.resident_bytes.store(store.bytes() as u64, Ordering::Relaxed);
                    ss.spilled_seqs.store(store.spilled_len() as u64, Ordering::Relaxed);
                }
                if let Some((dir, ack)) = deferred_snapshot {
                    send_ack(&metrics, &ack, store.export_all(&dir));
                }
                if shutdown {
                    return Ok(());
                }
            }
        }
    }
}

/// Admit a fresh sequence and seed its rolling prefix-hash cursor — a
/// newborn session's (empty) chunk stream is cacheable by definition.
fn create_seq(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    d_v: usize,
    seed: u64,
    id: SeqId,
) -> anyhow::Result<()> {
    store.create(id, backend.new_state(d_v))?;
    store.set_prefix_cursor(id, Some(seed));
    Ok(())
}

/// Load one serialized state through the backend's validating decoder and
/// admit it under `id` — the restore / shard-migration entry (ADR-004).
/// The cursor stays `None`: an installed state's chunk provenance is
/// unknown, so it must neither hit nor poison the prefix cache.
fn install(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    id: SeqId,
    path: &std::path::Path,
) -> anyhow::Result<()> {
    let f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("cannot open state file {}: {e}", path.display()))?;
    let state = backend.load_state(&mut std::io::BufReader::new(f))?;
    store.create(id, state)
}

/// Respawn adoption (ADR-008): scan the shard's spill directory for a dead
/// predecessor's `seq_<id>.state` files and re-admit each one *paged-out*
/// ([`SequenceStore::adopt_spilled`]) after validating it through the
/// backend's decoder. Unreadable files are removed — losing one equals an
/// eviction, which is the spill tier's durability contract anyway.
fn adopt_spill_files(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    dir: &std::path::Path,
) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut adopted = 0usize;
    for entry in entries.flatten() {
        let path = entry.path();
        let name = entry.file_name();
        let name = name.to_string_lossy();
        let Some(id) = name
            .strip_prefix("seq_")
            .and_then(|s| s.strip_suffix(".state"))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        let decoded = std::fs::File::open(&path)
            .map_err(anyhow::Error::from)
            .and_then(|f| backend.load_state(&mut std::io::BufReader::new(f)));
        match decoded {
            Ok(st) => {
                if store
                    .adopt_spilled(SeqId(id), path, st.capacity_bytes(), st.len())
                    .is_ok()
                {
                    adopted += 1;
                }
            }
            Err(e) => {
                crate::log_warn!("dropping unreadable spill file {}: {e}", path.display());
                let _ = std::fs::remove_file(&path);
            }
        }
    }
    if adopted > 0 {
        crate::log_info!("respawned shard adopted {adopted} spilled session(s)");
    }
}

/// One work item left the shard queue: settle the queue-depth gauge
/// (incremented by `submit_with` before `try_send`) and count it against
/// this shard. A no-op when shard stats were never initialized (direct
/// `run()` callers in tests).
fn note_dequeue(metrics: &Metrics, shard: usize) {
    if let Some(ss) = metrics.obs.shard(shard) {
        ss.queue_depth.fetch_sub(1, Ordering::Relaxed);
        ss.items.fetch_add(1, Ordering::Relaxed);
    }
}

#[allow(clippy::too_many_arguments)]
fn process_batch(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    scratch: &mut Scratch,
    mut batch: Vec<WorkItem>,
    batch_formed: Instant,
    metrics: &Metrics,
    inflight: &AtomicU64,
    mech_tag: u64,
) {
    order_batch(&mut batch);
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics
        .batched_items
        .fetch_add(batch.len() as u64, Ordering::Relaxed);

    // ---- fused cross-session decode (ADR-005) ---------------------------
    // `order_batch` puts decode chunks (single token, latency-critical)
    // first, so the decode group is the batch's prefix. Same-sequence
    // decodes must apply in arrival order, so the group executes as a
    // series of WAVES: each wave takes the first pending decode of every
    // distinct sequence and runs them as ONE fused `decode_batch_with`
    // block — cross-sequence order inside a wave is immaterial, the states
    // are disjoint. Under steady multi-session traffic a batch is one wave.
    let n_decode = batch.iter().take_while(|w| w.chunk.is_decode()).count();
    let mut decode_items: Vec<WorkItem> = batch.drain(..n_decode).collect();
    while !decode_items.is_empty() {
        let mut wave: Vec<WorkItem> = Vec::with_capacity(decode_items.len());
        let mut later: Vec<WorkItem> = Vec::new();
        for w in decode_items {
            // a wave holds at most one chunk per sequence (ordering) and is
            // homogeneous in value width (it becomes one stacked block)
            if wave.iter().any(|p| p.chunk.seq == w.chunk.seq)
                || wave.first().is_some_and(|p| p.chunk.v.cols != w.chunk.v.cols)
            {
                later.push(w);
            } else {
                wave.push(w);
            }
        }
        decode_items = later;
        process_decode_wave(
            store,
            backend,
            scratch,
            wave,
            batch_formed,
            metrics,
            inflight,
            mech_tag,
        );
    }

    // ---- per-chunk prefill streaming through sequence state -------------
    // Each prefill chunk streams through `prefill_into`: the backend maps
    // features over zero-copy views of the arrival buffers at the
    // session's true position (`state.len()`, so cosformer serving matches
    // its one-shot forward) and draws every intermediate from the worker's
    // scratch arena. The result tensor is the only allocation on this
    // path — it crosses the reply channel, so the caller owns it.
    for w in batch {
        metrics.prefill_chunks.fetch_add(1, Ordering::Relaxed);
        process_item_guarded(store, backend, scratch, w, batch_formed, metrics, inflight, mech_tag);
    }
}

/// Stream one work item's chunk through its sequence state — the per-item
/// path: every prefill chunk, plus any decode wave that fell back out of
/// the fused path.
#[allow(clippy::too_many_arguments)]
fn process_item(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    scratch: &mut Scratch,
    w: WorkItem,
    batch_formed: Instant,
    metrics: &Metrics,
    inflight: &AtomicU64,
    mech_tag: u64,
) {
    let n = w.chunk.n_tokens();
    let is_decode = w.chunk.is_decode();
    // Deadline gate (ADR-008): an item already past `--request-timeout-ms`
    // gets its deterministic timeout instead of compute nobody waits for.
    if w.expired(Instant::now()) {
        metrics.request_timeouts.fetch_add(1, Ordering::Relaxed);
        inflight.fetch_sub(1, Ordering::Relaxed);
        send_reply(metrics, &w.reply, Err(ServeError::Timeout.into()));
        return;
    }
    // Fault sites `decode`/`prefill` (ADR-008): `panic` exercises the
    // per-item poison path; io/corrupt degrade to a per-item error reply —
    // the state was not touched yet, only the hash chain is stopped
    // (conservatively, as for any errored chunk).
    match crate::util::fault::fire(if is_decode { "decode" } else { "prefill" }) {
        Some(crate::util::fault::FaultKind::Panic) => {
            panic!("injected fault at site '{}'", if is_decode { "decode" } else { "prefill" })
        }
        Some(_) => {
            store.set_prefix_cursor(w.chunk.seq, None);
            inflight.fetch_sub(1, Ordering::Relaxed);
            send_reply(
                metrics,
                &w.reply,
                Err(anyhow::anyhow!("injected compute fault on {:?}", w.chunk.seq)),
            );
            return;
        }
        None => {}
    }
    // Rolling prefix hash (ADR-006): the cursor chains over prefill chunks
    // from creation; any decode (or a restore-installed session) sets it
    // to None, so decode traffic skips this path entirely.
    let rolled = if is_decode {
        None
    } else {
        store.prefix_cursor(w.chunk.seq).map(|h| {
            crate::coordinator::prefix::roll_chunk(h, &w.chunk.q, &w.chunk.k, &w.chunk.v)
        })
    };
    if let Some(h) = rolled {
        // fault the session in first: the hit path swaps the memoized
        // post-chunk state into the *resident* entry
        if store.get_mut(w.chunk.seq).is_some() {
            if let Some(y) = store.prefix_lookup(w.chunk.seq, h, mech_tag, n) {
                // cache hit: the chunk's compute is skipped and its cached
                // output replays verbatim
                metrics.prefix_hits.fetch_add(1, Ordering::Relaxed);
                let saved = (w.chunk.q.data.len()
                    + w.chunk.k.data.len()
                    + w.chunk.v.data.len())
                    * std::mem::size_of::<f32>();
                metrics.prefix_bytes_saved.fetch_add(saved as u64, Ordering::Relaxed);
                // Ticks 3/4 collapse: a cache hit IS the compute, so the
                // compute stage records zero and the batch stage absorbs
                // the lookup cost. Hits only exist on the prefill chain.
                let t_done = Instant::now();
                metrics.obs.record_stage(
                    Class::Prefill,
                    Stage::Queue,
                    batch_formed.saturating_duration_since(w.enqueued),
                );
                metrics.obs.record_stage(
                    Class::Prefill,
                    Stage::Batch,
                    t_done.saturating_duration_since(batch_formed),
                );
                metrics.obs.record_stage(Class::Prefill, Stage::Compute, Duration::ZERO);
                let result = AttendResult {
                    seq: w.chunk.seq,
                    y,
                    seq_len: store.seq_len(w.chunk.seq).unwrap_or(0),
                    latency: w.enqueued.elapsed(),
                    trace: Some(ObsTicks {
                        class: Class::Prefill,
                        submit: w.submitted,
                        compute_end: t_done,
                    }),
                };
                metrics.record_latency(result.latency);
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                metrics.tokens_in.fetch_add(n as u64, Ordering::Relaxed);
                inflight.fetch_sub(1, Ordering::Relaxed);
                send_reply(metrics, &w.reply, Ok(result));
                return;
            }
            metrics.prefix_misses.fetch_add(1, Ordering::Relaxed);
        }
    }
    let class = if is_decode { Class::Decode } else { Class::Prefill };
    let t_compute = Instant::now(); // tick 3
    let mut result = match store.get_mut(w.chunk.seq) {
        None => Err(anyhow::anyhow!("unknown sequence {:?}", w.chunk.seq)),
        Some(state) => {
            let (q, k, v) = (w.chunk.q.view(), w.chunk.k.view(), w.chunk.v.view());
            let mut y = Mat::zeros(v.rows(), v.cols());
            let res = backend.prefill_into(scratch, state, q, k, v, y.view_mut());
            res.map(|()| AttendResult {
                seq: w.chunk.seq,
                y,
                seq_len: state.len(),
                latency: w.enqueued.elapsed(),
                trace: None,
            })
        }
    };
    let t_done = Instant::now(); // tick 4
    match &mut result {
        Ok(res) => {
            metrics.obs.record_stage(
                class,
                Stage::Queue,
                batch_formed.saturating_duration_since(w.enqueued),
            );
            metrics.obs.record_stage(
                class,
                Stage::Batch,
                t_compute.saturating_duration_since(batch_formed),
            );
            metrics.obs.record_stage(
                class,
                Stage::Compute,
                t_done.saturating_duration_since(t_compute),
            );
            res.trace =
                Some(ObsTicks { class, submit: w.submitted, compute_end: t_done });
            metrics.record_latency(res.latency);
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            metrics.tokens_in.fetch_add(n as u64, Ordering::Relaxed);
            if is_decode {
                // divergence: the hash chain no longer covers the stream
                store.set_prefix_cursor(w.chunk.seq, None);
            } else if let Some(h) = rolled {
                // memoize the post-chunk boundary and advance the cursor
                store.prefix_insert(w.chunk.seq, h, &res.y);
                store.set_prefix_cursor(w.chunk.seq, Some(h));
            }
        }
        Err(_) => {
            // unknown whether the state advanced — stop the hash chain
            store.set_prefix_cursor(w.chunk.seq, None);
        }
    }
    inflight.fetch_sub(1, Ordering::Relaxed);
    send_reply(metrics, &w.reply, result);
}

/// Deliver a result, counting a vanished consumer (`dropped_replies`)
/// instead of silently discarding it (ADR-008).
fn send_reply(metrics: &Metrics, reply: &ReplyTo, r: anyhow::Result<AttendResult>) {
    if reply.send(r).is_err() {
        metrics.dropped_replies.fetch_add(1, Ordering::Relaxed);
    }
}

/// Control-plane twin of [`send_reply`]: an ack whose coordinator-side
/// receiver vanished is counted, never unwrapped or silently dropped.
fn send_ack<T>(metrics: &Metrics, ack: &mpsc::Sender<T>, v: T) {
    if ack.send(v).is_err() {
        metrics.dropped_replies.fetch_add(1, Ordering::Relaxed);
    }
}

/// [`process_item`] under panic isolation (ADR-008): a panic poisons only
/// this item — its session is released if resident (a torn mutation can
/// only live in the resident state; a spilled file was untouched and stays
/// valid), the client gets a structured error, and the shard keeps
/// serving.
#[allow(clippy::too_many_arguments)]
fn process_item_guarded(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    scratch: &mut Scratch,
    w: WorkItem,
    batch_formed: Instant,
    metrics: &Metrics,
    inflight: &AtomicU64,
    mech_tag: u64,
) {
    let seq = w.chunk.seq;
    let reply = w.reply.clone();
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        process_item(store, backend, scratch, w, batch_formed, metrics, inflight, mech_tag);
    }));
    if outcome.is_err() {
        metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
        if store.release_resident(seq) {
            metrics.session_poisoned(format!(
                "sequence {seq:?} released after a per-item panic"
            ));
        }
        // Every panic source inside process_item sits before the item's
        // own inflight decrement (the injected sites fire first; compute
        // panics precede the post-compute accounting), so settling here is
        // never a double count.
        inflight.fetch_sub(1, Ordering::Relaxed);
        send_reply(
            metrics,
            &reply,
            Err(anyhow::anyhow!(
                "internal error serving sequence {seq:?} (request poisoned; session released)"
            )),
        );
    }
}

/// Execute one wave of single-token decode chunks — distinct sequences,
/// each at its own position — as one fused step (ADR-005): stack the
/// wave's q/k/v rows into scratch-backed matrices, borrow every state
/// disjointly ([`SequenceStore::get_many_mut`]), run ONE
/// [`AttentionBackend::decode_batch_with`] call, and fan the per-item
/// replies back out. Unknown sequences fail alone before the fused call;
/// if the fused preconditions don't hold (a width-mismatched wave, a store
/// too small to co-resident the whole wave), the wave falls back to the
/// exact per-item path — `decode_batch_with` validates before mutating, so
/// no token is ever absorbed twice.
#[allow(clippy::too_many_arguments)]
fn process_decode_wave(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    scratch: &mut Scratch,
    wave: Vec<WorkItem>,
    batch_formed: Instant,
    metrics: &Metrics,
    inflight: &AtomicU64,
    mech_tag: u64,
) {
    metrics
        .decode_chunks
        .fetch_add(wave.len() as u64, Ordering::Relaxed);
    // Per-item admission: an expired or unknown sequence fails alone, not
    // its wave.
    let now = Instant::now();
    let mut items: Vec<WorkItem> = Vec::with_capacity(wave.len());
    for w in wave {
        if w.expired(now) {
            metrics.request_timeouts.fetch_add(1, Ordering::Relaxed);
            inflight.fetch_sub(1, Ordering::Relaxed);
            send_reply(metrics, &w.reply, Err(ServeError::Timeout.into()));
        } else if store.contains(w.chunk.seq) {
            items.push(w);
        } else {
            inflight.fetch_sub(1, Ordering::Relaxed);
            send_reply(
                metrics,
                &w.reply,
                Err(anyhow::anyhow!("unknown sequence {:?}", w.chunk.seq)),
            );
        }
    }
    if items.is_empty() {
        return;
    }
    // Panic isolation for the fused path (ADR-008). One backend call
    // mutates every member state, so a panic mid-wave may have torn ANY
    // member: the roster — captured before the guarded region — is what
    // gets poisoned. `settled` counts items whose reply + inflight
    // accounting already happened inside the guard, so recovery settles
    // exactly the remainder, exactly once.
    let roster: Vec<(SeqId, ReplyTo)> =
        items.iter().map(|w| (w.chunk.seq, w.reply.clone())).collect();
    let settled = std::cell::Cell::new(0usize);
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        fused_wave_body(
            store,
            backend,
            scratch,
            items,
            batch_formed,
            metrics,
            inflight,
            mech_tag,
            &settled,
        );
    }));
    if outcome.is_err() {
        metrics.worker_panics.fetch_add(1, Ordering::Relaxed);
        let done = settled.get();
        crate::log_error!(
            "decode wave of {} panicked after {done} settled item(s); poisoning wave members",
            roster.len()
        );
        for (i, (seq, reply)) in roster.into_iter().enumerate() {
            if store.release_resident(seq) {
                metrics.session_poisoned(format!(
                    "sequence {seq:?} released after a fused decode-wave panic"
                ));
            }
            if i >= done {
                inflight.fetch_sub(1, Ordering::Relaxed);
                send_reply(
                    metrics,
                    &reply,
                    Err(anyhow::anyhow!(
                        "internal error serving sequence {seq:?} (decode wave poisoned; \
                         session released)"
                    )),
                );
            }
        }
    }
}

/// The fused wave's compute + fan-out, run under the poison guard above.
#[allow(clippy::too_many_arguments)]
fn fused_wave_body(
    store: &mut SequenceStore,
    backend: &dyn AttentionBackend,
    scratch: &mut Scratch,
    items: Vec<WorkItem>,
    batch_formed: Instant,
    metrics: &Metrics,
    inflight: &AtomicU64,
    mech_tag: u64,
    settled: &std::cell::Cell<usize>,
) {
    // Fault site `decode`, fused flavor (ADR-008): the wave has no
    // per-item error lane of its own, so every kind is a panic here — the
    // point is to exercise the poison/recovery machinery in the caller.
    if crate::util::fault::fire("decode").is_some() {
        panic!("injected fault at site 'decode' (fused wave)");
    }
    let b = items.len();
    let d_k = items[0].chunk.q.cols;
    let d_v = items[0].chunk.v.cols;
    // Stack the wave's rows into scratch-backed matrices — B×d copies are
    // noise next to the one feature GEMM they enable.
    let mut q_buf = scratch.take(b * d_k);
    let mut k_buf = scratch.take(b * d_k);
    let mut v_buf = scratch.take(b * d_v);
    let mut y_buf = scratch.take(b * d_v);
    for (i, w) in items.iter().enumerate() {
        q_buf[i * d_k..(i + 1) * d_k].copy_from_slice(w.chunk.q.row(0));
        k_buf[i * d_k..(i + 1) * d_k].copy_from_slice(w.chunk.k.row(0));
        v_buf[i * d_v..(i + 1) * d_v].copy_from_slice(w.chunk.v.row(0));
    }
    let ids: Vec<SeqId> = items.iter().map(|w| w.chunk.seq).collect();
    // Pre-call lengths guard the fall-back below: decode_batch_with
    // implementations validate before mutating, but a length that DID
    // advance (a contract violation, e.g. a future backend keeping the
    // partial-on-error provided default) must never be re-run — that would
    // absorb the same token twice.
    let pre_lens: Vec<Option<usize>> = ids.iter().map(|&id| store.seq_len(id)).collect();
    let t_compute = Instant::now(); // tick 3 (shared by the whole wave)
    let fused = store.get_many_mut(&ids).and_then(|mut states| {
        backend.decode_batch_with(
            scratch,
            &mut states,
            MatView::new(&q_buf, b, d_k),
            MatView::new(&k_buf, b, d_k),
            MatView::new(&v_buf, b, d_v),
            MatViewMut::new(&mut y_buf, b, d_v),
        )
    });
    let t_done = Instant::now(); // tick 4 (ditto)
    match fused {
        Ok(()) => {
            metrics.fused_decode_batches.fetch_add(1, Ordering::Relaxed);
            metrics.fused_decode_rows.fetch_add(b as u64, Ordering::Relaxed);
            metrics.max_fused_batch.fetch_max(b as u64, Ordering::Relaxed);
            for (i, w) in items.into_iter().enumerate() {
                // a decode diverges the stream from its cacheable prefix
                store.set_prefix_cursor(w.chunk.seq, None);
                let y = Mat::from_vec(1, d_v, y_buf[i * d_v..(i + 1) * d_v].to_vec());
                // The wave's members share one fused backend call, so they
                // share ticks 3/4 — each still gets its own queue wait.
                metrics.obs.record_stage(
                    Class::FusedWave,
                    Stage::Queue,
                    batch_formed.saturating_duration_since(w.enqueued),
                );
                metrics.obs.record_stage(
                    Class::FusedWave,
                    Stage::Batch,
                    t_compute.saturating_duration_since(batch_formed),
                );
                metrics.obs.record_stage(
                    Class::FusedWave,
                    Stage::Compute,
                    t_done.saturating_duration_since(t_compute),
                );
                let result = AttendResult {
                    seq: w.chunk.seq,
                    y,
                    seq_len: store.seq_len(w.chunk.seq).unwrap_or(0),
                    latency: w.enqueued.elapsed(),
                    trace: Some(ObsTicks {
                        class: Class::FusedWave,
                        submit: w.submitted,
                        compute_end: t_done,
                    }),
                };
                metrics.record_latency(result.latency);
                metrics.completed.fetch_add(1, Ordering::Relaxed);
                metrics.tokens_in.fetch_add(1, Ordering::Relaxed);
                inflight.fetch_sub(1, Ordering::Relaxed);
                send_reply(metrics, &w.reply, Ok(result));
                settled.set(settled.get() + 1);
            }
        }
        Err(e) => {
            crate::log_warn!("fused decode wave of {b} fell back to per-item: {e}");
            let msg = e.to_string();
            for (i, w) in items.into_iter().enumerate() {
                // re-run only sequences the failed fused call verifiably
                // did not advance; an advanced one gets an error instead of
                // a double-absorbed token. The guarded per-item path keeps
                // one item's panic from poisoning the rest of the wave.
                if store.seq_len(w.chunk.seq) == pre_lens[i] {
                    process_item_guarded(
                        store, backend, scratch, w, batch_formed, metrics, inflight, mech_tag,
                    );
                } else {
                    inflight.fetch_sub(1, Ordering::Relaxed);
                    send_reply(
                        metrics,
                        &w.reply,
                        Err(anyhow::anyhow!(
                            "fused decode failed after advancing sequence {:?}: {msg}",
                            w.chunk.seq
                        )),
                    );
                }
                settled.set(settled.get() + 1);
            }
        }
    }
    scratch.put(y_buf);
    scratch.put(v_buf);
    scratch.put(k_buf);
    scratch.put(q_buf);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{AttendChunk, ReplyTo};
    use crate::math::rng::Rng;
    use std::time::Duration;

    fn worker_cfg() -> WorkerConfig {
        WorkerConfig {
            shard: 0,
            mechanism: Mechanism::EluLinear,
            d_head: 8,
            d_v: 4,
            horizon: 64,
            window: 0,
            policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(50) },
            store: StoreConfig::default(),
        }
    }

    fn chunk(seq: SeqId, n: usize, rng: &mut Rng) -> AttendChunk {
        AttendChunk {
            seq,
            q: Mat::randn(n, 8, rng),
            k: Mat::randn(n, 8, rng),
            v: Mat::randn(n, 4, rng),
        }
    }

    fn work_item(c: AttendChunk) -> (Msg, mpsc::Receiver<anyhow::Result<AttendResult>>) {
        let (tx, rx) = mpsc::channel();
        let item = WorkItem {
            chunk: c,
            submitted: Instant::now(),
            enqueued: Instant::now(),
            deadline: None,
            reply: ReplyTo::Channel(tx),
        };
        (Msg::Work(item), rx)
    }

    fn work(
        seq: SeqId,
        n: usize,
        rng: &mut Rng,
    ) -> (Msg, mpsc::Receiver<anyhow::Result<AttendResult>>) {
        work_item(chunk(seq, n, rng))
    }

    #[test]
    fn fork_of_mid_flight_parent_rejected_deterministically() {
        // The whole schedule is pre-loaded before the worker runs: the
        // fork is already queued behind the parent's chunk when the batch
        // forms, so the gather loop MUST see it while the parent is
        // mid-flight — no timing involved, the rejection is deterministic.
        let (tx, rx) = mpsc::channel();
        let inflight = Arc::new(AtomicU64::new(1));
        let metrics = Arc::new(Metrics::new());
        let mut rng = Rng::new(7);
        let (cack_tx, cack_rx) = mpsc::channel();
        tx.send(Msg::Create(SeqId(1), cack_tx)).unwrap();
        let (wmsg, wrx) = work(SeqId(1), 4, &mut rng);
        tx.send(wmsg).unwrap();
        let (fack_tx, fack_rx) = mpsc::channel();
        tx.send(Msg::Fork(SeqId(1), SeqId(2), fack_tx)).unwrap();
        tx.send(Msg::Shutdown).unwrap();
        run(worker_cfg(), rx, metrics.clone(), inflight).unwrap();
        cack_rx.recv().unwrap().unwrap();
        let err = fack_rx.recv().unwrap().expect_err("mid-flight fork must be rejected");
        assert!(err.to_string().contains("mid-flight"), "{err}");
        wrx.recv().unwrap().unwrap(); // the parent's chunk still completes
        assert_eq!(metrics.forks.load(Ordering::Relaxed), 0, "no torn clone was made");
    }

    #[test]
    fn fork_of_idle_parent_during_gather_succeeds() {
        // Same pre-loaded-schedule construction, but the fork's parent is
        // NOT in the forming batch — the gather loop serves it inline.
        let (tx, rx) = mpsc::channel();
        let inflight = Arc::new(AtomicU64::new(1));
        let metrics = Arc::new(Metrics::new());
        let mut rng = Rng::new(8);
        let (a_tx, a_rx) = mpsc::channel();
        tx.send(Msg::Create(SeqId(1), a_tx)).unwrap();
        let (b_tx, b_rx) = mpsc::channel();
        tx.send(Msg::Create(SeqId(2), b_tx)).unwrap();
        let (wmsg, wrx) = work(SeqId(2), 4, &mut rng);
        tx.send(wmsg).unwrap();
        let (fack_tx, fack_rx) = mpsc::channel();
        tx.send(Msg::Fork(SeqId(1), SeqId(3), fack_tx)).unwrap();
        let (len_tx, len_rx) = mpsc::channel();
        tx.send(Msg::Len(SeqId(3), len_tx)).unwrap();
        tx.send(Msg::Shutdown).unwrap();
        run(worker_cfg(), rx, metrics.clone(), inflight).unwrap();
        a_rx.recv().unwrap().unwrap();
        b_rx.recv().unwrap().unwrap();
        fack_rx.recv().unwrap().expect("fork of a sequence outside the batch succeeds");
        assert_eq!(len_rx.recv().unwrap(), Some(0), "the child exists on the shard");
        wrx.recv().unwrap().unwrap();
        assert_eq!(metrics.forks.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn expired_items_get_a_deterministic_timeout_not_compute() {
        // Pre-loaded schedule: one already-expired decode and one live
        // prefill on the same sequence. The expired item must be answered
        // with ServeError::Timeout (never computed), the live one served.
        let (tx, rx) = mpsc::channel();
        let inflight = Arc::new(AtomicU64::new(2));
        let metrics = Arc::new(Metrics::new());
        let mut rng = Rng::new(9);
        let (c_tx, c_rx) = mpsc::channel();
        tx.send(Msg::Create(SeqId(1), c_tx)).unwrap();
        let (d_tx, d_rx) = mpsc::channel();
        tx.send(Msg::Work(WorkItem {
            chunk: chunk(SeqId(1), 1, &mut rng),
            submitted: Instant::now(),
            enqueued: Instant::now(),
            // expired() is `now >= deadline`, so "now" is already too late
            deadline: Some(Instant::now()),
            reply: ReplyTo::Channel(d_tx),
        }))
        .unwrap();
        let (wmsg, wrx) = work(SeqId(1), 4, &mut rng);
        tx.send(wmsg).unwrap();
        tx.send(Msg::Shutdown).unwrap();
        run(worker_cfg(), rx, metrics.clone(), inflight.clone()).unwrap();
        c_rx.recv().unwrap().unwrap();
        let err = d_rx.recv().unwrap().expect_err("expired item must not compute");
        assert!(err.to_string().contains("deadline"), "{err}");
        wrx.recv().unwrap().expect("live item still served");
        assert_eq!(metrics.request_timeouts.load(Ordering::Relaxed), 1);
        assert_eq!(inflight.load(Ordering::Relaxed), 0, "no leaked inflight slots");
    }

    #[test]
    fn panic_mid_wave_poisons_only_the_wave_and_worker_survives() {
        // Satellite 3 (ADR-008): a malformed decode chunk (q narrower than
        // d_head) panics the fused wave's row stacking mid-batch. The
        // whole pre-loaded schedule runs through ONE `run()` call:
        //
        //   create 1..4
        //   d1 (good) d2 (malformed) d3 (good)   <- wave 1: panics
        //   d1 again                              <- wave 2: seq released
        //   p4 (good prefill)                     <- served after the panic
        //
        // Invariants: all three wave members get bounded error replies and
        // are released (poisoned); the repeat on seq 1 sees "unknown
        // sequence" (proving release, not a hang); the prefill on seq 4
        // completes bit-identically to a direct backend call (proving the
        // worker and untouched state survived); inflight drains to zero.
        let (tx, rx) = mpsc::channel();
        let inflight = Arc::new(AtomicU64::new(5));
        let metrics = Arc::new(Metrics::new());
        let mut rng = Rng::new(10);
        let mut acks = Vec::new();
        for id in 1..=4 {
            let (a_tx, a_rx) = mpsc::channel();
            tx.send(Msg::Create(SeqId(id), a_tx)).unwrap();
            acks.push(a_rx);
        }
        let (d1_msg, d1_rx) = work(SeqId(1), 1, &mut rng);
        tx.send(d1_msg).unwrap();
        let bad = AttendChunk {
            seq: SeqId(2),
            q: Mat::randn(1, 4, &mut rng), // 4 != d_head=8: stacking panics
            k: Mat::randn(1, 8, &mut rng),
            v: Mat::randn(1, 4, &mut rng),
        };
        let (d2_msg, d2_rx) = work_item(bad);
        tx.send(d2_msg).unwrap();
        let (d3_msg, d3_rx) = work(SeqId(3), 1, &mut rng);
        tx.send(d3_msg).unwrap();
        let (d1b_msg, d1b_rx) = work(SeqId(1), 1, &mut rng);
        tx.send(d1b_msg).unwrap();
        let p4 = chunk(SeqId(4), 4, &mut rng);
        let (p4_ref_q, p4_ref_k, p4_ref_v) = (p4.q.clone(), p4.k.clone(), p4.v.clone());
        let (p4_msg, p4_rx) = work_item(p4);
        tx.send(p4_msg).unwrap();
        tx.send(Msg::Shutdown).unwrap();
        run(worker_cfg(), rx, metrics.clone(), inflight.clone()).unwrap();
        for a in acks {
            a.recv().unwrap().unwrap();
        }
        for (name, rx) in [("d1", d1_rx), ("d2", d2_rx), ("d3", d3_rx)] {
            let err = rx.recv().unwrap().expect_err("wave member must be poisoned");
            assert!(err.to_string().contains("poisoned"), "{name}: {err}");
        }
        let err = d1b_rx.recv().unwrap().expect_err("poisoned session must be gone");
        assert!(err.to_string().contains("unknown sequence"), "{err}");
        let got = p4_rx.recv().unwrap().expect("prefill after the panic still serves");
        let backend = crate::kernels::build_with_window(&Mechanism::EluLinear, 8, 64, 0).unwrap();
        let mut reference = backend.new_state(4);
        let want = backend
            .prefill(&mut reference, p4_ref_q.view(), p4_ref_k.view(), p4_ref_v.view())
            .unwrap();
        assert_eq!(got.y, want, "untouched session must be bit-identical");
        assert_eq!(metrics.worker_panics.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.sessions_poisoned.load(Ordering::Relaxed), 3);
        assert_eq!(inflight.load(Ordering::Relaxed), 0, "no leaked inflight slots");
    }
}
