//! Request/response types for the attention-serving coordinator.
//!
//! The service model mirrors what linear attention makes possible
//! (§3.2/Fig. 2): each *sequence* owns a constant-size streaming state
//! `(S, z)`; clients stream token chunks and receive attention outputs.
//! Prefill = large chunk, decode = single-token chunk — the scheduler
//! distinguishes them the way vLLM-style servers do.

use crate::math::linalg::Mat;
use std::sync::mpsc;

/// Sequence identifier handed out at `create_sequence`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SeqId(pub u64);

/// One unit of work: attend a chunk of (Q, K, V) rows for a sequence,
/// absorbing the keys/values into its streaming state.
#[derive(Debug)]
pub struct AttendChunk {
    pub seq: SeqId,
    /// Query rows `[n, d_head]`.
    pub q: Mat,
    /// Key rows `[n, d_head]`.
    pub k: Mat,
    /// Value rows `[n, d_v]`.
    pub v: Mat,
}

impl AttendChunk {
    pub fn n_tokens(&self) -> usize {
        self.q.rows
    }

    /// Decode = single token; prefill = many (scheduler priority signal).
    pub fn is_decode(&self) -> bool {
        self.q.rows == 1
    }

    pub fn validate(&self, d_head: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.q.cols == d_head, "q dim {} != d_head {d_head}", self.q.cols);
        anyhow::ensure!(self.k.cols == d_head, "k dim {} != d_head {d_head}", self.k.cols);
        anyhow::ensure!(
            self.q.rows == self.k.rows && self.k.rows == self.v.rows,
            "row mismatch q={} k={} v={}",
            self.q.rows,
            self.k.rows,
            self.v.rows
        );
        anyhow::ensure!(self.q.rows > 0, "empty chunk");
        Ok(())
    }
}

/// Completed work unit.
#[derive(Debug)]
pub struct AttendResult {
    pub seq: SeqId,
    /// Attention outputs `[n, d_v]` for the chunk's query rows.
    pub y: Mat,
    /// Total tokens absorbed by the sequence after this chunk.
    pub seq_len: usize,
    /// Queue + compute latency.
    pub latency: std::time::Duration,
    /// Observability ticks stamped by the worker, consumed by the front
    /// end that flushes the reply (reply_flush / total stages). Purely
    /// in-memory: no wire encoder reads it, so replies stay byte-identical
    /// whether observability is enabled or not.
    pub trace: Option<crate::obs::ObsTicks>,
}

/// Where a finished [`WorkItem`]'s result is delivered.
///
/// `Channel` is the blocking-caller path: [`Coordinator::submit`] hands the
/// matching receiver back and the caller parks on it (the thread-per-
/// connection server, `Coordinator::attend`). `Completion` is the reactor
/// path (ADR-007): a single-threaded epoll front end cannot park on one
/// receiver per request, so every in-flight request of a front end fans
/// into one shared completion queue tagged with an opaque id, and `wake`
/// nudges the consumer out of its `epoll_pwait` so replies flush promptly.
///
/// [`Coordinator::submit`]: crate::coordinator::Coordinator::submit
pub enum ReplyTo {
    Channel(mpsc::Sender<anyhow::Result<AttendResult>>),
    Completion {
        /// Opaque correlation id, echoed with the result.
        tag: u64,
        /// Shared completion queue of the submitting front end.
        queue: mpsc::Sender<(u64, anyhow::Result<AttendResult>)>,
        /// Nudges the queue's consumer (e.g. writes the reactor's wake
        /// pipe). Called after every enqueue.
        wake: std::sync::Arc<dyn Fn() + Send + Sync>,
    },
}

// Manual impl: `Arc<dyn Fn()>` has no derived Clone path through the
// enum, and the panic-isolation wrapper (ADR-008) must capture a reply
// handle *before* moving the item into `catch_unwind`.
impl Clone for ReplyTo {
    fn clone(&self) -> ReplyTo {
        match self {
            ReplyTo::Channel(tx) => ReplyTo::Channel(tx.clone()),
            ReplyTo::Completion { tag, queue, wake } => ReplyTo::Completion {
                tag: *tag,
                queue: queue.clone(),
                wake: wake.clone(),
            },
        }
    }
}

impl ReplyTo {
    /// Deliver the result. A vanished consumer is not actionable for the
    /// worker beyond counting it (`dropped_replies`), so the error
    /// carries no payload.
    pub fn send(&self, r: anyhow::Result<AttendResult>) -> Result<(), ()> {
        match self {
            ReplyTo::Channel(tx) => tx.send(r).map_err(|_| ()),
            ReplyTo::Completion { tag, queue, wake } => {
                let sent = queue.send((*tag, r)).map_err(|_| ());
                (**wake)();
                sent
            }
        }
    }
}

/// What the router moves around internally.
pub struct WorkItem {
    pub chunk: AttendChunk,
    /// Tick 0: the request entered `submit_with` (before validation and
    /// shard routing). `total` latency is measured from here.
    pub submitted: std::time::Instant,
    /// Tick 1: the item was handed to the shard queue. `queue_wait` is
    /// measured from here to batch formation.
    pub enqueued: std::time::Instant,
    /// Absolute deadline stamped at submission from `--request-timeout-ms`
    /// (ADR-008). Workers skip items already past it with a deterministic
    /// [`ServeError::Timeout`] instead of computing a reply nobody waits
    /// for; `None` = no deadline.
    pub deadline: Option<std::time::Instant>,
    pub reply: ReplyTo,
}

impl WorkItem {
    /// True iff the item carries a deadline that has already passed.
    pub fn expired(&self, now: std::time::Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Errors surfaced to clients. See `docs/PROTOCOL.md` ("Error taxonomy &
/// recovery") for how each maps onto the two wire planes.
#[derive(Debug, thiserror::Error)]
pub enum ServeError {
    #[error("queue full: {depth} items (backpressure)")]
    Backpressure { depth: usize },
    #[error("unknown sequence {0:?}")]
    UnknownSequence(SeqId),
    #[error("coordinator shutting down")]
    Shutdown,
    /// The request's `--request-timeout-ms` deadline passed before a
    /// reply was produced (ADR-008).
    #[error("request deadline exceeded")]
    Timeout,
    /// The shard's worker thread is gone or unresponsive; the request got
    /// a bounded structured error instead of hanging on a dead channel.
    #[error("shard {shard} unavailable (worker thread dead or unresponsive)")]
    ShardUnavailable { shard: usize },
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    #[test]
    fn validation_catches_mismatches() {
        let mut rng = Rng::new(1);
        let ok = AttendChunk {
            seq: SeqId(1),
            q: Mat::randn(4, 8, &mut rng),
            k: Mat::randn(4, 8, &mut rng),
            v: Mat::randn(4, 16, &mut rng),
        };
        assert!(ok.validate(8).is_ok());
        assert!(ok.validate(16).is_err());
        let bad_rows = AttendChunk {
            seq: SeqId(1),
            q: Mat::randn(4, 8, &mut rng),
            k: Mat::randn(3, 8, &mut rng),
            v: Mat::randn(4, 16, &mut rng),
        };
        assert!(bad_rows.validate(8).is_err());
    }

    #[test]
    fn decode_detection() {
        let mut rng = Rng::new(2);
        let decode = AttendChunk {
            seq: SeqId(1),
            q: Mat::randn(1, 8, &mut rng),
            k: Mat::randn(1, 8, &mut rng),
            v: Mat::randn(1, 8, &mut rng),
        };
        assert!(decode.is_decode());
        assert_eq!(decode.n_tokens(), 1);
    }
}
