//! Sequence state store — the linear-attention analog of a KV-cache
//! manager.
//!
//! Each live sequence owns one [`StreamingState`] `(S ∈ R^{m×d_v}, z ∈ R^m)`
//! per attention instance: **constant memory per sequence** regardless of
//! how many tokens it has absorbed. This is exactly the property that lets
//! SLAY serve 131K-token contexts where quadratic KV-caches OOM (Fig. 2/21)
//! — the store tracks bytes and enforces a budget with idle-eviction.

use crate::kernels::engine::StreamingState;
use crate::coordinator::request::SeqId;
use std::collections::HashMap;
use std::time::Instant;

struct Entry {
    state: StreamingState,
    last_touch: Instant,
}

/// Store configuration.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Feature dimension m of the serving mechanism.
    pub m: usize,
    /// Value dimension d_v.
    pub d_v: usize,
    /// Hard cap on live sequences (admission control).
    pub max_sequences: usize,
    /// Soft memory budget in bytes; exceeding it evicts idle sequences.
    pub memory_budget: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { m: 384, d_v: 32, max_sequences: 4096, memory_budget: 256 << 20 }
    }
}

/// Per-worker (sharded) sequence store.
pub struct SequenceStore {
    cfg: StoreConfig,
    seqs: HashMap<SeqId, Entry>,
    bytes: usize,
}

impl SequenceStore {
    pub fn new(cfg: StoreConfig) -> Self {
        SequenceStore { cfg, seqs: HashMap::new(), bytes: 0 }
    }

    /// Bytes one sequence state costs (constant — the linear-attention win).
    pub fn bytes_per_sequence(&self) -> usize {
        (self.cfg.m * self.cfg.d_v + self.cfg.m) * std::mem::size_of::<f32>()
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Admit a new sequence. Fails when the cap is reached and nothing is
    /// evictable (backpressure surfaces to the client).
    pub fn create(&mut self, id: SeqId) -> anyhow::Result<()> {
        if self.seqs.len() >= self.cfg.max_sequences
            || self.bytes + self.bytes_per_sequence() > self.cfg.memory_budget
        {
            self.evict_idle(1);
        }
        anyhow::ensure!(
            self.seqs.len() < self.cfg.max_sequences,
            "sequence cap {} reached",
            self.cfg.max_sequences
        );
        anyhow::ensure!(
            self.bytes + self.bytes_per_sequence() <= self.cfg.memory_budget,
            "state memory budget exhausted ({} bytes)",
            self.bytes
        );
        let prev = self.seqs.insert(
            id,
            Entry {
                state: StreamingState::new(self.cfg.m, self.cfg.d_v),
                last_touch: Instant::now(),
            },
        );
        anyhow::ensure!(prev.is_none(), "sequence {id:?} already exists");
        self.bytes += self.bytes_per_sequence();
        Ok(())
    }

    /// Mutable access, bumping the LRU clock.
    pub fn get_mut(&mut self, id: SeqId) -> Option<&mut StreamingState> {
        match self.seqs.get_mut(&id) {
            Some(e) => {
                e.last_touch = Instant::now();
                Some(&mut e.state)
            }
            None => None,
        }
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.seqs.contains_key(&id)
    }

    /// Tokens absorbed by a sequence.
    pub fn seq_len(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|e| e.state.len)
    }

    /// Drop a finished sequence, reclaiming its bytes.
    pub fn release(&mut self, id: SeqId) -> bool {
        if self.seqs.remove(&id).is_some() {
            self.bytes -= self.bytes_per_sequence();
            true
        } else {
            false
        }
    }

    /// Evict the `n` least-recently-touched sequences.
    pub fn evict_idle(&mut self, n: usize) -> usize {
        let mut order: Vec<(Instant, SeqId)> =
            self.seqs.iter().map(|(id, e)| (e.last_touch, *id)).collect();
        order.sort();
        let victims: Vec<SeqId> = order.into_iter().take(n).map(|(_, id)| id).collect();
        let count = victims.len();
        for id in victims {
            self.release(id);
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(max: usize) -> SequenceStore {
        SequenceStore::new(StoreConfig {
            m: 16,
            d_v: 4,
            max_sequences: max,
            memory_budget: 1 << 20,
        })
    }

    #[test]
    fn create_touch_release_accounting() {
        let mut s = store(8);
        s.create(SeqId(1)).unwrap();
        s.create(SeqId(2)).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.bytes(), 2 * s.bytes_per_sequence());
        assert!(s.get_mut(SeqId(1)).is_some());
        assert!(s.get_mut(SeqId(99)).is_none());
        assert!(s.release(SeqId(1)));
        assert!(!s.release(SeqId(1)));
        assert_eq!(s.bytes(), s.bytes_per_sequence());
    }

    #[test]
    fn duplicate_create_rejected() {
        let mut s = store(8);
        s.create(SeqId(1)).unwrap();
        assert!(s.create(SeqId(1)).is_err());
    }

    #[test]
    fn cap_evicts_idle_then_enforces() {
        let mut s = store(2);
        s.create(SeqId(1)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        s.create(SeqId(2)).unwrap();
        // third admission evicts the idlest (seq 1)
        s.create(SeqId(3)).unwrap();
        assert_eq!(s.len(), 2);
        assert!(!s.contains(SeqId(1)));
        assert!(s.contains(SeqId(2)) && s.contains(SeqId(3)));
    }

    #[test]
    fn state_absorbs_tokens() {
        let mut s = store(4);
        s.create(SeqId(7)).unwrap();
        let st = s.get_mut(SeqId(7)).unwrap();
        st.append(&[1.0; 16], &[0.5; 4]);
        st.append(&[0.5; 16], &[1.0; 4]);
        assert_eq!(s.seq_len(SeqId(7)), Some(2));
    }

    #[test]
    fn constant_memory_per_sequence() {
        // The central serving property: absorbing 10k tokens does not grow
        // the state.
        let mut s = store(4);
        s.create(SeqId(1)).unwrap();
        let before = s.bytes();
        let st = s.get_mut(SeqId(1)).unwrap();
        for _ in 0..10_000 {
            st.append(&[0.1; 16], &[0.2; 4]);
        }
        assert_eq!(s.bytes(), before);
        assert_eq!(s.seq_len(SeqId(1)), Some(10_000));
    }
}
