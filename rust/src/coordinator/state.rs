//! Sequence state store — the serving analog of a KV-cache manager.
//!
//! Each live sequence owns one opaque [`AttnState`] produced by the
//! worker's [`crate::kernels::AttentionBackend`]: for linear mechanisms
//! that is the constant-size `(S ∈ R^{m×d_v}, z ∈ R^m)` streaming pair —
//! exactly the property that lets SLAY serve 131K-token contexts where
//! quadratic KV-caches OOM (Fig. 2/21) — and for quadratic mechanisms a
//! bounded rolling KV window. The store budgets each state at its
//! *capacity* (the window fully populated), tracks bytes, and enforces the
//! budget with idle-eviction.

use crate::coordinator::request::SeqId;
use crate::kernels::AttnState;
use std::collections::HashMap;
use std::time::Instant;

struct Entry {
    state: AttnState,
    /// Admission-time capacity charge (constant for the entry's lifetime).
    cap_bytes: usize,
    last_touch: Instant,
}

/// Store configuration.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Hard cap on live sequences (admission control).
    pub max_sequences: usize,
    /// Soft memory budget in bytes; exceeding it evicts idle sequences.
    pub memory_budget: usize,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig { max_sequences: 4096, memory_budget: 256 << 20 }
    }
}

/// Per-worker (sharded) sequence store.
pub struct SequenceStore {
    cfg: StoreConfig,
    seqs: HashMap<SeqId, Entry>,
    bytes: usize,
}

impl SequenceStore {
    pub fn new(cfg: StoreConfig) -> Self {
        SequenceStore { cfg, seqs: HashMap::new(), bytes: 0 }
    }

    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    /// Budgeted bytes across live sequences (capacity accounting).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Admit a new sequence with its backend-created state. Fails when the
    /// cap is reached and nothing is evictable (backpressure surfaces to
    /// the client).
    pub fn create(&mut self, id: SeqId, state: AttnState) -> anyhow::Result<()> {
        // Reject duplicates before touching the map: a blind insert would
        // destroy the live sequence's absorbed state even while erroring.
        anyhow::ensure!(!self.seqs.contains_key(&id), "sequence {id:?} already exists");
        let cap_bytes = state.capacity_bytes();
        if self.seqs.len() >= self.cfg.max_sequences
            || self.bytes + cap_bytes > self.cfg.memory_budget
        {
            self.evict_idle(1);
        }
        anyhow::ensure!(
            self.seqs.len() < self.cfg.max_sequences,
            "sequence cap {} reached",
            self.cfg.max_sequences
        );
        anyhow::ensure!(
            self.bytes + cap_bytes <= self.cfg.memory_budget,
            "state memory budget exhausted ({} bytes)",
            self.bytes
        );
        self.seqs.insert(id, Entry { state, cap_bytes, last_touch: Instant::now() });
        self.bytes += cap_bytes;
        Ok(())
    }

    /// Mutable access, bumping the LRU clock.
    pub fn get_mut(&mut self, id: SeqId) -> Option<&mut AttnState> {
        match self.seqs.get_mut(&id) {
            Some(e) => {
                e.last_touch = Instant::now();
                Some(&mut e.state)
            }
            None => None,
        }
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.seqs.contains_key(&id)
    }

    /// Tokens absorbed by a sequence.
    pub fn seq_len(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|e| e.state.len())
    }

    /// Drop a finished sequence, reclaiming its bytes.
    pub fn release(&mut self, id: SeqId) -> bool {
        if let Some(e) = self.seqs.remove(&id) {
            self.bytes -= e.cap_bytes;
            true
        } else {
            false
        }
    }

    /// Evict the `n` least-recently-touched sequences.
    pub fn evict_idle(&mut self, n: usize) -> usize {
        let mut order: Vec<(Instant, SeqId)> =
            self.seqs.iter().map(|(id, e)| (e.last_touch, *id)).collect();
        order.sort();
        let victims: Vec<SeqId> = order.into_iter().take(n).map(|(_, id)| id).collect();
        let count = victims.len();
        for id in victims {
            self.release(id);
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{build, AttentionBackend};
    use crate::kernels::config::Mechanism;
    use crate::math::linalg::Mat;
    use crate::math::rng::Rng;

    fn backend() -> Box<dyn AttentionBackend> {
        build(&Mechanism::EluLinear, 16, 0).unwrap()
    }

    fn store(max: usize) -> SequenceStore {
        SequenceStore::new(StoreConfig { max_sequences: max, memory_budget: 1 << 20 })
    }

    #[test]
    fn create_touch_release_accounting() {
        let b = backend();
        let per_seq = b.new_state(4).capacity_bytes();
        let mut s = store(8);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        s.create(SeqId(2), b.new_state(4)).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.bytes(), 2 * per_seq);
        assert!(s.get_mut(SeqId(1)).is_some());
        assert!(s.get_mut(SeqId(99)).is_none());
        assert!(s.release(SeqId(1)));
        assert!(!s.release(SeqId(1)));
        assert_eq!(s.bytes(), per_seq);
    }

    #[test]
    fn duplicate_create_rejected_and_preserves_live_state() {
        let b = backend();
        let mut s = store(8);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        let mut out = vec![0.0f32; 4];
        let st = s.get_mut(SeqId(1)).unwrap();
        b.decode(st, &[0.5; 16], &[0.5; 16], &[1.0; 4], &mut out).unwrap();
        assert!(s.create(SeqId(1), b.new_state(4)).is_err());
        // the rejected create must not have wiped the absorbed tokens
        assert_eq!(s.seq_len(SeqId(1)), Some(1));
        assert_eq!(s.len(), 1);
        assert_eq!(s.bytes(), s.get_mut(SeqId(1)).unwrap().capacity_bytes());
    }

    #[test]
    fn cap_evicts_idle_then_enforces() {
        let b = backend();
        let mut s = store(2);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        s.create(SeqId(2), b.new_state(4)).unwrap();
        // third admission evicts the idlest (seq 1)
        s.create(SeqId(3), b.new_state(4)).unwrap();
        assert_eq!(s.len(), 2);
        assert!(!s.contains(SeqId(1)));
        assert!(s.contains(SeqId(2)) && s.contains(SeqId(3)));
    }

    #[test]
    fn state_absorbs_tokens() {
        let b = backend();
        let mut s = store(4);
        s.create(SeqId(7), b.new_state(4)).unwrap();
        let mut rng = Rng::new(5);
        let (q, k, v) = (
            Mat::randn(2, 16, &mut rng),
            Mat::randn(2, 16, &mut rng),
            Mat::randn(2, 4, &mut rng),
        );
        let st = s.get_mut(SeqId(7)).unwrap();
        b.prefill(st, q.view(), k.view(), v.view()).unwrap();
        assert_eq!(s.seq_len(SeqId(7)), Some(2));
    }

    #[test]
    fn constant_memory_per_sequence() {
        // The central serving property: absorbing 10k tokens does not grow
        // a linear state.
        let b = backend();
        let mut s = store(4);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        let before = s.bytes();
        let mut rng = Rng::new(6);
        let st = s.get_mut(SeqId(1)).unwrap();
        let mut out = vec![0.0f32; 4];
        let q = Mat::randn(1, 16, &mut rng);
        let k = Mat::randn(1, 16, &mut rng);
        let v = Mat::randn(1, 4, &mut rng);
        for _ in 0..10_000 {
            b.decode(st, q.row(0), k.row(0), v.row(0), &mut out).unwrap();
        }
        assert_eq!(st.bytes(), st.capacity_bytes());
        assert_eq!(s.bytes(), before);
        assert_eq!(s.seq_len(SeqId(1)), Some(10_000));
    }

    #[test]
    fn windowed_state_budgeted_at_capacity() {
        // Quadratic sessions are admitted at their fully-populated window
        // size, so the budget can never be overrun by growth.
        let b = build(&Mechanism::Standard, 16, 32).unwrap();
        let mut s = store(4);
        let st = b.new_state(4);
        assert!(st.bytes() < st.capacity_bytes());
        let cap = st.capacity_bytes();
        s.create(SeqId(1), st).unwrap();
        assert_eq!(s.bytes(), cap);
    }
}
