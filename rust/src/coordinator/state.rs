//! Sequence state store — the serving analog of a KV-cache manager.
//!
//! Each live sequence owns one opaque [`AttnState`] produced by the
//! worker's [`crate::kernels::AttentionBackend`]: for linear mechanisms
//! that is the constant-size `(S ∈ R^{m×d_v}, z ∈ R^m)` streaming pair —
//! exactly the property that lets SLAY serve 131K-token contexts where
//! quadratic KV-caches OOM (Fig. 2/21) — and for quadratic mechanisms a
//! bounded rolling KV window. The store budgets each state at its
//! *capacity* (the window fully populated), tracks bytes, and enforces the
//! budget with idle-eviction.
//!
//! # Spill tier (ADR-004)
//!
//! With [`StoreConfig::spill_dir`] set, idle eviction *pages states out*
//! through the versioned session codec ([`AttnState::encode`]) instead of
//! destroying them, and [`SequenceStore::get_mut`] transparently faults a
//! spilled state back in on the sequence's next chunk — so the memory
//! budget bounds the *resident* set while the number of live sessions is
//! bounded only by disk. Spill files are not fsynced (losing one equals an
//! eviction); durable snapshots go through
//! [`SequenceStore::export_all`], which does fsync.
//!
//! # Batched borrows (ADR-005)
//!
//! [`SequenceStore::get_many_mut`] hands out disjoint `&mut` borrows of
//! several sequences' states at once — what the fused cross-session decode
//! path feeds to
//! [`AttentionBackend::decode_batch_with`](crate::kernels::AttentionBackend::decode_batch_with).
//! Duplicate ids are rejected, every requested state is faulted in before
//! any borrow is handed out, and room-making evictions never touch the
//! request's own members.
//!
//! # Forking and the shared-prefix cache (ADR-006)
//!
//! [`SequenceStore::fork`] clones a live sequence under a fresh id:
//! linear states copy `(S, z)` outright, quadratic states fork their
//! window as a copy-on-write page table ([`AttnState::fork`]) — and a
//! *spilled* parent forks by verifying + copying its codec file, no
//! fault-in. The store also hosts the shard's
//! [`PrefixCache`](crate::coordinator::prefix::PrefixCache): memoized
//! post-chunk snapshots keyed by a rolling hash of the prefill stream.
//! Cache bytes are charged against the same `memory_budget` as resident
//! sessions, and under pressure cache entries are always shed *before*
//! any live session is evicted or spilled.

use crate::coordinator::metrics::Metrics;
use crate::coordinator::prefix::PrefixCache;
use crate::coordinator::request::SeqId;
use crate::kernels::AttnState;
use crate::math::linalg::Mat;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

struct Entry {
    state: AttnState,
    /// Admission-time capacity charge (constant for the entry's lifetime).
    cap_bytes: usize,
    last_touch: Instant,
    /// Rolling prefix-hash cursor: `Some(h)` while the sequence's chunk
    /// stream is still prefix-cacheable, `None` once it diverged (any
    /// decode step) or its provenance is unknown (snapshot install).
    prefix_cursor: Option<u64>,
}

/// Per-sequence snapshot record: `(id, seq_len, serialized bytes)` — what
/// [`SequenceStore::export_all`] reports per exported state.
pub type SnapshotRecord = (SeqId, usize, u64);

/// A paged-out sequence: its serialized state on disk plus the metadata
/// needed to answer queries and re-admit it without touching the file.
struct SpillEntry {
    path: PathBuf,
    cap_bytes: usize,
    len: usize,
    /// Carried across the spill round-trip so a faulted-in sequence can
    /// keep extending its cacheable prefix.
    prefix_cursor: Option<u64>,
}

/// Store configuration.
#[derive(Clone, Debug)]
pub struct StoreConfig {
    /// Hard cap on resident sequences (admission control).
    pub max_sequences: usize,
    /// Soft memory budget in bytes; exceeding it evicts idle sequences.
    pub memory_budget: usize,
    /// Spill directory for this shard: when set, idle eviction serializes
    /// states here instead of destroying them and the store faults them
    /// back in on demand. `None` keeps destructive eviction. The store
    /// *owns* this directory: stale `seq_*.state` files from a previous
    /// process are swept at startup (they are cache, and nothing tracks
    /// them anymore) — do not point it at a snapshot directory.
    pub spill_dir: Option<PathBuf>,
    /// Upper bound on shared-prefix cache bytes (ADR-006). The cache is
    /// *additionally* charged against `memory_budget` alongside resident
    /// sessions and shed first under pressure; `0` disables caching.
    pub prefix_cache_budget: usize,
    /// Respawn mode (ADR-008): skip the startup sweep so `seq_*.state`
    /// files left by a dead predecessor worker survive for the
    /// coordinator's re-adoption pass ([`SequenceStore::adopt_spilled`])
    /// instead of being treated as orphans.
    pub adopt_spills: bool,
}

impl Default for StoreConfig {
    fn default() -> Self {
        StoreConfig {
            max_sequences: 4096,
            memory_budget: 256 << 20,
            spill_dir: None,
            prefix_cache_budget: 64 << 20,
            adopt_spills: false,
        }
    }
}

/// Per-worker (sharded) sequence store.
pub struct SequenceStore {
    cfg: StoreConfig,
    seqs: HashMap<SeqId, Entry>,
    spilled: HashMap<SeqId, SpillEntry>,
    bytes: usize,
    metrics: Option<Arc<Metrics>>,
    /// Shard-local shared-prefix cache (ADR-006).
    prefix: PrefixCache,
    /// Cache bytes last published into the shared gauge (the gauge sums
    /// across shards, so each store moves it only by its own delta).
    prefix_gauge: u64,
}

impl SequenceStore {
    pub fn new(cfg: StoreConfig) -> Self {
        if let Some(dir) = &cfg.spill_dir {
            match std::fs::create_dir_all(dir) {
                Ok(()) if !cfg.adopt_spills => {
                    // A fresh store tracks no spilled sequences, so any
                    // surviving seq_* files are orphans of a previous
                    // process — unswept they accumulate until the disk
                    // fills and the spill tier degrades to destructive
                    // eviction. (A respawned worker sets `adopt_spills`
                    // instead: its predecessor's files are re-adopted, not
                    // orphaned.)
                    if let Ok(entries) = std::fs::read_dir(dir) {
                        for entry in entries.flatten() {
                            let name = entry.file_name();
                            let name = name.to_string_lossy();
                            if name.starts_with("seq_")
                                && (name.ends_with(".state") || name.ends_with(".tmp"))
                            {
                                let _ = std::fs::remove_file(entry.path());
                            }
                        }
                    }
                }
                Ok(()) => {}
                Err(e) => {
                    crate::log_warn!("cannot create spill dir {}: {e}", dir.display());
                }
            }
        }
        let prefix = PrefixCache::new(cfg.prefix_cache_budget);
        SequenceStore {
            cfg,
            seqs: HashMap::new(),
            spilled: HashMap::new(),
            bytes: 0,
            metrics: None,
            prefix,
            prefix_gauge: 0,
        }
    }

    /// Wire the shared metrics sink (spill counters flow through it).
    pub fn attach_metrics(&mut self, metrics: Arc<Metrics>) {
        self.metrics = Some(metrics);
    }

    /// Resident sequences (excludes spilled ones).
    pub fn len(&self) -> usize {
        self.seqs.len()
    }

    /// Sequences currently paged out to disk.
    pub fn spilled_len(&self) -> usize {
        self.spilled.len()
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty() && self.spilled.is_empty()
    }

    /// Budgeted bytes across resident sequences (capacity accounting).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Admit a new sequence with its backend-created state. Fails when the
    /// cap is reached and nothing is evictable (backpressure surfaces to
    /// the client).
    pub fn create(&mut self, id: SeqId, state: AttnState) -> anyhow::Result<()> {
        // Reject duplicates before touching the map: a blind insert would
        // destroy the live sequence's absorbed state even while erroring.
        anyhow::ensure!(
            !self.seqs.contains_key(&id) && !self.spilled.contains_key(&id),
            "sequence {id:?} already exists"
        );
        let cap_bytes = state.capacity_bytes();
        self.shed_cache_for(cap_bytes);
        if self.seqs.len() >= self.cfg.max_sequences
            || self.bytes + self.prefix.bytes() + cap_bytes > self.cfg.memory_budget
        {
            self.evict_idle(1);
        }
        anyhow::ensure!(
            self.seqs.len() < self.cfg.max_sequences,
            "sequence cap {} reached",
            self.cfg.max_sequences
        );
        anyhow::ensure!(
            self.bytes + self.prefix.bytes() + cap_bytes <= self.cfg.memory_budget,
            "state memory budget exhausted ({} bytes)",
            self.bytes
        );
        self.seqs
            .insert(id, Entry { state, cap_bytes, last_touch: Instant::now(), prefix_cursor: None });
        self.bytes += cap_bytes;
        Ok(())
    }

    /// Mutable access, bumping the LRU clock. A spilled sequence is
    /// transparently faulted back in (evicting other idle residents to
    /// make room) before the reference is handed out.
    pub fn get_mut(&mut self, id: SeqId) -> Option<&mut AttnState> {
        if !self.seqs.contains_key(&id) && !self.fault_in(id) {
            return None;
        }
        match self.seqs.get_mut(&id) {
            Some(e) => {
                e.last_touch = Instant::now();
                Some(&mut e.state)
            }
            None => None,
        }
    }

    /// Disjoint mutable borrows of several sequences' states at once — the
    /// fused batched-decode entry (ADR-005). Borrow-splitting rules:
    ///
    /// * `ids` must be mutually distinct — a duplicate would alias a
    ///   `&mut`, so it is rejected up front, before any state is touched;
    /// * every requested sequence is faulted in *before* any borrow is
    ///   handed out, and the room-making eviction only ever considers
    ///   residents **outside** the request — a batch can never evict its
    ///   own members;
    /// * an unknown id errors without handing out any borrows; a fault-in
    ///   that finds no room (every evictable resident is itself a wave
    ///   member) fails the call with the sequence left *spilled and
    ///   intact* — the caller retries per-item, no session is lost.
    ///
    /// Returns the states in `ids` order, each LRU-touched. Disjointness
    /// holds by construction: the ids are distinct map keys, and the
    /// borrows are produced by one `iter_mut` pass over the map — that
    /// pass is the O(residents + B log B) price of staying in safe code
    /// (no aliasing-based splitting), paid once per fused wave.
    pub fn get_many_mut(&mut self, ids: &[SeqId]) -> anyhow::Result<Vec<&mut AttnState>> {
        // sorted (id, request-position) index: duplicate detection here,
        // binary search in the resident pass below
        let mut order: Vec<(SeqId, usize)> =
            ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        order.sort_unstable();
        for w in order.windows(2) {
            anyhow::ensure!(
                w[0].0 != w[1].0,
                "duplicate sequence {:?} in batched borrow",
                w[0].0
            );
        }
        for &id in ids {
            if self.seqs.contains_key(&id) {
                continue;
            }
            anyhow::ensure!(self.spilled.contains_key(&id), "unknown sequence {id:?}");
            anyhow::ensure!(
                self.fault_in_skipping(id, ids),
                "cannot fault sequence {id:?} back in (resident set full; raise the store \
                 budget or shrink the batch)"
            );
        }
        let now = Instant::now();
        let mut slots: Vec<Option<&mut AttnState>> = ids.iter().map(|_| None).collect();
        for (id, e) in self.seqs.iter_mut() {
            if let Ok(j) = order.binary_search_by_key(id, |&(sid, _)| sid) {
                e.last_touch = now;
                slots[order[j].1] = Some(&mut e.state);
            }
        }
        let mut out = Vec::with_capacity(ids.len());
        for (slot, id) in slots.into_iter().zip(ids) {
            out.push(slot.ok_or_else(|| anyhow::anyhow!("unknown sequence {id:?}"))?);
        }
        Ok(out)
    }

    pub fn contains(&self, id: SeqId) -> bool {
        self.seqs.contains_key(&id) || self.spilled.contains_key(&id)
    }

    /// Tokens absorbed by a sequence (answered from metadata for spilled
    /// ones — no fault-in).
    pub fn seq_len(&self, id: SeqId) -> Option<usize> {
        self.seqs
            .get(&id)
            .map(|e| e.state.len())
            .or_else(|| self.spilled.get(&id).map(|s| s.len))
    }

    /// Drop a finished sequence (resident or spilled), reclaiming its
    /// bytes / spill file.
    pub fn release(&mut self, id: SeqId) -> bool {
        if let Some(e) = self.seqs.remove(&id) {
            self.bytes -= e.cap_bytes;
            true
        } else if let Some(s) = self.spilled.remove(&id) {
            let _ = std::fs::remove_file(&s.path);
            true
        } else {
            false
        }
    }

    /// Poison-release (ADR-008): drop `id` only if it is *resident*. A
    /// panic caught mid-compute may have left the borrowed state torn
    /// half-way through a mutation, so releasing it is the only safe
    /// disposition — while a *spilled* state was not being mutated at all
    /// and is deliberately left intact (entry and file). Returns true iff
    /// a resident state was dropped.
    pub fn release_resident(&mut self, id: SeqId) -> bool {
        if let Some(e) = self.seqs.remove(&id) {
            self.bytes -= e.cap_bytes;
            true
        } else {
            false
        }
    }

    /// Re-adopt a predecessor worker's spill file under `id` (ADR-008
    /// respawn path): the sequence enters the store *paged-out* — no
    /// resident bytes are charged, and its first chunk faults it in
    /// through the normal spill machinery. The caller has already decoded
    /// and validated the file against the backend; `cap_bytes`/`len` are
    /// the decoded state's admission metadata. The prefix cursor does not
    /// survive a worker death (the cache died with the thread), so the
    /// adopted sequence restarts uncacheable, exactly like a snapshot
    /// install.
    pub fn adopt_spilled(
        &mut self,
        id: SeqId,
        path: PathBuf,
        cap_bytes: usize,
        len: usize,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.seqs.contains_key(&id) && !self.spilled.contains_key(&id),
            "sequence {id:?} already exists"
        );
        self.spilled.insert(id, SpillEntry { path, cap_bytes, len, prefix_cursor: None });
        Ok(())
    }

    /// Evict the `n` least-recently-touched resident sequences — spilling
    /// them to disk when a spill dir is configured, destroying them
    /// otherwise (seed behavior).
    pub fn evict_idle(&mut self, n: usize) -> usize {
        self.evict_idle_skipping(n, &[])
    }

    /// [`SequenceStore::evict_idle`] restricted to victims outside `keep` —
    /// the batched-borrow path ([`SequenceStore::get_many_mut`]) protects
    /// every requested sequence while making room to fault spilled ones in.
    fn evict_idle_skipping(&mut self, n: usize, keep: &[SeqId]) -> usize {
        let mut order: Vec<(Instant, SeqId)> = self
            .seqs
            .iter()
            .filter(|(id, _)| !keep.contains(id))
            .map(|(id, e)| (e.last_touch, *id))
            .collect();
        order.sort();
        let victims: Vec<SeqId> = order.into_iter().take(n).map(|(_, id)| id).collect();
        let count = victims.len();
        for id in victims {
            if !self.spill(id) {
                self.release(id);
            }
        }
        count
    }

    /// Page one resident sequence out to the spill directory. Returns
    /// false (the caller falls back to destructive eviction) when no spill
    /// dir is configured or the write fails. Spill files are *not* fsynced:
    /// the tier is a cache whose loss equals an eviction, not a durability
    /// promise (ADR-004) — durable writes go through
    /// [`SequenceStore::export_all`].
    fn spill(&mut self, id: SeqId) -> bool {
        let dir = match &self.cfg.spill_dir {
            Some(d) => d.clone(),
            None => return false,
        };
        let entry = match self.seqs.get(&id) {
            Some(e) => e,
            None => return false,
        };
        let buf = entry.state.encode_to_vec();
        let path = crate::coordinator::persist::state_file(&dir, id);
        let wrote = if crate::util::fault::fire("spill_write").is_some() {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "injected spill_write fault"))
        } else {
            std::fs::create_dir_all(&dir).and_then(|()| std::fs::write(&path, &buf))
        };
        if let Err(e) = wrote {
            // Graceful degradation (ADR-008): a failed spill write falls
            // back to destructive eviction — counted, never a crash.
            crate::log_warn!("spill of sequence {:?} failed ({e}); evicting destructively", id);
            if let Some(m) = &self.metrics {
                m.spill_write_failed(format!("sequence {id:?}: {e}; evicted destructively"));
            }
            return false;
        }
        let e = self.seqs.remove(&id).expect("victim is resident");
        self.bytes -= e.cap_bytes;
        self.spilled.insert(
            id,
            SpillEntry {
                path,
                cap_bytes: e.cap_bytes,
                len: e.state.len(),
                prefix_cursor: e.prefix_cursor,
            },
        );
        if let Some(m) = &self.metrics {
            m.spilled.fetch_add(1, Ordering::Relaxed);
            m.bytes_spilled.fetch_add(buf.len() as u64, Ordering::Relaxed);
        }
        true
    }

    /// Fault a spilled sequence back into the resident set, evicting other
    /// idle sequences until its admission charge fits the budget again.
    /// The spill files were written by this store from validated states,
    /// so only the codec's checksum is re-verified here.
    fn fault_in(&mut self, id: SeqId) -> bool {
        self.fault_in_skipping(id, &[])
    }

    /// [`SequenceStore::fault_in`] with the room-making eviction
    /// restricted to residents outside `keep` (the batched-borrow path).
    ///
    /// Room is made *before* the spill entry is touched: when the resident
    /// set cannot take the state — e.g. every resident is a protected wave
    /// member — the sequence stays spilled (entry and file intact) instead
    /// of being destroyed, so an oversized batched borrow degrades to an
    /// error the caller can retry per-item, never to session loss.
    fn fault_in_skipping(&mut self, id: SeqId, keep: &[SeqId]) -> bool {
        let cap_bytes = match self.spilled.get(&id) {
            Some(e) => e.cap_bytes,
            None => return false,
        };
        self.shed_cache_for(cap_bytes);
        while !self.seqs.is_empty()
            && (self.seqs.len() >= self.cfg.max_sequences
                || self.bytes + self.prefix.bytes() + cap_bytes > self.cfg.memory_budget)
        {
            if self.evict_idle_skipping(1, keep) == 0 {
                break;
            }
        }
        if self.seqs.len() >= self.cfg.max_sequences
            || self.bytes + self.prefix.bytes() + cap_bytes > self.cfg.memory_budget
        {
            crate::log_warn!("no room to fault sequence {:?} back in; leaving it spilled", id);
            return false;
        }
        let entry = self.spilled.remove(&id).expect("presence checked above");
        let decoded = if crate::util::fault::fire("spill_read").is_some() {
            Err(anyhow::anyhow!("injected spill_read fault"))
        } else {
            std::fs::File::open(&entry.path)
                .map_err(anyhow::Error::from)
                .and_then(|f| AttnState::decode(&mut std::io::BufReader::new(f)))
        };
        let _ = std::fs::remove_file(&entry.path);
        let state = match decoded {
            Ok(s) => s,
            Err(e) => {
                // the file itself is unusable — dropping IS the eviction
                crate::log_warn!("dropping spilled sequence {:?}: {e}", id);
                return false;
            }
        };
        self.bytes += entry.cap_bytes;
        self.seqs.insert(
            id,
            Entry {
                state,
                cap_bytes: entry.cap_bytes,
                last_touch: Instant::now(),
                prefix_cursor: entry.prefix_cursor,
            },
        );
        if let Some(m) = &self.metrics {
            m.restored_from_spill.fetch_add(1, Ordering::Relaxed);
        }
        true
    }

    /// Write every sequence this shard owns — resident *and* spilled —
    /// into `dir` as one codec file per sequence, each fsynced and
    /// atomically renamed into place (snapshots are a durability promise,
    /// unlike the spill tier). Spilled entries' bytes come from unsynced
    /// cache files, so their codec checksum is verified before promotion —
    /// a rotten spill file is skipped (= an eviction) instead of poisoning
    /// the snapshot. Returns one [`SnapshotRecord`] per exported sequence.
    pub fn export_all(&self, dir: &Path) -> anyhow::Result<Vec<SnapshotRecord>> {
        std::fs::create_dir_all(dir)?;
        let mut out = Vec::with_capacity(self.seqs.len() + self.spilled.len());
        for (id, e) in &self.seqs {
            let buf = e.state.encode_to_vec();
            let path = crate::coordinator::persist::state_file(dir, *id);
            crate::coordinator::persist::write_durable(&path, &buf)?;
            out.push((*id, e.state.len(), buf.len() as u64));
        }
        for (id, s) in &self.spilled {
            let buf = match std::fs::read(&s.path) {
                Ok(b) => b,
                Err(e) => {
                    crate::log_warn!("snapshot skips spilled sequence {:?} (unreadable: {e})", id);
                    continue;
                }
            };
            if let Err(e) = AttnState::verify_encoded(&buf) {
                crate::log_warn!("snapshot skips spilled sequence {:?} (corrupt: {e})", id);
                continue;
            }
            let path = crate::coordinator::persist::state_file(dir, *id);
            crate::coordinator::persist::write_durable(&path, &buf)?;
            out.push((*id, s.len, buf.len() as u64));
        }
        Ok(out)
    }

    /// Clone a live (or spilled) sequence under a fresh id (ADR-006).
    ///
    /// A resident parent forks in O(1) for linear states (the `(S, z)`
    /// pair copies outright) and O(pages) for quadratic ones (the COW
    /// window page table clones by refcount), with admission control as
    /// in [`SequenceStore::create`] — room-making never victimizes the
    /// parent. A *spilled* parent forks without fault-in: its codec file
    /// is checksum-verified and copied under the child's spill path, so
    /// the child is born paged-out and charges no resident bytes.
    pub fn fork(&mut self, parent: SeqId, child: SeqId) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.seqs.contains_key(&child) && !self.spilled.contains_key(&child),
            "sequence {child:?} already exists"
        );
        if let Some(pe) = self.seqs.get(&parent) {
            let state = pe.state.fork();
            let cap_bytes = pe.cap_bytes;
            let prefix_cursor = pe.prefix_cursor;
            self.shed_cache_for(cap_bytes);
            if self.seqs.len() >= self.cfg.max_sequences
                || self.bytes + self.prefix.bytes() + cap_bytes > self.cfg.memory_budget
            {
                self.evict_idle_skipping(1, &[parent]);
            }
            anyhow::ensure!(
                self.seqs.len() < self.cfg.max_sequences,
                "sequence cap {} reached",
                self.cfg.max_sequences
            );
            anyhow::ensure!(
                self.bytes + self.prefix.bytes() + cap_bytes <= self.cfg.memory_budget,
                "state memory budget exhausted ({} bytes)",
                self.bytes
            );
            self.seqs
                .insert(child, Entry { state, cap_bytes, last_touch: Instant::now(), prefix_cursor });
            self.bytes += cap_bytes;
            if let Some(m) = &self.metrics {
                m.forks.fetch_add(1, Ordering::Relaxed);
            }
            return Ok(());
        }
        let (src, cap_bytes, len, prefix_cursor) = match self.spilled.get(&parent) {
            Some(s) => (s.path.clone(), s.cap_bytes, s.len, s.prefix_cursor),
            None => anyhow::bail!("unknown sequence {parent:?}"),
        };
        // The codec file IS the fork payload: verify its checksum and copy
        // it under the child's path — the parent never faults in.
        let dir = self
            .cfg
            .spill_dir
            .clone()
            .ok_or_else(|| anyhow::anyhow!("spilled sequence {parent:?} without a spill dir"))?;
        let buf = std::fs::read(&src)?;
        AttnState::verify_encoded(&buf)?;
        let path = crate::coordinator::persist::state_file(&dir, child);
        std::fs::write(&path, &buf)?;
        self.spilled.insert(child, SpillEntry { path, cap_bytes, len, prefix_cursor });
        if let Some(m) = &self.metrics {
            m.forks.fetch_add(1, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Swap a *resident* sequence's state in place (the prefix-cache hit
    /// path), keeping byte accounting and the LRU clock coherent. Errors
    /// for spilled or unknown sequences — callers touch the state first,
    /// which faults it in.
    pub fn replace_state(&mut self, id: SeqId, state: AttnState) -> anyhow::Result<()> {
        let cap_bytes = state.capacity_bytes();
        match self.seqs.get_mut(&id) {
            Some(e) => {
                self.bytes = self.bytes + cap_bytes - e.cap_bytes;
                e.state = state;
                e.cap_bytes = cap_bytes;
                e.last_touch = Instant::now();
                Ok(())
            }
            None => anyhow::bail!("sequence {id:?} is not resident"),
        }
    }

    /// Rolling prefix-hash cursor of a sequence (resident or spilled):
    /// `Some(h)` while its chunk stream is still cacheable, `None` once
    /// it diverged or its provenance is unknown.
    pub fn prefix_cursor(&self, id: SeqId) -> Option<u64> {
        self.seqs
            .get(&id)
            .map(|e| e.prefix_cursor)
            .or_else(|| self.spilled.get(&id).map(|s| s.prefix_cursor))
            .flatten()
    }

    pub fn set_prefix_cursor(&mut self, id: SeqId, cursor: Option<u64>) {
        if let Some(e) = self.seqs.get_mut(&id) {
            e.prefix_cursor = cursor;
        } else if let Some(s) = self.spilled.get_mut(&id) {
            s.prefix_cursor = cursor;
        }
    }

    /// Shared-prefix cache lookup for a *resident* sequence. On a hit the
    /// session's state is replaced by a fork of the memoized post-chunk
    /// snapshot, its cursor advances to `h`, and the cached chunk output
    /// comes back — the caller skips the chunk's compute entirely and
    /// replays it. `n` is the incoming chunk's token count: the memoized
    /// boundary must sit exactly at `current_len + n` (collision guard).
    pub fn prefix_lookup(&mut self, id: SeqId, h: u64, mech_tag: u64, n: usize) -> Option<Mat> {
        let cur = match self.seqs.get(&id) {
            Some(e) => e.state.len(),
            None => return None,
        };
        let hit = self.prefix.lookup(h, cur + n, mech_tag);
        self.publish_cache_gauge();
        let (state, y) = hit?;
        self.replace_state(id, state).ok()?;
        self.set_prefix_cursor(id, Some(h));
        Some(y)
    }

    /// Memoize `id`'s current state as the post-chunk snapshot for rolling
    /// hash `h`, paired with the chunk output `y`. The snapshot is a COW
    /// fork; its bytes are charged against `memory_budget` and shed first
    /// under pressure (never displacing a live session).
    pub fn prefix_insert(&mut self, id: SeqId, h: u64, y: &Mat) {
        let (state, len) = match self.seqs.get(&id) {
            Some(e) => (e.state.fork(), e.state.len()),
            None => return,
        };
        self.prefix.insert(h, state, y.clone(), len);
        if self.bytes + self.prefix.bytes() > self.cfg.memory_budget {
            let allow = self.cfg.memory_budget.saturating_sub(self.bytes);
            self.prefix.shrink_to(allow);
        }
        self.publish_cache_gauge();
    }

    /// Bytes currently held by the shared-prefix cache.
    pub fn prefix_cache_bytes(&self) -> usize {
        self.prefix.bytes()
    }

    /// Chunk boundaries currently memoized in the shared-prefix cache.
    pub fn prefix_cache_len(&self) -> usize {
        self.prefix.len()
    }

    /// Memory-pressure valve: before any session is evicted or spilled to
    /// admit `cap_bytes`, shed prefix-cache entries (they are pure cache)
    /// until the combined charge fits — or the cache is empty.
    fn shed_cache_for(&mut self, cap_bytes: usize) {
        if self.bytes + self.prefix.bytes() + cap_bytes > self.cfg.memory_budget {
            let allow = self.cfg.memory_budget.saturating_sub(self.bytes + cap_bytes);
            self.prefix.shrink_to(allow);
            self.publish_cache_gauge();
        }
    }

    /// Push this shard's cache size into the shared gauge as a delta (the
    /// gauge sums across worker shards).
    fn publish_cache_gauge(&mut self) {
        let now = self.prefix.bytes() as u64;
        if let Some(m) = &self.metrics {
            if now > self.prefix_gauge {
                m.prefix_cache_bytes.fetch_add(now - self.prefix_gauge, Ordering::Relaxed);
            } else if now < self.prefix_gauge {
                m.prefix_cache_bytes.fetch_sub(self.prefix_gauge - now, Ordering::Relaxed);
            }
        }
        self.prefix_gauge = now;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{build, AttentionBackend};
    use crate::kernels::config::Mechanism;
    use crate::math::linalg::Mat;
    use crate::math::rng::Rng;

    fn backend() -> Box<dyn AttentionBackend> {
        build(&Mechanism::EluLinear, 16, 0).unwrap()
    }

    fn store(max: usize) -> SequenceStore {
        SequenceStore::new(StoreConfig {
            max_sequences: max,
            memory_budget: 1 << 20,
            spill_dir: None,
            prefix_cache_budget: 1 << 20,
            adopt_spills: false,
        })
    }

    fn spill_store(max: usize, budget: usize, dir: &std::path::Path) -> SequenceStore {
        let _ = std::fs::remove_dir_all(dir);
        SequenceStore::new(StoreConfig {
            max_sequences: max,
            memory_budget: budget,
            spill_dir: Some(dir.to_path_buf()),
            prefix_cache_budget: 1 << 20,
            adopt_spills: false,
        })
    }

    #[test]
    fn create_touch_release_accounting() {
        let b = backend();
        let per_seq = b.new_state(4).capacity_bytes();
        let mut s = store(8);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        s.create(SeqId(2), b.new_state(4)).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.bytes(), 2 * per_seq);
        assert!(s.get_mut(SeqId(1)).is_some());
        assert!(s.get_mut(SeqId(99)).is_none());
        assert!(s.release(SeqId(1)));
        assert!(!s.release(SeqId(1)));
        assert_eq!(s.bytes(), per_seq);
    }

    #[test]
    fn duplicate_create_rejected_and_preserves_live_state() {
        let b = backend();
        let mut s = store(8);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        let mut out = vec![0.0f32; 4];
        let st = s.get_mut(SeqId(1)).unwrap();
        b.decode(st, &[0.5; 16], &[0.5; 16], &[1.0; 4], &mut out).unwrap();
        assert!(s.create(SeqId(1), b.new_state(4)).is_err());
        // the rejected create must not have wiped the absorbed tokens
        assert_eq!(s.seq_len(SeqId(1)), Some(1));
        assert_eq!(s.len(), 1);
        assert_eq!(s.bytes(), s.get_mut(SeqId(1)).unwrap().capacity_bytes());
    }

    #[test]
    fn cap_evicts_idle_then_enforces() {
        let b = backend();
        let mut s = store(2);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        s.create(SeqId(2), b.new_state(4)).unwrap();
        // third admission evicts the idlest (seq 1)
        s.create(SeqId(3), b.new_state(4)).unwrap();
        assert_eq!(s.len(), 2);
        assert!(!s.contains(SeqId(1)));
        assert!(s.contains(SeqId(2)) && s.contains(SeqId(3)));
    }

    #[test]
    fn state_absorbs_tokens() {
        let b = backend();
        let mut s = store(4);
        s.create(SeqId(7), b.new_state(4)).unwrap();
        let mut rng = Rng::new(5);
        let (q, k, v) = (
            Mat::randn(2, 16, &mut rng),
            Mat::randn(2, 16, &mut rng),
            Mat::randn(2, 4, &mut rng),
        );
        let st = s.get_mut(SeqId(7)).unwrap();
        b.prefill(st, q.view(), k.view(), v.view()).unwrap();
        assert_eq!(s.seq_len(SeqId(7)), Some(2));
    }

    #[test]
    fn constant_memory_per_sequence() {
        // The central serving property: absorbing 10k tokens does not grow
        // a linear state.
        let b = backend();
        let mut s = store(4);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        let before = s.bytes();
        let mut rng = Rng::new(6);
        let st = s.get_mut(SeqId(1)).unwrap();
        let mut out = vec![0.0f32; 4];
        let q = Mat::randn(1, 16, &mut rng);
        let k = Mat::randn(1, 16, &mut rng);
        let v = Mat::randn(1, 4, &mut rng);
        for _ in 0..10_000 {
            b.decode(st, q.row(0), k.row(0), v.row(0), &mut out).unwrap();
        }
        assert_eq!(st.bytes(), st.capacity_bytes());
        assert_eq!(s.bytes(), before);
        assert_eq!(s.seq_len(SeqId(1)), Some(10_000));
    }

    #[test]
    fn windowed_state_budgeted_at_capacity() {
        // Quadratic sessions are admitted at their fully-populated window
        // size, so the budget can never be overrun by growth.
        let b = build(&Mechanism::Standard, 16, 32).unwrap();
        let mut s = store(4);
        let st = b.new_state(4);
        assert!(st.bytes() < st.capacity_bytes());
        let cap = st.capacity_bytes();
        s.create(SeqId(1), st).unwrap();
        assert_eq!(s.bytes(), cap);
    }

    #[test]
    fn eviction_spills_and_fault_in_restores_bit_identically() {
        let b = backend();
        let dir = std::env::temp_dir().join("slay_store_spill_roundtrip");
        let mut s = spill_store(1, 1 << 20, &dir);
        let mut rng = Rng::new(11);
        let q = Mat::randn(3, 16, &mut rng);
        let k = Mat::randn(3, 16, &mut rng);
        let v = Mat::randn(3, 4, &mut rng);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        b.prefill(s.get_mut(SeqId(1)).unwrap(), q.view(), k.view(), v.view()).unwrap();
        // reference: the same prefill on a never-evicted state
        let mut reference = b.new_state(4);
        b.prefill(&mut reference, q.view(), k.view(), v.view()).unwrap();
        // admitting a second sequence under max_sequences = 1 spills seq 1
        std::thread::sleep(std::time::Duration::from_millis(2));
        s.create(SeqId(2), b.new_state(4)).unwrap();
        assert_eq!(s.len(), 1);
        assert_eq!(s.spilled_len(), 1);
        assert!(s.contains(SeqId(1)), "spilled sequence still belongs to the store");
        assert_eq!(s.seq_len(SeqId(1)), Some(3), "seq_len answered from spill metadata");
        // fault back in (which spills seq 2 in turn) and decode on both
        let mut out_spilled = vec![0.0f32; 4];
        let mut out_ref = vec![0.0f32; 4];
        let st = s.get_mut(SeqId(1)).expect("fault-in");
        b.decode(st, q.row(0), k.row(0), v.row(0), &mut out_spilled).unwrap();
        b.decode(&mut reference, q.row(0), k.row(0), v.row(0), &mut out_ref).unwrap();
        assert_eq!(out_spilled, out_ref, "fault-in must resume bit-identically");
        assert_eq!(s.seq_len(SeqId(1)), Some(4));
        assert_eq!(s.spilled_len(), 1, "seq 2 was paged out to make room");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn release_reclaims_spilled_sequences_and_their_files() {
        let b = backend();
        let dir = std::env::temp_dir().join("slay_store_spill_release");
        let mut s = spill_store(1, 1 << 20, &dir);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        s.create(SeqId(2), b.new_state(4)).unwrap();
        let file = crate::coordinator::persist::state_file(&dir, SeqId(1));
        assert!(file.exists(), "spill file must exist while paged out");
        // duplicate admission is rejected against the spilled tier too
        assert!(s.create(SeqId(1), b.new_state(4)).is_err());
        assert!(s.release(SeqId(1)));
        assert!(!s.contains(SeqId(1)));
        assert!(!file.exists(), "release must reclaim the spill file");
        assert!(!s.release(SeqId(1)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn get_many_mut_disjoint_borrows_and_duplicate_rejection() {
        let b = backend();
        let mut s = store(8);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        s.create(SeqId(2), b.new_state(4)).unwrap();
        s.create(SeqId(3), b.new_state(4)).unwrap();
        // duplicates would alias a &mut — rejected before any state is touched
        assert!(s.get_many_mut(&[SeqId(1), SeqId(2), SeqId(1)]).is_err());
        // unknown ids error without handing out borrows
        assert!(s.get_many_mut(&[SeqId(1), SeqId(99)]).is_err());
        // happy path: borrows come back in request order and are disjoint
        let mut out = vec![0.0f32; 4];
        {
            let states = s.get_many_mut(&[SeqId(3), SeqId(1)]).unwrap();
            assert_eq!(states.len(), 2);
            for st in states {
                b.decode(st, &[0.5; 16], &[0.5; 16], &[1.0; 4], &mut out).unwrap();
            }
        }
        assert_eq!(s.seq_len(SeqId(3)), Some(1));
        assert_eq!(s.seq_len(SeqId(1)), Some(1));
        assert_eq!(s.seq_len(SeqId(2)), Some(0), "unrequested sequence untouched");
    }

    #[test]
    fn get_many_mut_faults_spilled_in_and_protects_requested_residents() {
        let b = backend();
        let dir = std::env::temp_dir().join("slay_store_many_mut_spill");
        let per_seq = b.new_state(4).capacity_bytes();
        // budget fits exactly two resident states
        let mut s = spill_store(8, 2 * per_seq, &dir);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        s.create(SeqId(2), b.new_state(4)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        // admitting #3 pages #1 (the LRU resident) out
        s.create(SeqId(3), b.new_state(4)).unwrap();
        let f1 = crate::coordinator::persist::state_file(&dir, SeqId(1));
        assert!(f1.exists(), "seq 1 paged out");
        // Request {1, 2}: faulting 1 back in must evict 3 — the only
        // resident OUTSIDE the request — even though 2 is the older touch
        // (an unprotected LRU pass would have victimized 2).
        {
            let states = s.get_many_mut(&[SeqId(1), SeqId(2)]).unwrap();
            assert_eq!(states.len(), 2);
            assert_eq!(states[0].len(), 0, "faulted state decodes from its true length");
        }
        assert!(!f1.exists(), "fault-in reclaims the spill file");
        let f3 = crate::coordinator::persist::state_file(&dir, SeqId(3));
        assert!(f3.exists(), "the non-requested resident was paged out to make room");
        assert_eq!(s.len(), 2);
        assert_eq!(s.spilled_len(), 1);
        assert!(s.contains(SeqId(1)) && s.contains(SeqId(2)) && s.contains(SeqId(3)));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn get_many_mut_no_room_leaves_sequence_spilled_not_destroyed() {
        // A wave larger than the resident budget must fail the batched
        // borrow with the spilled member INTACT (entry + file) — the
        // worker then retries per-item; the session is never lost.
        let b = backend();
        let dir = std::env::temp_dir().join("slay_store_many_mut_no_room");
        let per_seq = b.new_state(4).capacity_bytes();
        // budget fits exactly one resident state
        let mut s = spill_store(8, per_seq, &dir);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        // admitting #2 pages #1 out
        s.create(SeqId(2), b.new_state(4)).unwrap();
        let f1 = crate::coordinator::persist::state_file(&dir, SeqId(1));
        assert!(f1.exists());
        // both requested: faulting #1 cannot evict #2 (protected) → error,
        // and #1 must still be spilled afterwards
        assert!(s.get_many_mut(&[SeqId(1), SeqId(2)]).is_err());
        assert!(s.contains(SeqId(1)), "failed batched borrow must not destroy the session");
        assert_eq!(s.spilled_len(), 1);
        assert!(f1.exists(), "spill file must survive the failed fault-in");
        // the per-item path still serves it (unprotected eviction)
        assert!(s.get_mut(SeqId(1)).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_counters_flow_through_metrics() {
        let b = backend();
        let dir = std::env::temp_dir().join("slay_store_spill_metrics");
        let per_seq = b.new_state(4).capacity_bytes();
        let mut s = spill_store(8, per_seq, &dir);
        let m = Arc::new(Metrics::new());
        s.attach_metrics(m.clone());
        s.create(SeqId(1), b.new_state(4)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        // the budget fits exactly one state: admitting #2 pages #1 out
        s.create(SeqId(2), b.new_state(4)).unwrap();
        assert_eq!(m.spilled.load(Ordering::Relaxed), 1);
        assert!(m.bytes_spilled.load(Ordering::Relaxed) > 0);
        // touching #1 faults it back (paging #2 out)
        assert!(s.get_mut(SeqId(1)).is_some());
        assert_eq!(m.restored_from_spill.load(Ordering::Relaxed), 1);
        assert_eq!(m.spilled.load(Ordering::Relaxed), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fork_resident_clones_and_accounts() {
        let b = backend();
        let per_seq = b.new_state(4).capacity_bytes();
        let mut s = store(8);
        let mut rng = Rng::new(21);
        let q = Mat::randn(3, 16, &mut rng);
        let k = Mat::randn(3, 16, &mut rng);
        let v = Mat::randn(3, 4, &mut rng);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        b.prefill(s.get_mut(SeqId(1)).unwrap(), q.view(), k.view(), v.view()).unwrap();
        s.fork(SeqId(1), SeqId(9)).unwrap();
        assert_eq!(s.len(), 2);
        assert_eq!(s.bytes(), 2 * per_seq);
        assert_eq!(s.seq_len(SeqId(9)), Some(3));
        // the fork resumes bit-identically to its parent
        let mut out_parent = vec![0.0f32; 4];
        let mut out_child = vec![0.0f32; 4];
        b.decode(s.get_mut(SeqId(1)).unwrap(), q.row(0), k.row(0), v.row(0), &mut out_parent)
            .unwrap();
        b.decode(s.get_mut(SeqId(9)).unwrap(), q.row(0), k.row(0), v.row(0), &mut out_child)
            .unwrap();
        assert_eq!(out_parent, out_child);
        // duplicate child and unknown parent are rejected
        assert!(s.fork(SeqId(1), SeqId(9)).is_err());
        assert!(s.fork(SeqId(42), SeqId(10)).is_err());
    }

    #[test]
    fn fork_spilled_parent_without_fault_in() {
        let b = backend();
        let dir = std::env::temp_dir().join("slay_store_fork_spilled");
        let per_seq = b.new_state(4).capacity_bytes();
        // budget fits exactly one resident state
        let mut s = spill_store(8, per_seq, &dir);
        let m = Arc::new(Metrics::new());
        s.attach_metrics(m.clone());
        let mut rng = Rng::new(22);
        let q = Mat::randn(3, 16, &mut rng);
        let k = Mat::randn(3, 16, &mut rng);
        let v = Mat::randn(3, 4, &mut rng);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        b.prefill(s.get_mut(SeqId(1)).unwrap(), q.view(), k.view(), v.view()).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        // admitting #2 pages #1 out
        s.create(SeqId(2), b.new_state(4)).unwrap();
        assert_eq!(s.spilled_len(), 1);
        // forking the spilled parent copies its codec file — no fault-in
        s.fork(SeqId(1), SeqId(9)).unwrap();
        assert_eq!(m.restored_from_spill.load(Ordering::Relaxed), 0, "fork must not fault in");
        assert_eq!(m.forks.load(Ordering::Relaxed), 1);
        assert_eq!(s.spilled_len(), 2, "the child is born paged-out");
        assert_eq!(s.len(), 1);
        assert_eq!(s.seq_len(SeqId(9)), Some(3), "child metadata answers without fault-in");
        // the child faults in and resumes bit-identically to a reference
        let mut reference = b.new_state(4);
        b.prefill(&mut reference, q.view(), k.view(), v.view()).unwrap();
        let mut out_child = vec![0.0f32; 4];
        let mut out_ref = vec![0.0f32; 4];
        b.decode(s.get_mut(SeqId(9)).unwrap(), q.row(0), k.row(0), v.row(0), &mut out_child)
            .unwrap();
        b.decode(&mut reference, q.row(0), k.row(0), v.row(0), &mut out_ref).unwrap();
        assert_eq!(out_child, out_ref);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_spill_write_degrades_to_counted_destroy_evict() {
        let b = backend();
        // Point the spill dir UNDER a regular file: create_dir_all fails,
        // so every spill attempt is a real write failure.
        let blocker = std::env::temp_dir().join("slay_store_spill_fail_blocker");
        std::fs::write(&blocker, b"not a directory").unwrap();
        let dir = blocker.join("spill");
        let per_seq = b.new_state(4).capacity_bytes();
        let mut s = SequenceStore::new(StoreConfig {
            max_sequences: 8,
            memory_budget: per_seq, // exactly one resident
            spill_dir: Some(dir),
            prefix_cache_budget: 1 << 20,
            adopt_spills: false,
        });
        let m = Arc::new(Metrics::new());
        s.attach_metrics(m.clone());
        s.create(SeqId(1), b.new_state(4)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        // admitting #2 tries to spill #1, fails, and destroys it instead
        s.create(SeqId(2), b.new_state(4)).unwrap();
        assert!(!s.contains(SeqId(1)), "failed spill degrades to destructive eviction");
        assert!(s.contains(SeqId(2)));
        assert_eq!(s.spilled_len(), 0);
        assert_eq!(m.spill_write_failures.load(Ordering::Relaxed), 1);
        assert_eq!(m.spilled.load(Ordering::Relaxed), 0, "a failed spill is not a spill");
        let _ = std::fs::remove_file(&blocker);
    }

    #[test]
    fn release_resident_leaves_spilled_states_intact() {
        let b = backend();
        let dir = std::env::temp_dir().join("slay_store_release_resident");
        let per_seq = b.new_state(4).capacity_bytes();
        let mut s = spill_store(8, per_seq, &dir);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(2));
        s.create(SeqId(2), b.new_state(4)).unwrap(); // pages #1 out
        assert_eq!(s.spilled_len(), 1);
        // the poison path drops the resident…
        assert!(s.release_resident(SeqId(2)));
        assert!(!s.contains(SeqId(2)));
        assert_eq!(s.bytes(), 0);
        // …but never touches spilled or unknown sequences
        assert!(!s.release_resident(SeqId(1)));
        assert!(!s.release_resident(SeqId(99)));
        assert!(s.contains(SeqId(1)), "spilled state survives the poison path");
        let f1 = crate::coordinator::persist::state_file(&dir, SeqId(1));
        assert!(f1.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn adopt_spilled_readmits_a_predecessors_file_bit_identically() {
        let b = backend();
        let dir = std::env::temp_dir().join("slay_store_adopt_spilled");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(31);
        let q = Mat::randn(3, 16, &mut rng);
        let k = Mat::randn(3, 16, &mut rng);
        let v = Mat::randn(3, 4, &mut rng);
        // "Predecessor worker": build a state, write its codec file the
        // way the spill tier would, then drop everything but the file.
        let mut prior = b.new_state(4);
        b.prefill(&mut prior, q.view(), k.view(), v.view()).unwrap();
        let path = crate::coordinator::persist::state_file(&dir, SeqId(5));
        std::fs::write(&path, prior.encode_to_vec()).unwrap();
        let (cap, len) = (prior.capacity_bytes(), prior.len());
        // "Respawned worker": adopt_spills must keep the file through
        // construction, then the adopted entry serves normally.
        let mut s = SequenceStore::new(StoreConfig {
            max_sequences: 8,
            memory_budget: 1 << 20,
            spill_dir: Some(dir.clone()),
            prefix_cache_budget: 1 << 20,
            adopt_spills: true,
        });
        assert!(path.exists(), "adopt_spills must not sweep the predecessor's files");
        s.adopt_spilled(SeqId(5), path.clone(), cap, len).unwrap();
        assert!(s.adopt_spilled(SeqId(5), path, cap, len).is_err(), "duplicate rejected");
        assert_eq!(s.len(), 0, "adopted sequences enter paged-out");
        assert_eq!(s.spilled_len(), 1);
        assert_eq!(s.seq_len(SeqId(5)), Some(3));
        // first touch faults it in; decode must match the uninterrupted state
        let mut out_adopted = vec![0.0f32; 4];
        let mut out_ref = vec![0.0f32; 4];
        let st = s.get_mut(SeqId(5)).expect("fault-in of adopted state");
        b.decode(st, q.row(0), k.row(0), v.row(0), &mut out_adopted).unwrap();
        b.decode(&mut prior, q.row(0), k.row(0), v.row(0), &mut out_ref).unwrap();
        assert_eq!(out_adopted, out_ref, "adoption must resume bit-identically");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefix_cache_charges_budget_and_sheds_before_sessions() {
        let b = backend();
        let per_seq = b.new_state(4).capacity_bytes();
        // room for three residents plus one slim cache entry (y = 2×4 f32)
        let mut s = SequenceStore::new(StoreConfig {
            max_sequences: 8,
            memory_budget: 3 * per_seq + 64,
            spill_dir: None,
            prefix_cache_budget: 1 << 20,
            adopt_spills: false,
        });
        let m = Arc::new(Metrics::new());
        s.attach_metrics(m.clone());
        let mut rng = Rng::new(23);
        let q = Mat::randn(2, 16, &mut rng);
        let k = Mat::randn(2, 16, &mut rng);
        let v = Mat::randn(2, 4, &mut rng);
        s.create(SeqId(1), b.new_state(4)).unwrap();
        s.create(SeqId(2), b.new_state(4)).unwrap();
        let y = b.prefill(s.get_mut(SeqId(1)).unwrap(), q.view(), k.view(), v.view()).unwrap();
        s.prefix_insert(SeqId(1), 0xfeed, &y);
        assert_eq!(s.prefix_cache_len(), 1);
        assert_eq!(
            m.prefix_cache_bytes.load(Ordering::Relaxed) as usize,
            s.prefix_cache_bytes(),
            "gauge tracks cache bytes"
        );
        // a third session no longer fits alongside the cache entry: the
        // cache is shed first and every live session survives
        s.create(SeqId(3), b.new_state(4)).unwrap();
        assert_eq!(s.prefix_cache_len(), 0, "cache entries go before sessions");
        assert_eq!(m.prefix_cache_bytes.load(Ordering::Relaxed), 0);
        assert!(s.contains(SeqId(1)) && s.contains(SeqId(2)) && s.contains(SeqId(3)));
    }

    #[test]
    fn prefix_lookup_replays_state_output_and_cursor() {
        use crate::coordinator::prefix::{prefix_seed, roll_chunk};
        let b = backend();
        let mut s = store(8);
        let mut rng = Rng::new(24);
        let q = Mat::randn(4, 16, &mut rng);
        let k = Mat::randn(4, 16, &mut rng);
        let v = Mat::randn(4, 4, &mut rng);
        let seed = prefix_seed("elu", 16, 4, 0);
        let h = roll_chunk(seed, &q, &k, &v);
        // session 1 computes the chunk and memoizes the boundary
        s.create(SeqId(1), b.new_state(4)).unwrap();
        s.set_prefix_cursor(SeqId(1), Some(seed));
        let y = b.prefill(s.get_mut(SeqId(1)).unwrap(), q.view(), k.view(), v.view()).unwrap();
        s.prefix_insert(SeqId(1), h, &y);
        // session 2 replays it without computing anything
        s.create(SeqId(2), b.new_state(4)).unwrap();
        s.set_prefix_cursor(SeqId(2), Some(seed));
        let tag = s.get_mut(SeqId(2)).unwrap().mech_tag();
        // wrong expected length misses
        assert!(s.prefix_lookup(SeqId(2), h, tag, 3).is_none());
        let replay = s.prefix_lookup(SeqId(2), h, tag, 4).expect("hit");
        assert_eq!(replay, y, "cached output replays verbatim");
        assert_eq!(s.seq_len(SeqId(2)), Some(4), "state fast-forwarded past the chunk");
        assert_eq!(s.prefix_cursor(SeqId(2)), Some(h), "cursor advanced to the boundary");
        // both sessions decode identically from here
        let mut out1 = vec![0.0f32; 4];
        let mut out2 = vec![0.0f32; 4];
        b.decode(s.get_mut(SeqId(1)).unwrap(), q.row(0), k.row(0), v.row(0), &mut out1).unwrap();
        b.decode(s.get_mut(SeqId(2)).unwrap(), q.row(0), k.row(0), v.row(0), &mut out2).unwrap();
        assert_eq!(out1, out2);
    }
}
