//! TCP front-end: a JSON-lines protocol over `std::net` exposing the
//! coordinator to external clients (no HTTP framework is vendored
//! offline; the protocol is deliberately line-oriented so `nc` works).
//!
//! Requests (one JSON object per line):
//! ```text
//! {"op":"create"}                         -> {"ok":true,"seq":N}
//! {"op":"attend","seq":N,
//!  "q":[...],"k":[...],"v":[...],"n":R}   -> {"ok":true,"y":[...],"seq_len":L}
//! {"op":"decode","seq":N,
//!  "q":[...],"k":[...],"v":[...]}         -> same as attend with n=1
//! {"op":"fork","seq":N}                   -> {"ok":true,"seq":C,"seq_parent":N}
//! {"op":"release","seq":N}                -> {"ok":true,"released":true}
//! {"op":"metrics"}                        -> {"ok":true,"metrics":{...}}
//! {"op":"snapshot","dir":"name"}          -> {"ok":true,"sequences":N,
//!                                             "state_bytes":B,"dir":"..."}
//! ```
//! `fork` clones the parent's attention state copy-on-write under a fresh
//! sequence id (ADR-006); both ids then evolve independently.
//! `snapshot` writes under the coordinator's configured `snapshot_root`
//! (`--snapshot-root`); `dir` is a plain directory *name* below it, never
//! a path — without a root the op is disabled.
//! Errors: `{"ok":false,"error":"..."}`. One thread per connection, up to
//! `max_conns` concurrent; past the cap the server writes a one-line JSON
//! error and closes instead of spawning (`shed_connections` counts these,
//! `active_connections` gauges the live handlers). The coordinator's own
//! backpressure bounds admitted work.

use crate::coordinator::request::{AttendChunk, SeqId};
use crate::coordinator::Coordinator;
use crate::math::linalg::Mat;
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// A running TCP server bound to `addr`.
pub struct Server {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind and start serving on `addr` (e.g. "127.0.0.1:0" for an
    /// ephemeral test port). At most `max_conns` connections are handled
    /// concurrently; excess accepts are shed with a JSON error reply
    /// instead of spawning an unbounded thread.
    pub fn start(
        addr: &str,
        coord: Arc<Coordinator>,
        max_conns: usize,
    ) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let metrics = coord.metrics_handle();
        let accept_thread = std::thread::Builder::new()
            .name("slay-server-accept".into())
            .spawn(move || {
                // Connection threads are detached: joining them on shutdown
                // would deadlock against clients blocked in read_line. Each
                // handler exits when its client closes or errors; a read
                // timeout bounds lingering after shutdown.
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // Only this thread increments the gauge, so a
                            // plain load-then-add admission check is
                            // race-free; handlers merely free slots.
                            if metrics.active_connections.load(Ordering::Relaxed)
                                >= max_conns as u64
                            {
                                metrics.shed_connections.fetch_add(1, Ordering::Relaxed);
                                shed(stream, max_conns);
                                continue;
                            }
                            let _ = stream
                                .set_read_timeout(Some(std::time::Duration::from_secs(30)));
                            metrics.active_connections.fetch_add(1, Ordering::Relaxed);
                            let c = coord.clone();
                            let m = metrics.clone();
                            std::thread::spawn(move || {
                                let _ = handle_conn(stream, c);
                                m.active_connections.fetch_sub(1, Ordering::Relaxed);
                            });
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        Err(_) => break,
                    }
                }
            })?;
        crate::log_info!("tcp server listening on {local} (max {max_conns} connections)");
        Ok(Server { addr: local, stop, accept_thread: Some(accept_thread) })
    }

    /// Stop accepting; existing connections finish their current line.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

/// Refuse a connection over the cap: one JSON error line, then close.
/// Best-effort — a peer that vanished mid-write is already gone.
fn shed(mut stream: TcpStream, max_conns: usize) {
    let reply = Json::obj(vec![
        ("ok", Json::Bool(false)),
        (
            "error",
            Json::Str(format!("server at connection capacity ({max_conns}); retry later")),
        ),
    ]);
    let _ = stream.write_all(reply.to_string().as_bytes());
    let _ = stream.write_all(b"\n");
}

fn handle_conn(stream: TcpStream, coord: Arc<Coordinator>) -> anyhow::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client closed
        }
        if line.trim().is_empty() {
            continue;
        }
        let reply = match handle_line(line.trim(), &coord) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::Str(e.to_string())),
            ]),
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
    }
}

/// Parse the required `seq` field as a nonnegative integer sequence id.
/// Missing, non-numeric, negative or fractional values are protocol
/// errors — they must never alias onto a real id (the seed's
/// `unwrap_or(-1.0) as u64` silently turned them into id 0).
fn seq_id(req: &Json) -> anyhow::Result<SeqId> {
    let v = req
        .req("seq")?
        .as_f64()
        .ok_or_else(|| anyhow::anyhow!("'seq' must be a number"))?;
    anyhow::ensure!(
        v >= 0.0 && v.fract() == 0.0 && v <= u64::MAX as f64,
        "'seq' must be a nonnegative integer (got {v})"
    );
    Ok(SeqId(v as u64))
}

fn handle_line(line: &str, coord: &Coordinator) -> anyhow::Result<Json> {
    let req = Json::parse(line).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let op = req
        .get("op")
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow::anyhow!("missing 'op'"))?;
    match op {
        "create" => {
            let seq = coord.create_sequence()?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("seq", Json::Num(seq.0 as f64)),
            ]))
        }
        "fork" => {
            let parent = seq_id(&req)?;
            let child = coord.fork_sequence(parent)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("seq", Json::Num(child.0 as f64)),
                ("seq_parent", Json::Num(parent.0 as f64)),
            ]))
        }
        "release" => {
            let seq = seq_id(&req)?;
            let released = coord.release_sequence(seq)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("released", Json::Bool(released)),
            ]))
        }
        "metrics" => Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("metrics", coord.metrics().to_json()),
        ])),
        "snapshot" => {
            let name = req
                .req("dir")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("'dir' must be a string"))?;
            // A network peer names a snapshot under the configured root —
            // it never chooses server-side paths (no snapshot_root, no
            // wire snapshots).
            let root = coord.config().snapshot_root.as_ref().ok_or_else(|| {
                anyhow::anyhow!("snapshot over TCP is disabled (serve with --snapshot-root)")
            })?;
            anyhow::ensure!(
                !name.is_empty()
                    && !name.starts_with('.')
                    && name
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.')),
                "'dir' must be a plain snapshot name under the snapshot root, not a path"
            );
            let dir = root.join(name);
            let report = coord.snapshot(&dir)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("sequences", Json::Num(report.sequences as f64)),
                ("state_bytes", Json::Num(report.bytes as f64)),
                ("dir", Json::Str(dir.display().to_string())),
            ]))
        }
        "attend" | "decode" => {
            let seq = seq_id(&req)?;
            // `decode` is single-token sugar: `n` defaults to 1 and, when
            // given, must be 1 — it shares the attend reply shape.
            let n = if op == "decode" {
                let n = req.get("n").and_then(|v| v.as_usize()).unwrap_or(1);
                anyhow::ensure!(n == 1, "'decode' is single-token (n=1), got n={n}");
                n
            } else {
                req.req("n")?.as_usize().unwrap_or(0)
            };
            let d_head = coord.config().d_head;
            let d_v = coord.config().d_v;
            let get = |key: &str, cols: usize| -> anyhow::Result<Mat> {
                let v = req
                    .req(key)?
                    .as_f32_vec()
                    .ok_or_else(|| anyhow::anyhow!("'{key}' must be a number array"))?;
                anyhow::ensure!(
                    v.len() == n * cols,
                    "'{key}' has {} values, expected n*{cols}={}",
                    v.len(),
                    n * cols
                );
                Ok(Mat::from_vec(n, cols, v))
            };
            let chunk = AttendChunk {
                seq,
                q: get("q", d_head)?,
                k: get("k", d_head)?,
                v: get("v", d_v)?,
            };
            let res = coord.attend(chunk)?;
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("seq_len", Json::Num(res.seq_len as f64)),
                ("latency_ms", Json::Num(res.latency.as_secs_f64() * 1e3)),
                ("y", Json::arr_f32(&res.y.data)),
            ]))
        }
        other => anyhow::bail!("unknown op '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use std::io::{BufRead, BufReader, Write};

    fn start() -> (Server, Arc<Coordinator>) {
        let coord = Arc::new(
            Coordinator::start(CoordinatorConfig {
                d_head: 4,
                d_v: 4,
                workers: 1,
                snapshot_root: Some(std::env::temp_dir().join("slay_server_snap_root")),
                ..CoordinatorConfig::default()
            })
            .unwrap(),
        );
        let server = Server::start("127.0.0.1:0", coord.clone(), 1024).unwrap();
        (server, coord)
    }

    fn roundtrip(stream: &TcpStream, req: &str) -> Json {
        let mut w = stream.try_clone().unwrap();
        w.write_all(req.as_bytes()).unwrap();
        w.write_all(b"\n").unwrap();
        let mut r = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        Json::parse(line.trim()).unwrap()
    }

    #[test]
    fn full_protocol_roundtrip() {
        let (server, _coord) = start();
        let stream = TcpStream::connect(server.addr).unwrap();

        let created = roundtrip(&stream, r#"{"op":"create"}"#);
        assert_eq!(created.get("ok").unwrap().as_bool(), Some(true));
        let seq = created.get("seq").unwrap().as_usize().unwrap();

        let ones = vec!["1.0"; 8].join(",");
        let attend = roundtrip(
            &stream,
            &format!(
                r#"{{"op":"attend","seq":{seq},"n":2,"q":[{ones}],"k":[{ones}],"v":[{ones}]}}"#
            ),
        );
        assert_eq!(attend.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(attend.get("seq_len").unwrap().as_usize(), Some(2));
        assert_eq!(attend.get("y").unwrap().as_f32_vec().unwrap().len(), 8);

        let metrics = roundtrip(&stream, r#"{"op":"metrics"}"#);
        assert_eq!(
            metrics
                .get("metrics")
                .unwrap()
                .get("completed")
                .unwrap()
                .as_usize(),
            Some(1)
        );

        let released = roundtrip(&stream, &format!(r#"{{"op":"release","seq":{seq}}}"#));
        assert_eq!(released.get("released").unwrap().as_bool(), Some(true));
        server.shutdown();
    }

    #[test]
    fn errors_are_reported_not_fatal() {
        let (server, _coord) = start();
        let stream = TcpStream::connect(server.addr).unwrap();
        let bad = roundtrip(&stream, "not json at all");
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
        let unknown = roundtrip(&stream, r#"{"op":"warp"}"#);
        assert_eq!(unknown.get("ok").unwrap().as_bool(), Some(false));
        // connection still alive
        let m = roundtrip(&stream, r#"{"op":"metrics"}"#);
        assert_eq!(m.get("ok").unwrap().as_bool(), Some(true));
        server.shutdown();
    }

    #[test]
    fn attend_validates_shapes() {
        let (server, _coord) = start();
        let stream = TcpStream::connect(server.addr).unwrap();
        let created = roundtrip(&stream, r#"{"op":"create"}"#);
        let seq = created.get("seq").unwrap().as_usize().unwrap();
        let bad = roundtrip(
            &stream,
            &format!(r#"{{"op":"attend","seq":{seq},"n":2,"q":[1.0],"k":[1.0],"v":[1.0]}}"#),
        );
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
        server.shutdown();
    }

    #[test]
    fn malformed_seq_is_rejected_not_aliased_to_zero() {
        // Seed bug: a missing/non-numeric/negative `seq` silently became
        // id 0. Every such request must now fail as a protocol error.
        let (server, _coord) = start();
        let stream = TcpStream::connect(server.addr).unwrap();
        let ones = vec!["1.0"; 4].join(",");
        for req in [
            // missing seq
            format!(r#"{{"op":"attend","n":1,"q":[{ones}],"k":[{ones}],"v":[{ones}]}}"#),
            // non-numeric seq
            format!(r#"{{"op":"attend","seq":"x","n":1,"q":[{ones}],"k":[{ones}],"v":[{ones}]}}"#),
            // negative seq
            format!(r#"{{"op":"attend","seq":-3,"n":1,"q":[{ones}],"k":[{ones}],"v":[{ones}]}}"#),
            // fractional seq
            format!(r#"{{"op":"attend","seq":1.5,"n":1,"q":[{ones}],"k":[{ones}],"v":[{ones}]}}"#),
            // and the same for release
            r#"{"op":"release"}"#.to_string(),
            r#"{"op":"release","seq":-1}"#.to_string(),
        ] {
            let reply = roundtrip(&stream, &req);
            assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false), "{req}");
        }
        server.shutdown();
    }

    #[test]
    fn attend_on_unknown_sequence_reports_an_error() {
        let (server, _coord) = start();
        let stream = TcpStream::connect(server.addr).unwrap();
        let ones = vec!["1.0"; 4].join(",");
        let req =
            format!(r#"{{"op":"attend","seq":4242,"n":1,"q":[{ones}],"k":[{ones}],"v":[{ones}]}}"#);
        let reply = roundtrip(&stream, &req);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false));
        assert!(
            reply.get("error").unwrap().as_str().unwrap().contains("unknown sequence"),
            "error should name the unknown sequence: {reply:?}"
        );
        // the connection and coordinator survive
        let m = roundtrip(&stream, r#"{"op":"metrics"}"#);
        assert_eq!(m.get("ok").unwrap().as_bool(), Some(true));
        server.shutdown();
    }

    #[test]
    fn snapshot_op_writes_a_restorable_manifest_under_the_root() {
        let (server, coord) = start();
        let root = coord.config().snapshot_root.clone().unwrap();
        let dir = root.join("snap_test");
        let _ = std::fs::remove_dir_all(&dir);
        let stream = TcpStream::connect(server.addr).unwrap();
        let created = roundtrip(&stream, r#"{"op":"create"}"#);
        let seq = created.get("seq").unwrap().as_usize().unwrap();
        let ones = vec!["1.0"; 8].join(",");
        roundtrip(
            &stream,
            &format!(
                r#"{{"op":"attend","seq":{seq},"n":2,"q":[{ones}],"k":[{ones}],"v":[{ones}]}}"#
            ),
        );
        let snap = roundtrip(&stream, r#"{"op":"snapshot","dir":"snap_test"}"#);
        assert_eq!(snap.get("ok").unwrap().as_bool(), Some(true), "{snap:?}");
        assert_eq!(snap.get("sequences").unwrap().as_usize(), Some(1));
        let manifest = crate::coordinator::persist::Manifest::load(&dir).unwrap();
        assert_eq!(manifest.seqs, vec![(seq as u64, 2)]);
        // path-shaped names never reach the filesystem
        for bad in [
            r#"{"op":"snapshot","dir":"../evil"}"#,
            r#"{"op":"snapshot","dir":"/abs/path"}"#,
            r#"{"op":"snapshot","dir":".."}"#,
            r#"{"op":"snapshot","dir":""}"#,
        ] {
            let reply = roundtrip(&stream, bad);
            assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false), "{bad}");
        }
        let _ = std::fs::remove_dir_all(&dir);
        server.shutdown();
    }

    #[test]
    fn snapshot_op_is_disabled_without_a_root() {
        let coord = Arc::new(
            Coordinator::start(CoordinatorConfig {
                d_head: 4,
                d_v: 4,
                workers: 1,
                ..CoordinatorConfig::default()
            })
            .unwrap(),
        );
        let server = Server::start("127.0.0.1:0", coord, 1024).unwrap();
        let stream = TcpStream::connect(server.addr).unwrap();
        let reply = roundtrip(&stream, r#"{"op":"snapshot","dir":"snap"}"#);
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false));
        assert!(reply.get("error").unwrap().as_str().unwrap().contains("disabled"));
        server.shutdown();
    }

    #[test]
    fn fork_op_clones_a_session_over_the_wire() {
        let (server, coord) = start();
        let stream = TcpStream::connect(server.addr).unwrap();

        let created = roundtrip(&stream, r#"{"op":"create"}"#);
        let seq = created.get("seq").unwrap().as_usize().unwrap();
        let ones = vec!["1.0"; 8].join(",");
        roundtrip(
            &stream,
            &format!(
                r#"{{"op":"attend","seq":{seq},"n":2,"q":[{ones}],"k":[{ones}],"v":[{ones}]}}"#
            ),
        );

        let forked = roundtrip(&stream, &format!(r#"{{"op":"fork","seq":{seq}}}"#));
        assert_eq!(forked.get("ok").unwrap().as_bool(), Some(true), "{forked:?}");
        assert_eq!(forked.get("seq_parent").unwrap().as_usize(), Some(seq));
        let child = forked.get("seq").unwrap().as_usize().unwrap();
        assert_ne!(child, seq, "fork must allocate a fresh sequence id");

        // identical continuations on parent and child stay bit-identical
        let tok = vec!["0.5"; 4].join(",");
        let mut replies = Vec::new();
        for id in [seq, child] {
            let r = roundtrip(
                &stream,
                &format!(r#"{{"op":"decode","seq":{id},"q":[{tok}],"k":[{tok}],"v":[{tok}]}}"#),
            );
            assert_eq!(r.get("ok").unwrap().as_bool(), Some(true), "{r:?}");
            assert_eq!(r.get("seq_len").unwrap().as_usize(), Some(3));
            replies.push(r.get("y").unwrap().as_f32_vec().unwrap());
        }
        assert_eq!(replies[0], replies[1], "fork diverged from its parent");
        assert_eq!(coord.metrics().forks, 1);

        // multi-token decode and unknown parents are protocol errors
        let bad = roundtrip(
            &stream,
            &format!(r#"{{"op":"decode","seq":{seq},"n":2,"q":[{ones}],"k":[{ones}],"v":[{ones}]}}"#),
        );
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));
        let unknown = roundtrip(&stream, r#"{"op":"fork","seq":999000}"#);
        assert_eq!(unknown.get("ok").unwrap().as_bool(), Some(false));
        server.shutdown();
    }

    #[test]
    fn connection_cap_sheds_with_json_error_and_recovers() {
        let coord = Arc::new(
            Coordinator::start(CoordinatorConfig {
                d_head: 4,
                d_v: 4,
                workers: 1,
                ..CoordinatorConfig::default()
            })
            .unwrap(),
        );
        let server = Server::start("127.0.0.1:0", coord.clone(), 1).unwrap();

        // first connection occupies the single slot; a completed roundtrip
        // proves its handler (and the gauge increment) is live
        let first = TcpStream::connect(server.addr).unwrap();
        let m = roundtrip(&first, r#"{"op":"metrics"}"#);
        assert_eq!(m.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(coord.metrics().active_connections, 1);

        // second connection is shed with a one-line JSON error, not queued
        let second = TcpStream::connect(server.addr).unwrap();
        let mut line = String::new();
        BufReader::new(second).read_line(&mut line).unwrap();
        let reply = Json::parse(line.trim()).unwrap();
        assert_eq!(reply.get("ok").unwrap().as_bool(), Some(false));
        assert!(
            reply.get("error").unwrap().as_str().unwrap().contains("capacity"),
            "shed reply should name the cap: {reply:?}"
        );
        assert_eq!(coord.metrics().shed_connections, 1);
        assert_eq!(coord.metrics().active_connections, 1);

        // closing the first frees the slot for a later client
        drop(first);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while coord.metrics().active_connections != 0 {
            assert!(std::time::Instant::now() < deadline, "slot never freed");
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        let third = TcpStream::connect(server.addr).unwrap();
        let m = roundtrip(&third, r#"{"op":"metrics"}"#);
        assert_eq!(m.get("ok").unwrap().as_bool(), Some(true));
        server.shutdown();
    }
}
